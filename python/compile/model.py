"""L2 JAX model: the compute graphs ApproxJoin's Rust coordinator executes.

Three graphs, each AOT-lowered to HLO text by aot.py and loaded by
``rust/src/runtime``:

* ``join_agg``     — the sampling-stage hot path (Alg 2 line 25): combine the
                     two sampled endpoint values per the query's aggregate
                     expression, then segment-aggregate per stratum via the
                     L1 Pallas kernel. Output feeds the CLT estimator.
* ``bloom_probe``  — the filtering-stage hot path (Alg 1 / §3.1): batched
                     membership of tuple keys in the broadcast join filter
                     (L1 Pallas kernel).
* ``clt_estimate`` — paper eq 12-14: per-stratum aggregates -> (total
                     estimate, variance estimate). The t-quantile and the
                     final ± bound stay in Rust (stats::distributions).

Everything is shape-static: the Rust side pads the last batch and masks.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.bloom import bloom_probe as _bloom_probe_kernel
from .kernels.stratified import seg_agg

# Artifact geometry — mirrored in rust/src/runtime/mod.rs (ArtifactGeometry).
BATCH = 4096          # rows per join_agg / bloom_probe execution
STRATA = 256          # stratum slots per join_agg execution
NUM_HASHES = 5        # h, probe bits per key
LOG2_BITS = 20        # m = 2^20 bits -> 32768 u32 words (128 KiB)
NWORDS = (1 << LOG2_BITS) // 32

# Combine-op one-hot indices (order pinned; mirrored in runtime/batch.rs).
OP_ADD, OP_MUL, OP_LEFT, OP_RIGHT = 0, 1, 2, 3


def join_agg(v1, v2, seg, mask, op):
    """Combine sampled pair values and aggregate per stratum.

    v1, v2: f32[BATCH] sampled endpoint values (left/right side of the edge)
    seg:    i32[BATCH] stratum slot in [0, STRATA)
    mask:   f32[BATCH] 1.0 for real rows, 0.0 for padding
    op:     f32[4] one-hot combine selector (OP_*)

    Returns (counts, sums, sumsqs) each f32[STRATA].
    """
    combined = op[0] * (v1 + v2) + op[1] * (v1 * v2) + op[2] * v1 + op[3] * v2
    combined = combined * mask
    stack = jnp.stack([mask, combined, combined * combined], axis=1)
    # CPU-artifact lowering: scatter body, single grid step. The matmul
    # body is the TPU lowering (MXU); on CPU-XLA the scatter is ~60x
    # faster at identical numerics — see EXPERIMENTS.md §Perf iter 1-2 and
    # kernels/stratified.py for the two bodies.
    out = seg_agg(seg, stack, num_strata=STRATA, block=BATCH, method="scatter")
    return out[:, 0], out[:, 1], out[:, 2]


def bloom_probe(words, keys):
    """int32[BATCH] membership mask of keys against the packed join filter."""
    return _bloom_probe_kernel(words, keys, num_hashes=NUM_HASHES,
                               log2_bits=LOG2_BITS)


def clt_estimate(big_b, small_b, sums, sumsqs):
    """(tau_hat, var_hat) for the stratified CLT estimator (eq 12-14)."""
    return ref.clt_estimate_ref(big_b, small_b, sums, sumsqs)
