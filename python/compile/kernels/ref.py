"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle bit-for-bit (integer paths) or to float tolerance
(accumulation paths). pytest sweeps shapes/dtypes with hypothesis against
these functions.

The 32-bit hash family here is mirrored *exactly* (same constants, same
wrapping arithmetic) by ``rust/src/bloom/hashing.rs``; golden values are
pinned on both sides so the two implementations cannot drift.
"""

from __future__ import annotations

import jax.numpy as jnp

# Seeds for the double-hash family. Mirrored in rust/src/bloom/hashing.rs.
SEED1 = 0x9E3779B9
SEED2 = 0x85EBCA77


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (wrapping u32 arithmetic)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def bloom_hashes(keys: jnp.ndarray, num_hashes: int, log2_bits: int) -> jnp.ndarray:
    """Positions of the ``num_hashes`` probe bits for each key.

    Double hashing (Kirsch-Mitzenmacher): pos_i = (h1 + i*h2) mod m with m a
    power of two and h2 forced odd so the probe sequence spans the table.

    Returns uint32[..., num_hashes].
    """
    keys = keys.astype(jnp.uint32)
    mask = jnp.uint32((1 << log2_bits) - 1)
    h1 = mix32(keys ^ jnp.uint32(SEED1))
    h2 = mix32(keys ^ jnp.uint32(SEED2)) | jnp.uint32(1)
    i = jnp.arange(num_hashes, dtype=jnp.uint32)
    return (h1[..., None] + i * h2[..., None]) & mask


def bloom_probe_ref(words: jnp.ndarray, keys: jnp.ndarray, *, num_hashes: int,
                    log2_bits: int) -> jnp.ndarray:
    """Membership mask (int32 0/1) of ``keys`` against a packed bit array.

    ``words`` is uint32[m/32]; bit ``p`` lives at words[p >> 5] bit (p & 31).
    """
    pos = bloom_hashes(keys, num_hashes, log2_bits)          # (B, H) u32
    word = jnp.take(words, (pos >> 5).astype(jnp.int32), axis=0)
    bit = (word >> (pos & jnp.uint32(31))) & jnp.uint32(1)
    return jnp.all(bit == 1, axis=-1).astype(jnp.int32)


def bloom_build_ref(keys: jnp.ndarray, *, num_hashes: int, log2_bits: int) -> jnp.ndarray:
    """Packed bit array (uint32[m/32]) with all probe bits of ``keys`` set."""
    pos = bloom_hashes(keys, num_hashes, log2_bits).reshape(-1)
    nwords = (1 << log2_bits) // 32
    bits = jnp.zeros((1 << log2_bits,), dtype=jnp.uint32)
    bits = bits.at[(pos).astype(jnp.int32)].set(jnp.uint32(1))
    # pack: bit p -> word p>>5, bit p&31
    bits = bits.reshape(nwords, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)


def seg_agg_ref(seg: jnp.ndarray, stack: jnp.ndarray, *, num_strata: int) -> jnp.ndarray:
    """Segment aggregation oracle: out[s, c] = sum_{i: seg[i]==s} stack[i, c].

    seg: int32[B]; stack: f32[B, C]; returns f32[num_strata, C].
    """
    onehot = (seg[:, None] == jnp.arange(num_strata)[None, :]).astype(stack.dtype)
    return onehot.T @ stack


def join_agg_ref(v1, v2, seg, mask, op, *, num_strata: int):
    """Oracle for the L2 join_agg model (combine + segment aggregate).

    op is a one-hot-ish f32[4] selector over combine ops:
      op[0]: v1 + v2   op[1]: v1 * v2   op[2]: v1   op[3]: v2
    Masked-out rows (mask==0) contribute nothing, including to counts.
    Returns (counts, sums, sumsqs) each f32[num_strata].
    """
    combined = op[0] * (v1 + v2) + op[1] * (v1 * v2) + op[2] * v1 + op[3] * v2
    combined = combined * mask
    stack = jnp.stack([mask, combined, combined * combined], axis=1)
    out = seg_agg_ref(seg, stack, num_strata=num_strata)
    return out[:, 0], out[:, 1], out[:, 2]


def clt_estimate_ref(big_b, small_b, sums, sumsqs):
    """Oracle for the CLT stratified estimator (paper eq 12-14).

    big_b:  f32[S]  B_i, population size (bipartite-product size) per stratum
    small_b:f32[S]  b_i, number of samples drawn per stratum
    sums:   f32[S]  sum of sampled combined values per stratum
    sumsqs: f32[S]  sum of squares of sampled combined values per stratum

    tau_hat = sum_i B_i/b_i * sum_i            (eq 12 text)
    var_hat = sum_i B_i (B_i - b_i) s_i^2/b_i  (eq 14, s_i^2 sample variance)

    Strata with b_i == 0 contribute nothing; b_i == 1 contributes to the
    total but not the variance (s_i^2 undefined); the (B_i - b_i) finite
    population correction is clamped at 0 for with-replacement oversampling.
    """
    safe_b = jnp.maximum(small_b, 1.0)
    mean = sums / safe_b
    s2 = jnp.where(small_b > 1,
                   jnp.maximum(sumsqs - safe_b * mean * mean, 0.0)
                   / jnp.maximum(safe_b - 1.0, 1.0),
                   0.0)
    tau = jnp.sum(jnp.where(small_b > 0, big_b / safe_b * sums, 0.0))
    fpc = jnp.maximum(big_b - small_b, 0.0)
    var = jnp.sum(jnp.where(small_b > 1, big_b * fpc * s2 / safe_b, 0.0))
    return tau, var
