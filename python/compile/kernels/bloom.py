"""L1 Pallas kernel: Bloom-filter membership probe over a packed bit array.

The filtering stage of ApproxJoin (paper §3.1, Alg 1) checks every tuple key
of every input against the broadcast *join filter*. That membership probe is
the per-tuple hot spot of stage 1, so it is expressed as a Pallas kernel:
the full packed bit array (m = 2^20 bits = 128 KiB of u32 words) stays
resident in VMEM while 4096-key batches stream through; each key computes
its ``h`` probe positions with the Kirsch-Mitzenmacher double hash (same
constants as rust/src/bloom/hashing.rs) and gathers+tests the bits.

This is a memory/VPU kernel, not an MXU kernel — the relevant TPU insight
is keeping the filter in scratchpad across the whole batch stream, which
BlockSpec expresses by mapping the words operand to the same (whole) block
on every grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _bloom_probe_kernel(words_ref, keys_ref, out_ref, *, num_hashes: int,
                        log2_bits: int):
    words = words_ref[...]                       # (W,) u32, whole filter
    keys = keys_ref[...].astype(jnp.uint32)      # (BLK,)
    mask = jnp.uint32((1 << log2_bits) - 1)
    h1 = ref.mix32(keys ^ jnp.uint32(ref.SEED1))
    h2 = ref.mix32(keys ^ jnp.uint32(ref.SEED2)) | jnp.uint32(1)
    member = jnp.ones(keys.shape, dtype=jnp.bool_)
    for i in range(num_hashes):
        pos = (h1 + jnp.uint32(i) * h2) & mask
        word = jnp.take(words, (pos >> 5).astype(jnp.int32))
        bit = (word >> (pos & jnp.uint32(31))) & jnp.uint32(1)
        member = member & (bit == jnp.uint32(1))
    out_ref[...] = member.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_hashes", "log2_bits", "block"))
def bloom_probe(words: jnp.ndarray, keys: jnp.ndarray, *, num_hashes: int,
                log2_bits: int, block: int = 1024) -> jnp.ndarray:
    """int32[B] mask: 1 where key may be in the filter, 0 where definitely not.

    words: uint32[2^log2_bits / 32] packed bit array (bit p at word p>>5,
    bit p&31). keys: uint32[B], B a multiple of ``block``.
    """
    (b,) = keys.shape
    nwords = (1 << log2_bits) // 32
    if words.shape != (nwords,):
        raise ValueError(f"words shape {words.shape} != ({nwords},)")
    if b % block != 0:
        raise ValueError(f"batch {b} must be a multiple of block {block}")
    grid = (b // block,)
    return pl.pallas_call(
        functools.partial(_bloom_probe_kernel, num_hashes=num_hashes,
                          log2_bits=log2_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nwords,), lambda i: (0,)),   # filter resident
            pl.BlockSpec((block,), lambda i: (i,)),    # key stream
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(words, keys)
