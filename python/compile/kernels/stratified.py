"""L1 Pallas kernel: stratified segment aggregation as a one-hot matmul.

The sampling stage of ApproxJoin (Alg 2) must reduce a stream of sampled
pair values into per-stratum (count, sum, sum-of-squares) triples — the
inputs to the CLT estimator (paper eq 12-14). On a TPU the natural way to
do a segment reduction is NOT a scatter (slow, serializing) but a one-hot
matrix product on the MXU systolic array:

    out[S, C] = onehot(seg)[B, S]^T @ stack[B, C]

The kernel tiles the batch dimension with BlockSpec so each grid step holds
one (BLK, S) one-hot tile + a (BLK, C) value tile + the (S, C) accumulator
in VMEM, and accumulates across grid steps into the same output block.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU VMEM/MXU estimates live in DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_agg_kernel_matmul(seg_ref, stack_ref, out_ref, *, num_strata: int):
    """TPU-shaped body: one-hot matmul on the MXU systolic array."""
    step = pl.program_id(0)
    seg = seg_ref[...]                                   # (BLK,) int32
    stack = stack_ref[...]                               # (BLK, C) f32
    onehot = (seg[:, None] == jnp.arange(num_strata, dtype=seg.dtype)[None, :])
    onehot = onehot.astype(stack.dtype)                  # (BLK, S)
    partial = jnp.dot(onehot.T, stack,
                      preferred_element_type=jnp.float32)  # (S, C) on the MXU

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def _seg_agg_kernel_scatter(seg_ref, stack_ref, out_ref, *, num_strata: int):
    """CPU-shaped body: scatter-add. On CPU-XLA a scatter over 256 buckets
    is ~60x faster than materializing the (BLK, S) one-hot and taking a
    skinny dot (EXPERIMENTS.md §Perf iteration 2); on a real TPU the matmul
    body wins — the MXU eats the one-hot and scatters serialize."""
    step = pl.program_id(0)
    seg = seg_ref[...]
    stack = stack_ref[...]
    partial = jnp.zeros((num_strata, stack.shape[1]), stack.dtype).at[seg].add(stack)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


_KERNELS = {
    "matmul": _seg_agg_kernel_matmul,
    "scatter": _seg_agg_kernel_scatter,
}


@functools.partial(jax.jit, static_argnames=("num_strata", "block", "method"))
def seg_agg(seg: jnp.ndarray, stack: jnp.ndarray, *, num_strata: int,
            block: int = 512, method: str = "matmul") -> jnp.ndarray:
    """Segment-sum ``stack`` rows into ``num_strata`` buckets keyed by ``seg``.

    seg: int32[B] with values in [0, num_strata); rows used for padding
    should carry zeros in ``stack`` (any seg value is then harmless).
    stack: f32[B, C]. Returns f32[num_strata, C].

    ``method`` picks the kernel body: "matmul" (MXU-shaped, the TPU
    lowering) or "scatter" (the CPU-artifact lowering). Both are
    hypothesis-checked against the same oracle.
    """
    b, c = stack.shape
    if b % block != 0:
        raise ValueError(f"batch {b} must be a multiple of block {block}")
    grid = (b // block,)
    return pl.pallas_call(
        functools.partial(_KERNELS[method], num_strata=num_strata),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_strata, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_strata, c), jnp.float32),
        interpret=True,
    )(seg, stack)
