"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text — NOT serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowered with return_tuple=True; the Rust side unwraps with
``to_tuple*``.

Run as:  cd python && python -m compile.aot --out-dir ../artifacts
Makefile target ``artifacts`` is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all() -> dict[str, str]:
    """Lower every artifact; returns {name: hlo_text}."""
    b, s, w = model.BATCH, model.STRATA, model.NWORDS
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    arts = {}

    arts["join_agg"] = to_hlo_text(jax.jit(model.join_agg).lower(
        _spec((b,), f32), _spec((b,), f32), _spec((b,), i32),
        _spec((b,), f32), _spec((4,), f32)))

    arts["bloom_probe"] = to_hlo_text(jax.jit(model.bloom_probe).lower(
        _spec((w,), u32), _spec((b,), u32)))

    arts["clt_estimate"] = to_hlo_text(jax.jit(model.clt_estimate).lower(
        _spec((s,), f32), _spec((s,), f32), _spec((s,), f32), _spec((s,), f32)))

    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    arts = lower_all()
    manifest = {
        "geometry": {
            "batch": model.BATCH,
            "strata": model.STRATA,
            "num_hashes": model.NUM_HASHES,
            "log2_bits": model.LOG2_BITS,
            "nwords": model.NWORDS,
        },
        "artifacts": {},
    }
    for name, text in arts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
