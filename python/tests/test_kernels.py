"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes per the repo's test policy; golden hash
values pin the Rust<->Python hash family.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bloom import bloom_probe
from compile.kernels.stratified import seg_agg

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------- hash family

# Golden values pinned against rust/src/bloom/hashing.rs (tests there pin the
# same constants). If either side changes, both tests fail.
GOLDEN_MIX32 = {
    0: 0x0,
    1: 0x514E28B7,
    42: 0x087FCD5C,
    0xDEADBEEF: 0x0DE5C6A9,
    123456789: 0xBA60D89A,
}
GOLDEN_POS_42 = [650960, 828291, 1005622, 134377, 311708]
GOLDEN_POS_0 = [667406, 868387, 20792, 221773, 422754]


def test_mix32_golden():
    keys = jnp.asarray(np.array(list(GOLDEN_MIX32), dtype=np.uint32))
    got = [int(v) for v in ref.mix32(keys)]
    assert got == list(GOLDEN_MIX32.values())


def test_bloom_positions_golden():
    pos = ref.bloom_hashes(jnp.uint32(42), 5, 20)
    assert [int(p) for p in pos] == GOLDEN_POS_42
    pos = ref.bloom_hashes(jnp.uint32(0), 5, 20)
    assert [int(p) for p in pos] == GOLDEN_POS_0


@given(st.integers(0, 2**32 - 1))
def test_mix32_is_a_bijection_roundtrip_free(k):
    # finalizer must be deterministic + stay in u32 range
    v = int(ref.mix32(jnp.uint32(k)))
    assert 0 <= v < 2**32
    assert int(ref.mix32(jnp.uint32(k))) == v


@given(st.integers(1, 8), st.integers(10, 20), st.integers(0, 2**32 - 1))
def test_bloom_hashes_in_range(h, log2_bits, key):
    pos = np.asarray(ref.bloom_hashes(jnp.uint32(key), h, log2_bits))
    assert pos.shape == (h,)
    assert (pos < (1 << log2_bits)).all()


# ---------------------------------------------------------- seg_agg (Pallas)

@given(
    blocks=st.integers(1, 4),
    block=st.sampled_from([8, 64, 128]),
    strata=st.sampled_from([4, 32, 256]),
    cols=st.integers(1, 4),
    method=st.sampled_from(["matmul", "scatter"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_seg_agg_matches_ref(blocks, block, strata, cols, method, seed):
    rng = np.random.default_rng(seed)
    b = blocks * block
    seg = rng.integers(0, strata, b).astype(np.int32)
    stack = rng.normal(size=(b, cols)).astype(np.float32)
    got = seg_agg(jnp.asarray(seg), jnp.asarray(stack),
                  num_strata=strata, block=block, method=method)
    want = ref.seg_agg_ref(jnp.asarray(seg), jnp.asarray(stack),
                           num_strata=strata)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_seg_agg_methods_agree():
    rng = np.random.default_rng(3)
    seg = jnp.asarray(rng.integers(0, 64, 512).astype(np.int32))
    stack = jnp.asarray(rng.normal(size=(512, 3)).astype(np.float32))
    a = seg_agg(seg, stack, num_strata=64, block=128, method="matmul")
    b = seg_agg(seg, stack, num_strata=64, block=128, method="scatter")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


def test_seg_agg_rejects_ragged_batch():
    with pytest.raises(ValueError):
        seg_agg(jnp.zeros(100, jnp.int32), jnp.zeros((100, 3), jnp.float32),
                num_strata=8, block=64)


def test_seg_agg_empty_strata_are_zero():
    seg = jnp.zeros(128, jnp.int32)  # everything in stratum 0
    stack = jnp.ones((128, 2), jnp.float32)
    out = np.asarray(seg_agg(seg, stack, num_strata=16, block=64))
    assert out[0, 0] == 128.0
    assert (out[1:] == 0).all()


# ------------------------------------------------------- bloom_probe (Pallas)

@given(
    log2_bits=st.sampled_from([14, 17, 20]),
    h=st.integers(1, 7),
    n_members=st.integers(1, 2000),
    seed=st.integers(0, 2**31 - 1),
)
def test_bloom_probe_no_false_negatives(log2_bits, h, n_members, seed):
    rng = np.random.default_rng(seed)
    members = rng.integers(0, 2**32, n_members, dtype=np.uint32)
    words = ref.bloom_build_ref(jnp.asarray(members), num_hashes=h,
                                log2_bits=log2_bits)
    batch = 1024
    keys = np.zeros(batch, dtype=np.uint32)
    keys[: min(n_members, batch)] = members[:batch]
    got = np.asarray(bloom_probe(words, jnp.asarray(keys), num_hashes=h,
                                 log2_bits=log2_bits, block=256))
    assert (got[: min(n_members, batch)] == 1).all()
    want = np.asarray(ref.bloom_probe_ref(words, jnp.asarray(keys),
                                          num_hashes=h, log2_bits=log2_bits))
    np.testing.assert_array_equal(got, want)


def test_bloom_probe_false_positive_rate_sane():
    rng = np.random.default_rng(7)
    members = rng.integers(0, 2**32, 50_000, dtype=np.uint32)
    words = ref.bloom_build_ref(jnp.asarray(members), num_hashes=5,
                                log2_bits=20)
    others = rng.integers(0, 2**32, 4096, dtype=np.uint32)
    got = np.asarray(bloom_probe(words, jnp.asarray(others), num_hashes=5,
                                 log2_bits=20))
    # theoretical fp ~ (1 - e^{-hn/m})^h ~ 0.00066 at n=50k, m=2^20, h=5.
    assert got.mean() < 0.01


def test_bloom_probe_rejects_bad_words_shape():
    with pytest.raises(ValueError):
        bloom_probe(jnp.zeros(100, jnp.uint32), jnp.zeros(1024, jnp.uint32),
                    num_hashes=5, log2_bits=20)


def test_empty_filter_rejects_everything():
    words = jnp.zeros(1 << 15, jnp.uint32)  # log2_bits=20 -> 32768 words
    keys = jnp.arange(1024, dtype=jnp.uint32)
    got = np.asarray(bloom_probe(words, keys, num_hashes=5, log2_bits=20))
    assert (got == 0).all()


def test_full_filter_accepts_everything():
    words = jnp.full((1 << 15,), 0xFFFFFFFF, jnp.uint32)
    keys = jnp.arange(1024, dtype=jnp.uint32)
    got = np.asarray(bloom_probe(words, keys, num_hashes=5, log2_bits=20))
    assert (got == 1).all()
