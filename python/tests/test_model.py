"""L2 model correctness: join_agg / clt_estimate vs independent numpy math."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

_OPS = {
    model.OP_ADD: lambda a, b: a + b,
    model.OP_MUL: lambda a, b: a * b,
    model.OP_LEFT: lambda a, b: a,
    model.OP_RIGHT: lambda a, b: b,
}


@given(op_idx=st.sampled_from(sorted(_OPS)), seed=st.integers(0, 2**31 - 1),
       mask_p=st.floats(0.0, 1.0))
def test_join_agg_matches_numpy(op_idx, seed, mask_p):
    rng = np.random.default_rng(seed)
    B, S = model.BATCH, model.STRATA
    v1 = rng.normal(size=B).astype(np.float32)
    v2 = rng.normal(size=B).astype(np.float32)
    seg = rng.integers(0, S, B).astype(np.int32)
    mask = (rng.random(B) < mask_p).astype(np.float32)
    op = np.zeros(4, np.float32)
    op[op_idx] = 1.0

    counts, sums, sumsqs = model.join_agg(v1, v2, seg, mask, op)

    comb = _OPS[op_idx](v1, v2) * mask
    cn, sn, qn = np.zeros(S), np.zeros(S), np.zeros(S)
    np.add.at(cn, seg, mask)
    np.add.at(sn, seg, comb)
    np.add.at(qn, seg, comb * comb)
    np.testing.assert_allclose(np.asarray(counts), cn, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sums), sn, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sumsqs), qn, rtol=1e-4, atol=1e-2)


def test_join_agg_all_masked_is_zero():
    B = model.BATCH
    z = np.zeros(B, np.float32)
    counts, sums, sumsqs = model.join_agg(
        np.ones(B, np.float32), np.ones(B, np.float32),
        np.zeros(B, np.int32), z, np.array([1, 0, 0, 0], np.float32))
    assert float(jnp.sum(counts)) == 0.0
    assert float(jnp.sum(jnp.abs(sums))) == 0.0
    assert float(jnp.sum(sumsqs)) == 0.0


@given(seed=st.integers(0, 2**31 - 1))
def test_clt_estimate_matches_direct_stratified_math(seed):
    """tau/var from the graph == hand-rolled eq 12-14 on materialized samples."""
    rng = np.random.default_rng(seed)
    S = model.STRATA
    m_active = rng.integers(1, 40)
    big_b = np.zeros(S, np.float32)
    small_b = np.zeros(S, np.float32)
    sums = np.zeros(S, np.float32)
    sumsqs = np.zeros(S, np.float32)
    tau_want, var_want = 0.0, 0.0
    for i in range(m_active):
        bi = int(rng.integers(2, 50))
        Bi = bi + int(rng.integers(0, 100))
        vals = rng.normal(loc=rng.uniform(-5, 5), size=bi)
        big_b[i], small_b[i] = Bi, bi
        sums[i], sumsqs[i] = vals.sum(), (vals**2).sum()
        s2 = vals.var(ddof=1)
        tau_want += Bi / bi * vals.sum()
        var_want += Bi * (Bi - bi) * s2 / bi
    tau, var = model.clt_estimate(big_b, small_b, sums, sumsqs)
    np.testing.assert_allclose(float(tau), tau_want, rtol=1e-3)
    np.testing.assert_allclose(float(var), max(var_want, 0.0),
                               rtol=1e-2, atol=1e-2)


def test_clt_estimate_singleton_and_empty_strata():
    S = model.STRATA
    big_b = np.zeros(S, np.float32)
    small_b = np.zeros(S, np.float32)
    sums = np.zeros(S, np.float32)
    sumsqs = np.zeros(S, np.float32)
    # stratum 0: one sample of value 3, population 10 -> contributes 10*3
    big_b[0], small_b[0], sums[0], sumsqs[0] = 10, 1, 3, 9
    tau, var = model.clt_estimate(big_b, small_b, sums, sumsqs)
    assert float(tau) == 30.0
    assert float(var) == 0.0  # singleton: no variance contribution


def test_clt_estimate_oversampled_stratum_clamps_fpc():
    """with-replacement can draw b_i > B_i; FPC must clamp at 0, not go negative."""
    S = model.STRATA
    big_b = np.zeros(S, np.float32)
    small_b = np.zeros(S, np.float32)
    sums = np.zeros(S, np.float32)
    sumsqs = np.zeros(S, np.float32)
    big_b[0], small_b[0] = 4, 8
    sums[0], sumsqs[0] = 8.0, 16.0  # eight samples of 1.0... variance 0.9-ish
    sumsqs[0] = 20.0
    _, var = model.clt_estimate(big_b, small_b, sums, sumsqs)
    assert float(var) >= 0.0
