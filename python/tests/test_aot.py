"""AOT path: lowering produces parseable HLO text with the pinned geometry."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def arts():
    return aot.lower_all()


def test_all_artifacts_lower(arts):
    assert set(arts) == {"join_agg", "bloom_probe", "clt_estimate"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name


def test_join_agg_signature_shapes(arts):
    text = arts["join_agg"]
    # entry params: 4 f32[BATCH] + f32[4]; outputs 3x f32[STRATA]
    assert f"f32[{model.BATCH}]" in text
    assert f"f32[{model.STRATA}]" in text


def test_bloom_probe_signature_shapes(arts):
    text = arts["bloom_probe"]
    assert f"u32[{model.NWORDS}]" in text
    assert f"u32[{model.BATCH}]" in text
    assert f"s32[{model.BATCH}]" in text


def test_manifest_written(tmp_path, monkeypatch):
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", str(tmp_path)])
    aot.main()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["geometry"]["batch"] == model.BATCH
    assert man["geometry"]["strata"] == model.STRATA
    assert man["geometry"]["log2_bits"] == model.LOG2_BITS
    for name, meta in man["artifacts"].items():
        p = tmp_path / meta["file"]
        assert p.exists(), name
        assert p.stat().st_size == meta["bytes"]


def test_geometry_constants_are_consistent():
    assert model.NWORDS * 32 == (1 << model.LOG2_BITS)
    assert model.BATCH % 512 == 0  # seg_agg default block
    assert model.BATCH % 1024 == 0  # bloom_probe default block
