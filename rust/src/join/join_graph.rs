//! Join graph over the FROM-clause relations.
//!
//! The parser collects the AND-ed equi-join chains (`a.k = b.k = c.k AND
//! c.k = d.k`) as `Vec<Vec<String>>`. This module is the **single source
//! of truth** for chain connectivity — the parser's legality check and the
//! join-order optimizer's adjacency structure both call into it, so the
//! two can never disagree about which multi-way queries are well-formed
//! (previously the fixpoint absorption lived inline in `query/parser.rs`
//! and any second consumer would have had to duplicate it).
//!
//! Two views:
//!
//! * [`connected_component`] — fixpoint absorption of chains into one
//!   connected table set; `Err` carries the first stray chain exactly as
//!   the parser reports it. Case-insensitive, clause-order independent.
//! * [`JoinGraph`] — adjacency over FROM *positions* (not names), so
//!   self-joins via duplicate FROM entries (`FROM a, a`) get distinct
//!   vertices that the optimizer can still permute.

/// Absorb equi-join chains into one connected component of table names.
///
/// Returns the distinct tables covered (first-appearance order,
/// case-insensitive dedup). `Err(msg)` reproduces the parser's exact
/// disconnected-chains message for the first chain that shares no table
/// with the component built so far — the result is clause-order
/// independent because absorption runs to a fixpoint before failing.
/// Empty input yields an empty component (no chains, nothing to check).
pub fn connected_component(chains: &[Vec<String>]) -> Result<Vec<String>, String> {
    let mut component: Vec<String> = Vec::new();
    let mut remaining: Vec<&Vec<String>> = chains.iter().collect();
    if !remaining.is_empty() {
        for t in remaining.remove(0) {
            if !component.iter().any(|x| x.eq_ignore_ascii_case(t)) {
                component.push(t.clone());
            }
        }
    }
    loop {
        let before = remaining.len();
        remaining.retain(|chain| {
            let connected = chain
                .iter()
                .any(|t| component.iter().any(|x| x.eq_ignore_ascii_case(t)));
            if connected {
                for t in chain.iter() {
                    if !component.iter().any(|x| x.eq_ignore_ascii_case(t)) {
                        component.push(t.clone());
                    }
                }
            }
            !connected
        });
        if remaining.is_empty() || remaining.len() == before {
            break;
        }
    }
    if let Some(stray) = remaining.first() {
        return Err(format!(
            "join chains are disconnected: {} does not share a table with \
             the other chain(s)",
            stray.join(" = ")
        ));
    }
    Ok(component)
}

/// Adjacency over the FROM-clause positions of a multi-way equi-join.
///
/// Vertices are FROM positions (0-based), so `FROM a, a` yields two
/// vertices both named `a`. An edge `(i, j)` means a join clause links the
/// two relations directly; the order optimizer only extends a prefix
/// through edges, keeping enumeration cross-product free.
#[derive(Clone, Debug)]
pub struct JoinGraph {
    tables: Vec<String>,
    edges: Vec<(usize, usize)>,
}

impl JoinGraph {
    /// Build the graph from the FROM list and the parsed join chains.
    ///
    /// Each chain `[t0, t1, t2]` contributes edges between consecutive
    /// members (resolved to their *first* FROM position). Duplicate FROM
    /// entries of the same name (self-joins) are additionally chained
    /// together position-by-position, since `a.k = a.k` necessarily links
    /// every copy of `a`. With no chains at all (programmatic legacy
    /// queries), the FROM order is treated as a linear chain — exactly
    /// what the engine executes.
    pub fn build(tables: &[String], clauses: &[Vec<String>]) -> Self {
        let mut edges: Vec<(usize, usize)> = Vec::new();
        let mut add = |a: usize, b: usize, edges: &mut Vec<(usize, usize)>| {
            if a == b {
                return;
            }
            let e = (a.min(b), a.max(b));
            if !edges.contains(&e) {
                edges.push(e);
            }
        };
        let pos_of = |name: &str| {
            tables
                .iter()
                .position(|t| t.eq_ignore_ascii_case(name))
                .unwrap_or(0)
        };
        if clauses.is_empty() {
            for i in 1..tables.len() {
                add(i - 1, i, &mut edges);
            }
        } else {
            for chain in clauses {
                for w in chain.windows(2) {
                    add(pos_of(&w[0]), pos_of(&w[1]), &mut edges);
                }
            }
        }
        // duplicate FROM entries (self-joins) share the join attribute by
        // construction: chain each repeated name's positions together
        for i in 0..tables.len() {
            for j in (i + 1)..tables.len() {
                if tables[i].eq_ignore_ascii_case(&tables[j]) {
                    add(i, j, &mut edges);
                }
            }
        }
        Self {
            tables: tables.to_vec(),
            edges,
        }
    }

    pub fn n(&self) -> usize {
        self.tables.len()
    }

    pub fn tables(&self) -> &[String] {
        &self.tables
    }

    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    pub fn adjacent(&self, i: usize, j: usize) -> bool {
        let e = (i.min(j), i.max(j));
        self.edges.contains(&e)
    }

    /// Whether every vertex is reachable from vertex 0.
    pub fn is_connected(&self) -> bool {
        if self.tables.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.tables.len()];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &(a, b) in &self.edges {
                let o = if a == v {
                    b
                } else if b == v {
                    a
                } else {
                    continue;
                };
                if !seen[o] {
                    seen[o] = true;
                    stack.push(o);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn component_absorbs_out_of_order_chains() {
        // c=d connects only via the later b=c clause — order must not matter
        let chains = vec![t(&["a", "b"]), t(&["c", "d"]), t(&["b", "c"])];
        let comp = connected_component(&chains).unwrap();
        assert_eq!(comp, t(&["a", "b", "c", "d"]));
    }

    #[test]
    fn component_rejects_disconnected() {
        let chains = vec![t(&["a", "b"]), t(&["c", "d"])];
        let err = connected_component(&chains).unwrap_err();
        assert!(err.contains("disconnected"), "{err}");
        assert!(err.contains("c = d"), "{err}");
    }

    #[test]
    fn component_is_case_insensitive_and_dedups() {
        let chains = vec![t(&["A", "b"]), t(&["B", "a", "c"])];
        let comp = connected_component(&chains).unwrap();
        assert_eq!(comp, t(&["A", "b", "c"]));
        assert!(connected_component(&[]).unwrap().is_empty());
    }

    #[test]
    fn graph_edges_follow_chains() {
        let g = JoinGraph::build(&t(&["a", "b", "c", "d"]), &[t(&["a", "b", "c"]), t(&["c", "d"])]);
        assert!(g.adjacent(0, 1));
        assert!(g.adjacent(1, 2));
        assert!(g.adjacent(2, 3));
        assert!(!g.adjacent(0, 3));
        assert!(!g.adjacent(0, 2));
        assert!(g.is_connected());
    }

    #[test]
    fn graph_without_clauses_is_from_order_chain() {
        let g = JoinGraph::build(&t(&["x", "y", "z"]), &[]);
        assert!(g.adjacent(0, 1));
        assert!(g.adjacent(1, 2));
        assert!(!g.adjacent(0, 2));
        assert!(g.is_connected());
    }

    #[test]
    fn self_join_duplicate_from_entries_are_linked() {
        // FROM a, a WHERE a.k = a.k: the chain resolves to position 0 twice,
        // but the duplicate-name rule links the two copies
        let g = JoinGraph::build(&t(&["a", "a"]), &[t(&["a", "a"])]);
        assert_eq!(g.n(), 2);
        assert!(g.adjacent(0, 1));
        assert!(g.is_connected());

        // self-join alongside a third table stays connected through it
        let g = JoinGraph::build(&t(&["a", "a", "b"]), &[t(&["a", "b"])]);
        assert!(g.adjacent(0, 1));
        assert!(g.adjacent(0, 2));
        assert!(g.is_connected());
    }
}
