//! Spark repartition join: tag every record with its source input, shuffle
//! all n inputs **once** by join key, then per key run the n-way cross
//! product in a streamed fashion (no materialized binary intermediates).
//! The paper's strongest exact baseline — ApproxJoin's filtering stage only
//! beats it while the overlap fraction is small (Fig 8/9 crossovers).

use super::{CombineOp, JoinError, JoinRun};
use crate::cluster::shuffle::shuffle_dataset;
use crate::cluster::SimCluster;
use crate::data::Dataset;
use crate::runtime::CogroupColumns;
use crate::stats::StratumAgg;
use std::collections::HashMap;
use std::time::Instant;

/// Repartition join. Infallible in practice (nothing is materialized), but
/// returns `Result` like every other strategy entry point.
pub fn repartition_join(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
) -> Result<JoinRun, JoinError> {
    assert!(inputs.len() >= 2);
    // single tagged shuffle of every input
    let mut s = cluster.stage("shuffle");
    let shuffled: Vec<Vec<Vec<crate::data::Record>>> = inputs
        .iter()
        .map(|d| shuffle_dataset(cluster, &mut s, d))
        .collect();
    s.finish(cluster);

    // per worker: cogroup the n tagged streams into flat columns, stream
    // the cross product over contiguous key runs — data-parallel across
    // workers; every key lives on one worker after the hash shuffle, so
    // the merged map is thread-count independent
    let mut s = cluster.stage("crossproduct");
    let per_worker = cluster.exec.map(cluster.k, |w| {
        let per_input: Vec<&[crate::data::Record]> =
            shuffled.iter().map(|inp| inp[w].as_slice()).collect();
        let t0 = Instant::now();
        let cg = CogroupColumns::from_slices(&per_input);
        let mut local: HashMap<u64, StratumAgg> = HashMap::with_capacity(cg.num_keys());
        let mut pairs = 0u64;
        let mut sides: Vec<&[f64]> = Vec::with_capacity(cg.n_inputs());
        for idx in 0..cg.num_keys() {
            cg.sides_into(idx, &mut sides);
            let agg = super::cross_product_agg(&sides, op);
            pairs += agg.population as u64;
            local.insert(cg.key(idx), agg);
        }
        (local, pairs, t0.elapsed().as_secs_f64())
    });
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    for (w, (local, pairs, secs)) in per_worker.into_iter().enumerate() {
        strata.extend(local);
        s.add_compute(w, secs);
        s.add_items(pairs);
    }
    s.finish(cluster);

    let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
    crate::faults::finalize_run(JoinRun::exact(strata, metrics).with_ledger(ledger), cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;
    use crate::join::native::native_join;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn ds(name: &str, recs: Vec<(u64, f64)>) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
            4,
            100,
        )
    }

    #[test]
    fn matches_native_join_result() {
        let a = ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]);
        let b = ds("b", vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]);
        let rep = repartition_join(&mut cluster(), &[a.clone(), b.clone()], CombineOp::Sum)
            .unwrap();
        let nat = native_join(&mut cluster(), &[a, b], CombineOp::Sum, u64::MAX).unwrap();
        assert!((rep.exact_sum() - nat.exact_sum()).abs() < 1e-9);
        assert_eq!(rep.output_cardinality(), nat.output_cardinality());
    }

    #[test]
    fn three_way_single_shuffle() {
        let a = ds("a", vec![(1, 1.0), (2, 2.0)]);
        let b = ds("b", vec![(1, 10.0), (1, 20.0), (2, 30.0)]);
        let c3 = ds("c", vec![(1, 100.0), (3, 0.0)]);
        let mut c = cluster();
        let run = repartition_join(&mut c, &[a, b, c3], CombineOp::Sum).unwrap();
        assert!((run.exact_sum() - 232.0).abs() < 1e-9);
        // exactly one shuffle stage + one crossproduct stage
        assert_eq!(run.metrics.stages.len(), 2);
    }

    #[test]
    fn shuffles_less_than_native_on_multiway() {
        // native pays for intermediates; repartition does not
        let a = ds("a", (0..300).map(|i| (i % 30, 1.0)).collect());
        let b = ds("b", (0..300).map(|i| (i % 30, 1.0)).collect());
        let c3 = ds("c", (0..300).map(|i| (i % 30, 1.0)).collect());
        let rep = repartition_join(
            &mut cluster(),
            &[a.clone(), b.clone(), c3.clone()],
            CombineOp::Sum,
        )
        .unwrap();
        let nat = native_join(&mut cluster(), &[a, b, c3], CombineOp::Sum, u64::MAX).unwrap();
        assert!((rep.exact_sum() - nat.exact_sum()).abs() < 1e-6);
        assert!(
            rep.metrics.total_shuffled_bytes() <= nat.metrics.total_shuffled_bytes(),
            "rep {} vs nat {}",
            rep.metrics.total_shuffled_bytes(),
            nat.metrics.total_shuffled_bytes()
        );
    }
}
