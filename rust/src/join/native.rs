//! Native Spark RDD join: `a.join(b)` chained left-to-right for n-way.
//!
//! Characteristics the paper measures: every input is fully shuffled
//! (cogroup), *and* every binary intermediate result is materialized —
//! its size is Π of the participating multiplicities so far, which is why
//! native join runs out of memory at 8-10% overlap in three-way joins
//! (Fig 9a's missing bars). The memory guard reproduces that failure mode.

use super::{CombineOp, JoinError, JoinRun};
use crate::cluster::shuffle::shuffle_dataset;
use crate::cluster::SimCluster;
use crate::data::{Dataset, Record};
use crate::runtime::CogroupColumns;
use crate::stats::StratumAgg;
use std::collections::HashMap;
use std::time::Instant;

/// Per-worker memory budget for materialized intermediates (bytes).
/// Default mirrors the paper's 8 GB nodes with ~4 GB usable for the join.
pub const DEFAULT_MEMORY_BUDGET: u64 = 4 << 30;

/// Chained-binary native join of `inputs` with full cross products.
pub fn native_join(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    memory_budget: u64,
) -> Result<JoinRun, JoinError> {
    assert!(inputs.len() >= 2);
    const PAIR_BYTES: u64 = 24; // (key, combined value, partition overhead)

    // left = materialized intermediate: records of (key, combined-prefix)
    let mut left = inputs[0].clone();
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();

    for (step, right) in inputs[1..].iter().enumerate() {
        let last = step + 2 == inputs.len();
        // cogroup: shuffle both sides fully
        let mut s = cluster.stage(&format!("shuffle_{step}"));
        let left_parts = shuffle_dataset(cluster, &mut s, &left);
        let right_parts = shuffle_dataset(cluster, &mut s, right);
        s.finish(cluster);

        let mut s = cluster.stage(&format!("crossproduct_{step}"));
        // per-worker cogroup + cross product, data-parallel across workers;
        // each worker returns (final aggregates, materialized intermediate)
        // or its OOM error
        type StepOut = (HashMap<u64, StratumAgg>, Vec<Record>, u64, f64);
        let per_worker: Vec<Result<StepOut, JoinError>> = cluster.exec.map(cluster.k, |w| {
            // flat columnar cogroup: the joinable directory is ascending
            // by key, so the materialized intermediate (whose record order
            // feeds the next step's f64 sums) stays deterministic — the
            // same order the old sorted hash-map walk produced
            let cg = CogroupColumns::from_slices(&[
                left_parts[w].as_slice(),
                right_parts[w].as_slice(),
            ]);
            let t0 = Instant::now();
            let mut local: HashMap<u64, StratumAgg> = HashMap::new();
            let mut materialized: Vec<Record> = Vec::new();
            let mut pairs = 0u64;
            for idx in 0..cg.num_keys() {
                let key = cg.key(idx);
                let (lvals, rvals) = (cg.side(idx, 0), cg.side(idx, 1));
                if last {
                    // final step: stream into aggregates. After the hash
                    // shuffle each key lives on exactly one worker, so a
                    // plain insert is safe.
                    let agg = super::cross_product_agg(&[lvals, rvals], op);
                    pairs += agg.population as u64;
                    local.insert(key, agg);
                } else {
                    // materialize the intermediate — the native-join sin
                    for &lv in lvals {
                        for &rv in rvals {
                            materialized.push(Record::new(key, op.fold(lv, rv)));
                            pairs += 1;
                        }
                    }
                    let bytes = materialized.len() as u64 * PAIR_BYTES;
                    if bytes > memory_budget {
                        return Err(JoinError::OutOfMemory {
                            stage: format!("crossproduct_{step}"),
                            bytes,
                        });
                    }
                }
            }
            Ok((local, materialized, pairs, t0.elapsed().as_secs_f64()))
        });
        let mut next: Vec<Vec<Record>> = Vec::with_capacity(cluster.k);
        for (w, r) in per_worker.into_iter().enumerate() {
            let (local, materialized, pairs, secs) = r?;
            strata.extend(local);
            next.push(materialized);
            s.add_compute(w, secs);
            s.add_items(pairs);
        }
        s.finish(cluster);

        if !last {
            // intermediate is already key-partitioned; wrap it as a dataset
            let mut d = Dataset {
                name: format!("intermediate_{step}"),
                partitions: next,
                record_bytes: PAIR_BYTES,
            };
            // cross-product aggregation per stratum needs exact population
            // which accumulates at the final step; intermediates carry on
            std::mem::swap(&mut left, &mut d);
        }
    }

    let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
    crate::faults::finalize_run(JoinRun::exact(strata, metrics).with_ledger(ledger), cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn ds(name: &str, recs: Vec<(u64, f64)>) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
            4,
            100,
        )
    }

    #[test]
    fn two_way_exact_sum() {
        let a = ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]);
        let b = ds("b", vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]);
        let mut c = cluster();
        let run = native_join(&mut c, &[a, b], CombineOp::Sum, u64::MAX).unwrap();
        // key 1: (1+100)+(2+100) = 203; key 2: (10+200)+(10+300) = 520
        assert!((run.exact_sum() - 723.0).abs() < 1e-9);
        assert_eq!(run.output_cardinality(), 4.0);
        assert!(!run.sampled);
    }

    #[test]
    fn three_way_chained() {
        let a = ds("a", vec![(1, 1.0), (2, 2.0)]);
        let b = ds("b", vec![(1, 10.0), (1, 20.0), (2, 30.0)]);
        let c3 = ds("c", vec![(1, 100.0), (3, 0.0)]);
        let mut c = cluster();
        let run = native_join(&mut c, &[a, b, c3], CombineOp::Sum, u64::MAX).unwrap();
        // key 1: (1+10+100) + (1+20+100) = 232; key 2 drops (no c)
        assert!((run.exact_sum() - 232.0).abs() < 1e-9);
        assert_eq!(run.output_cardinality(), 2.0);
    }

    #[test]
    fn shuffles_everything() {
        let a = ds("a", (0..1000).map(|k| (k, 1.0)).collect());
        let b = ds("b", (500..1500).map(|k| (k, 1.0)).collect());
        let mut c = cluster();
        let run = native_join(&mut c, &[a, b], CombineOp::Sum, u64::MAX).unwrap();
        // ~3/4 of 2000 records move at 100B each
        let bytes = run.metrics.total_shuffled_bytes();
        assert!(bytes > 120_000, "bytes {bytes}");
    }

    #[test]
    fn oom_on_huge_intermediate() {
        // 200x200 = 40k intermediate pairs per key chain -> tiny budget trips
        let a = ds("a", (0..200).map(|_| (1, 1.0)).collect());
        let b = ds("b", (0..200).map(|_| (1, 1.0)).collect());
        let c3 = ds("c", vec![(1, 1.0)]);
        let mut c = cluster();
        let err = native_join(&mut c, &[a, b, c3], CombineOp::Sum, 1000).unwrap_err();
        match err {
            JoinError::OutOfMemory { bytes, .. } => assert!(bytes > 1000),
            other => panic!("expected OutOfMemory, got {other}"),
        }
    }

    #[test]
    fn disjoint_inputs_empty_output() {
        let a = ds("a", vec![(1, 1.0)]);
        let b = ds("b", vec![(2, 1.0)]);
        let mut c = cluster();
        let run = native_join(&mut c, &[a, b], CombineOp::Sum, u64::MAX).unwrap();
        assert_eq!(run.exact_sum(), 0.0);
        assert_eq!(run.output_cardinality(), 0.0);
    }
}
