//! Cost-based join planning: rank the registered strategies on cheap input
//! statistics and produce an inspectable [`JoinPlan`] — chosen strategy,
//! predicted shuffle bytes and latency per candidate, and an `explain()`
//! rendering in the spirit of SQL EXPLAIN.

use super::strategy::{CostEstimate, InputStats, StrategyRegistry};
use super::JoinError;
use crate::cost::CostModel;
use crate::query::Budget;
use crate::util::fmt;
use std::fmt::Write as _;

/// How the caller wants the strategy picked.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StrategyChoice {
    /// Let the planner rank registered strategies by predicted cost.
    Auto,
    /// Force a specific registered strategy by name.
    Named(String),
}

impl StrategyChoice {
    pub fn named(name: impl Into<String>) -> Self {
        StrategyChoice::Named(name.into())
    }
}

/// The planner's decision: which strategy runs, why, and what every
/// candidate was predicted to cost.
#[derive(Clone, Debug)]
pub struct JoinPlan {
    /// Registry name of the chosen strategy.
    pub strategy: String,
    /// Whether the chosen strategy samples (result is an estimate).
    pub approximate: bool,
    /// The statistics the ranking was computed from.
    pub stats: InputStats,
    /// Every candidate's estimate, best first (infeasible last).
    pub estimates: Vec<CostEstimate>,
    /// Stage names the chosen strategy will record.
    pub stages: Vec<String>,
    /// Bytes the shuffle fabric actually counted, once the plan has been
    /// executed (from the run's [`crate::cluster::ShuffleLedger`]); `None`
    /// before execution. `explain()` prints it next to the prediction.
    pub measured_shuffle_bytes: Option<u64>,
    /// The join filter the executed run built — kind (standard/blocked),
    /// geometry and the measured-fill false-positive rate; `None` before
    /// execution or for strategies that do not filter. `explain()`
    /// renders it.
    pub filter: Option<crate::bloom::FilterReport>,
    /// The relational lowering behind this plan (pushed-down predicates,
    /// kernel projections, GROUP BY composite strata), when the query
    /// came through the relational front end. `explain()` renders it.
    pub lowering: Option<crate::relation::LoweringInfo>,
    /// The join-order optimizer's decision (chosen order, DP vs greedy,
    /// per-step predicted vs measured cardinality); `None` when ordering
    /// was skipped (two-way join, disabled, or a non-commutative combine
    /// op). `explain()` renders it.
    pub order: Option<super::order::JoinOrderReport>,
}

impl JoinPlan {
    /// The chosen strategy's estimate.
    pub fn chosen(&self) -> &CostEstimate {
        self.estimates
            .iter()
            .find(|e| e.strategy == self.strategy)
            .expect("chosen strategy always has an estimate")
    }

    /// Predicted bytes the chosen strategy shuffles.
    pub fn predicted_shuffle_bytes(&self) -> f64 {
        self.chosen().shuffle_bytes
    }

    /// Predicted latency (simulated seconds) of the chosen strategy.
    pub fn predicted_secs(&self) -> f64 {
        self.chosen().est_secs
    }

    /// Attach the measured shuffled bytes of the executed run, so
    /// `explain()` reports measurement next to prediction.
    pub fn with_measured_shuffle(mut self, bytes: u64) -> Self {
        self.measured_shuffle_bytes = Some(bytes);
        self
    }

    /// Attach the relational lowering this plan executes (pushed-down
    /// predicates + the lowered kernel plan), for `explain()`.
    pub fn with_lowering(mut self, lowering: crate::relation::LoweringInfo) -> Self {
        self.lowering = Some(lowering);
        self
    }

    /// Attach the join-order optimizer's report (or `None` when ordering
    /// was skipped), for `explain()` and `QueryOutcome::join_order`.
    pub fn with_order(mut self, order: Option<super::order::JoinOrderReport>) -> Self {
        self.order = order;
        self
    }

    /// Attach the executed run's join-filter report (kind + measured fp),
    /// when the run built one, for `explain()`.
    pub fn with_filter_report(
        mut self,
        report: Option<crate::bloom::FilterReport>,
    ) -> Self {
        self.filter = report;
        self
    }

    /// Human-readable plan: inputs, overlap, stages, and the cost ranking.
    pub fn explain(&self) -> String {
        let mut out = String::new();
        let kind = if self.approximate {
            "approximate"
        } else {
            "exact"
        };
        let _ = writeln!(out, "JoinPlan: strategy={} ({kind})", self.strategy);
        let _ = writeln!(
            out,
            "  inputs: {} datasets, rows={:?}, workers={}",
            self.stats.n_inputs(),
            self.stats.rows,
            self.stats.workers
        );
        let _ = writeln!(
            out,
            "  overlap: {} ({} common keys, {} predicted output pairs)",
            fmt::pct(self.stats.overlap_fraction),
            fmt::count(self.stats.common_keys),
            fmt::count(self.stats.est_output_pairs as u64)
        );
        let _ = writeln!(out, "  stages: {}", self.stages.join(" -> "));
        if let Some(lowering) = &self.lowering {
            out.push_str(&lowering.render());
        }
        if let Some(order) = &self.order {
            for line in order.render() {
                let _ = writeln!(out, "  {line}");
            }
        }
        if let Some(report) = &self.filter {
            let _ = writeln!(out, "  filter: {}", report.render());
        }
        match self.measured_shuffle_bytes {
            Some(measured) => {
                let _ = writeln!(
                    out,
                    "  shuffle: predicted {} -> measured {} (ledger)",
                    fmt::bytes(self.predicted_shuffle_bytes() as u64),
                    fmt::bytes(measured)
                );
            }
            None => {
                let _ = writeln!(
                    out,
                    "  shuffle: predicted {} (not executed yet)",
                    fmt::bytes(self.predicted_shuffle_bytes() as u64)
                );
            }
        }
        let _ = writeln!(out, "  cost ranking (best first):");
        for (i, e) in self.estimates.iter().enumerate() {
            let marker = if e.strategy == self.strategy {
                "  <- chosen"
            } else {
                ""
            };
            if e.feasible {
                let _ = writeln!(
                    out,
                    "    {}. {:<12} est {:>10}  shuffle {:>10}  pairs {:>10}{marker}",
                    i + 1,
                    e.strategy,
                    fmt::duration(e.est_secs),
                    fmt::bytes(e.shuffle_bytes as u64),
                    fmt::count(e.compute_pairs as u64)
                );
            } else {
                let _ = writeln!(
                    out,
                    "    {}. {:<12} infeasible: {}{marker}",
                    i + 1,
                    e.strategy,
                    e.note
                );
            }
        }
        out
    }
}

/// Ranks registered strategies with the cost model and picks one.
pub struct Planner<'a> {
    registry: &'a StrategyRegistry,
    cost: &'a CostModel,
}

impl<'a> Planner<'a> {
    pub fn new(registry: &'a StrategyRegistry, cost: &'a CostModel) -> Self {
        Self { registry, cost }
    }

    /// Whether the query's budget forces the sampled path: an error budget
    /// always does; a latency budget does when the predicted filtering +
    /// shuffle time d_dt leaves less time than the exact cross product
    /// needs (eq 6). The d_dt prediction is conservative (a full-shuffle
    /// bound) on purpose: over-predicting routes borderline queries to the
    /// engine, whose §3.2 planner re-decides with the *measured* d_dt and
    /// still runs exact when the budget turns out loose — whereas an
    /// under-prediction would lock in an exact plan that misses the budget.
    pub fn budget_requires_sampling(&self, budget: &Budget, stats: &InputStats) -> bool {
        if budget.error.is_some() {
            return true;
        }
        if let Some(desired) = budget.latency_secs {
            let est_d_dt =
                stats.net_secs(stats.full_shuffle_bytes()) + 2.0 * stats.stage_latency;
            return self
                .cost
                .fraction_for_latency(desired, est_d_dt, stats.est_output_pairs)
                < 1.0;
        }
        false
    }

    /// Produce a [`JoinPlan`] for inputs described by `stats` under the
    /// query's `budget`. `Named` choices must be registered and feasible;
    /// `Auto` picks the approximate strategy when the budget requires
    /// sampling and otherwise the cheapest feasible exact strategy.
    pub fn plan(
        &self,
        stats: &InputStats,
        choice: &StrategyChoice,
        budget: &Budget,
    ) -> Result<JoinPlan, JoinError> {
        let mut estimates: Vec<CostEstimate> = Vec::with_capacity(self.registry.len());
        for s in self.registry.iter() {
            let mut e = s.estimate_cost(stats, self.cost);
            e.strategy = s.name().to_string();
            e.approximate = s.is_approximate();
            e.baseline = s.is_baseline();
            estimates.push(e);
        }
        // feasible first, then by predicted latency; the sort is stable so
        // registry order breaks exact ties
        estimates.sort_by(|a, b| {
            b.feasible.cmp(&a.feasible).then(
                a.est_secs
                    .partial_cmp(&b.est_secs)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
        });

        let chosen = match choice {
            StrategyChoice::Named(name) => {
                let Some(est) = estimates.iter().find(|e| &e.strategy == name) else {
                    return Err(JoinError::Unsupported {
                        strategy: name.clone(),
                        reason: format!(
                            "not registered (available: {})",
                            self.registry.names().join(", ")
                        ),
                    });
                };
                if !est.feasible {
                    return Err(JoinError::Unsupported {
                        strategy: name.clone(),
                        reason: est.note.clone(),
                    });
                }
                name.clone()
            }
            StrategyChoice::Auto => {
                if estimates.is_empty() {
                    return Err(JoinError::Unsupported {
                        strategy: "auto".to_string(),
                        reason: "no strategies registered".to_string(),
                    });
                }
                if self.budget_requires_sampling(budget, stats) {
                    // baselines never win Auto: they exist for comparison,
                    // and centralizing a sample is not the paper's plan
                    match estimates
                        .iter()
                        .find(|e| e.approximate && e.feasible && !e.baseline)
                    {
                        Some(e) => e.strategy.clone(),
                        None => {
                            return Err(JoinError::Unsupported {
                                strategy: "auto".to_string(),
                                reason: "query budget requires sampling but no approximate \
                                         strategy is registered"
                                    .to_string(),
                            })
                        }
                    }
                } else {
                    match estimates
                        .iter()
                        .find(|e| e.feasible && !e.approximate && !e.baseline)
                    {
                        Some(e) => e.strategy.clone(),
                        None => {
                            return Err(JoinError::Unsupported {
                                strategy: "auto".to_string(),
                                reason: "no feasible exact strategy for these inputs".to_string(),
                            })
                        }
                    }
                }
            }
        };

        let approximate = estimates
            .iter()
            .find(|e| e.strategy == chosen)
            .map(|e| e.approximate)
            .unwrap_or(false);
        let stages = self
            .registry
            .get(&chosen)
            .map(|s| s.stage_names(stats.n_inputs()))
            .unwrap_or_default();
        Ok(JoinPlan {
            strategy: chosen,
            approximate,
            stats: stats.clone(),
            estimates,
            stages,
            measured_shuffle_bytes: None,
            filter: None,
            lowering: None,
            order: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::{generate_overlapping, SyntheticSpec};
    use crate::query::ErrorBudget;

    /// A network-bound cluster model so shuffle volume dominates the
    /// ranking, as on the paper's GbE testbed.
    fn slow_net() -> TimeModel {
        TimeModel {
            bandwidth: 1e6,
            stage_latency: 0.0,
            compute_scale: 1.0,
        }
    }

    fn stats_for(overlap: f64) -> InputStats {
        let inputs = generate_overlapping(&SyntheticSpec {
            items_per_input: 20_000,
            overlap_fraction: overlap,
            lambda: 20.0,
            partitions: 4,
            seed: 12,
            ..Default::default()
        });
        InputStats::collect(&inputs, 4, &slow_net())
    }

    fn plan(
        stats: &InputStats,
        choice: StrategyChoice,
        budget: Budget,
    ) -> Result<JoinPlan, JoinError> {
        let registry = StrategyRegistry::with_defaults();
        let cost = CostModel::default();
        Planner::new(&registry, &cost).plan(stats, &choice, &budget)
    }

    #[test]
    fn auto_picks_bloom_at_low_overlap() {
        let p = plan(&stats_for(0.01), StrategyChoice::Auto, Budget::unbounded()).unwrap();
        assert_eq!(p.strategy, "bloom", "\n{}", p.explain());
        assert!(!p.approximate);
    }

    #[test]
    fn auto_picks_repartition_at_full_overlap() {
        // at 100% overlap the filter drops nothing: bloom pays the filter
        // traffic and the probes on top of the same record shuffle
        let p = plan(&stats_for(1.0), StrategyChoice::Auto, Budget::unbounded()).unwrap();
        assert_eq!(p.strategy, "repartition", "\n{}", p.explain());
    }

    #[test]
    fn chosen_matches_lowest_feasible_estimate() {
        for overlap in [0.01, 0.3, 1.0] {
            let p = plan(&stats_for(overlap), StrategyChoice::Auto, Budget::unbounded()).unwrap();
            let best = p
                .estimates
                .iter()
                .filter(|e| e.feasible && !e.approximate)
                .min_by(|a, b| a.est_secs.partial_cmp(&b.est_secs).unwrap())
                .unwrap();
            assert_eq!(p.strategy, best.strategy, "overlap {overlap}");
            // estimates are sorted cheapest-first
            for pair in p.estimates.windows(2) {
                if pair[0].feasible && pair[1].feasible {
                    assert!(pair[0].est_secs <= pair[1].est_secs, "overlap {overlap}");
                }
            }
        }
    }

    #[test]
    fn error_budget_forces_approx() {
        let budget = Budget {
            latency_secs: None,
            error: Some(ErrorBudget {
                bound: 0.1,
                confidence: 0.95,
            }),
        };
        let p = plan(&stats_for(0.2), StrategyChoice::Auto, budget).unwrap();
        assert_eq!(p.strategy, "approx");
        assert!(p.approximate);
    }

    #[test]
    fn tight_latency_budget_forces_approx_loose_does_not() {
        let stats = stats_for(0.3);
        let tight = Budget {
            latency_secs: Some(1e-9),
            error: None,
        };
        let loose = Budget {
            latency_secs: Some(1e9),
            error: None,
        };
        assert_eq!(
            plan(&stats, StrategyChoice::Auto, tight).unwrap().strategy,
            "approx"
        );
        assert!(!plan(&stats, StrategyChoice::Auto, loose).unwrap().approximate);
    }

    #[test]
    fn named_strategy_is_honored() {
        let p = plan(
            &stats_for(0.01),
            StrategyChoice::named("native"),
            Budget::unbounded(),
        )
        .unwrap();
        assert_eq!(p.strategy, "native");
        assert_eq!(p.stages, vec!["shuffle_0", "crossproduct_0"]);
    }

    #[test]
    fn unknown_named_strategy_is_unsupported() {
        let err = plan(
            &stats_for(0.01),
            StrategyChoice::named("hash"),
            Budget::unbounded(),
        )
        .unwrap_err();
        match err {
            JoinError::Unsupported { strategy, reason } => {
                assert_eq!(strategy, "hash");
                assert!(reason.contains("not registered"), "{reason}");
            }
            other => panic!("expected Unsupported, got {other}"),
        }
    }

    #[test]
    fn explain_lists_every_strategy() {
        let p = plan(&stats_for(0.05), StrategyChoice::Auto, Budget::unbounded()).unwrap();
        let text = p.explain();
        for name in [
            "bloom",
            "repartition",
            "broadcast",
            "native",
            "approx",
            "bernoulli",
            "universe",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        assert!(text.contains("<- chosen"));
        assert!(text.contains("stages:"));
    }

    #[test]
    fn baselines_never_win_auto_but_answer_by_name() {
        let stats = stats_for(0.2);
        // error budget forces sampling; the distributed approx strategy
        // must win even if a baseline predicts cheaper
        let budget = Budget {
            latency_secs: None,
            error: Some(ErrorBudget {
                bound: 0.1,
                confidence: 0.95,
            }),
        };
        let p = plan(&stats, StrategyChoice::Auto, budget).unwrap();
        assert_eq!(p.strategy, "approx");
        for name in ["bernoulli", "universe"] {
            let p = plan(&stats, StrategyChoice::named(name), Budget::unbounded()).unwrap();
            assert_eq!(p.strategy, name);
            assert!(p.approximate);
            assert!(p.chosen().baseline);
            assert_eq!(p.stages, vec!["sample_inputs", "centralized_join"]);
        }
    }

    #[test]
    fn explain_reports_measured_next_to_predicted() {
        let p = plan(&stats_for(0.05), StrategyChoice::Auto, Budget::unbounded()).unwrap();
        assert!(p.explain().contains("not executed yet"));
        let executed = p.with_measured_shuffle(123_456);
        let text = executed.explain();
        assert!(
            text.contains("predicted") && text.contains("measured"),
            "{text}"
        );
        assert!(!text.contains("not executed yet"), "{text}");
    }
}
