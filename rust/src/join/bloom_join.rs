//! ApproxJoin stage 1 (paper §3.1, Algorithm 1): multi-way Bloom-filter
//! construction, redundant-item filtering, and the filtered shuffle —
//! shared by the exact Bloom join (filtering only, §5.2) and the full
//! approximate join (§5.3).
//!
//! Steps: (1) per input, build partition filters at the workers and
//! OR-merge them via treeReduce into a *dataset filter*; (2) AND the n
//! dataset filters into the *join filter* at the master; (3) broadcast the
//! join filter; (4) drop every local record whose key misses the filter;
//! (5) shuffle only the survivors and cogroup by key.
//!
//! Hot-path layout: filters are kind-dispatched ([`JoinFilter`]) — the
//! default standard layout the AOT prober understands, or the opt-in
//! cache-line-blocked layout (one memory access per probe). Keys are
//! folded to the u32 hash domain **once per run** into flat per-partition
//! buffers, and the shuffled survivors cogroup into flat columnar
//! [`CogroupColumns`] (sorted `(key64, f64)` columns + run-span
//! directories) instead of per-key hash-map allocations.
//!
//! Filter construction (per-worker Bloom shards), probing, cogrouping and
//! the cross product all run data-parallel through the cluster's
//! [`crate::runtime::ParallelExecutor`], bit-identical to the sequential
//! path.

use super::{CombineOp, JoinError, JoinRun, JoinVariant};
use crate::bloom::hashing::fold_key;
use crate::bloom::{BloomFilter, FilterKind, JoinFilter};
use crate::cluster::tree_reduce::build_dataset_join_filter;
use crate::cluster::SimCluster;
use crate::data::Dataset;
use crate::runtime::CogroupColumns;
use crate::stats::StratumAgg;
use std::collections::{HashMap, HashSet};
use std::time::Instant;

/// Bloom geometry + kind for the join filter. The default (2^20 bits, 5
/// hashes, standard layout) matches the AOT `bloom_probe` artifact so the
/// XLA path can probe it.
#[derive(Clone, Copy, Debug)]
pub struct FilterConfig {
    pub log2_bits: u32,
    pub num_hashes: u32,
    /// Bit layout — [`FilterKind::Blocked`] opts into the one-cache-line
    /// probe path (native probing only; the XLA artifact stays standard).
    pub kind: FilterKind,
}

impl Default for FilterConfig {
    fn default() -> Self {
        Self {
            log2_bits: 20,
            num_hashes: 5,
            kind: FilterKind::Standard,
        }
    }
}

impl FilterConfig {
    /// Geometry from the largest input size + target fp rate (eq 27, with
    /// N = |R_n| as §A.1 prescribes), bits rounded up to a power of two.
    pub fn for_inputs(inputs: &[Dataset], fp_rate: f64) -> Self {
        Self::for_inputs_kind(inputs, fp_rate, FilterKind::Standard)
    }

    /// [`FilterConfig::for_inputs`] for an explicit filter kind (blocked
    /// geometries floor at one 512-bit block). Pure arithmetic — the same
    /// eq-27 sizing as [`BloomFilter::with_capacity`], without allocating
    /// a filter to read its geometry back.
    pub fn for_inputs_kind(inputs: &[Dataset], fp_rate: f64, kind: FilterKind) -> Self {
        let n_max = inputs.iter().map(|d| d.len()).max().unwrap_or(1).max(1);
        let (log2_bits, num_hashes) =
            crate::bloom::hashing::pow2_geometry(n_max, fp_rate, kind.min_log2().max(6), 30);
        Self {
            log2_bits,
            num_hashes,
            kind,
        }
    }

    /// A kind-only config: `log2_bits == 0` is the "size from the inputs
    /// at execute time" sentinel the engine-level filter-kind switch uses
    /// (a registry strategy knows its kind before it sees any data).
    pub fn auto_sized(kind: FilterKind) -> Self {
        Self {
            log2_bits: 0,
            num_hashes: 0,
            kind,
        }
    }

    pub fn is_auto_sized(&self) -> bool {
        self.log2_bits == 0
    }

    /// Resolve an auto-sized config against concrete inputs; explicit
    /// geometries pass through unchanged.
    pub fn resolved(self, inputs: &[Dataset], fp_rate: f64) -> Self {
        if self.is_auto_sized() {
            Self::for_inputs_kind(inputs, fp_rate, self.kind)
        } else {
            self
        }
    }
}

/// Batched membership probing — implemented natively and by the runtime's
/// AOT `bloom_probe` executor (runtime/batch.rs). Probers consume the
/// standard filter layout; blocked filters are probed natively by the
/// kernel itself (one cache line per key needs no batching help).
pub trait KeyProber {
    /// For each folded key, whether it may be in the filter.
    fn probe(&mut self, filter: &BloomFilter, keys: &[u32]) -> anyhow::Result<Vec<bool>>;

    /// An independent prober for a parallel worker, when probing is safe to
    /// run concurrently. `None` (the default) keeps probing sequential —
    /// the XLA executor owns mutable device buffers and stays on this path.
    fn fork(&self) -> Option<Box<dyn KeyProber + Send>> {
        None
    }
}

/// Pure-Rust prober (the default).
pub struct NativeProber;

impl KeyProber for NativeProber {
    fn probe(&mut self, filter: &BloomFilter, keys: &[u32]) -> anyhow::Result<Vec<bool>> {
        Ok(keys.iter().map(|&k| filter.contains(k)).collect())
    }

    fn fork(&self) -> Option<Box<dyn KeyProber + Send>> {
        Some(Box::new(NativeProber))
    }
}

/// Probe every partition's pre-folded keys against the join filter,
/// returning (mask, measured seconds) per partition. Standard filters go
/// through the [`KeyProber`] (forkable probers run data-parallel; the XLA
/// prober stays sequential); blocked filters always probe natively and
/// data-parallel — the probe is a pure one-cache-line lookup. Both paths
/// produce identical masks for the same filter.
fn probe_partitions(
    cluster: &SimCluster,
    folded: &[Vec<u32>],
    join_filter: &JoinFilter,
    prober: &mut dyn KeyProber,
) -> anyhow::Result<Vec<(Vec<bool>, f64)>> {
    let n_parts = folded.len();
    match join_filter {
        JoinFilter::Blocked(f) => Ok(cluster.exec.map(n_parts, |j| {
            let t0 = Instant::now();
            let mask: Vec<bool> = folded[j].iter().map(|&k| f.contains(k)).collect();
            (mask, t0.elapsed().as_secs_f64())
        })),
        JoinFilter::Standard(f) => {
            if !cluster.exec.is_sequential() {
                // one independent prober per partition, each moved into its
                // thread stripe by map_with (no locks)
                let forks: Option<Vec<Box<dyn KeyProber + Send>>> =
                    (0..n_parts).map(|_| prober.fork()).collect();
                if let Some(forks) = forks {
                    let results = cluster.exec.map_with(forks, |j, local| {
                        let t0 = Instant::now();
                        let mask = local.probe(f, &folded[j]);
                        (mask, t0.elapsed().as_secs_f64())
                    });
                    return results
                        .into_iter()
                        .map(|(mask, secs)| Ok((mask?, secs)))
                        .collect();
                }
            }
            let mut out = Vec::with_capacity(n_parts);
            for keys in folded {
                let t0 = Instant::now();
                let mask = prober.probe(f, keys)?;
                out.push((mask, t0.elapsed().as_secs_f64()));
            }
            Ok(out)
        }
    }
}

/// Output of the filtering stage.
pub struct Filtered {
    /// Per-worker cogrouped survivors in flat columnar form: sorted
    /// `(key64, f64)` columns with a joinable-key run directory.
    pub per_worker: Vec<CogroupColumns>,
    /// Simulated seconds spent in filtering + shuffling (the cost
    /// function's d_dt, eq 1).
    pub d_dt: f64,
    /// The join filter (for cardinality estimates and fp reporting).
    pub join_filter: JoinFilter,
    /// Survivor counts per input (diagnostics; Fig 4b-style reporting).
    pub survivors: Vec<u64>,
}

impl Filtered {
    /// Σ B_i over every worker's joinable strata — the exact join-output
    /// cardinality, summed in (worker, ascending key) order so the f64
    /// total is deterministic.
    pub fn total_pairs(&self) -> f64 {
        self.per_worker.iter().map(|cg| cg.total_pairs()).sum()
    }
}

/// Run stage 1. Keys surviving in *every* input are shuffled and cogrouped.
pub fn filter_and_shuffle(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    cfg: FilterConfig,
    prober: &mut dyn KeyProber,
) -> anyhow::Result<Filtered> {
    assert!(inputs.len() >= 2);
    // auto-sized (kind-only) configs carry no geometry and no fp target —
    // the caller must resolve them against its own fp_rate first
    // (strategies do, via FilterConfig::resolved); guessing a default
    // here would silently override the caller's false-positive budget
    assert!(
        !cfg.is_auto_sized(),
        "auto-sized FilterConfig must be resolved against the inputs \
         (FilterConfig::resolved) before filtering"
    );
    let (join_filter, d_dt) = build_join_filter(cluster, inputs, cfg);
    probe_and_shuffle(cluster, inputs, join_filter, d_dt, prober)
}

/// Steps (1)-(3) of stage 1: per-dataset filters via map + treeReduce,
/// the AND at the master, and the broadcast. Returns the join filter and
/// the stage's simulated seconds. Split out so the serving layer's
/// [`crate::serve::SketchCache`] can reuse a built filter across queries
/// and pay only the probe + shuffle half.
pub fn build_join_filter(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    cfg: FilterConfig,
) -> (JoinFilter, f64) {
    let n = inputs.len();

    // (1) dataset filters via map + treeReduce
    let mut s = cluster.stage("build_filter");
    let mut dataset_filters = Vec::with_capacity(n);
    for d in inputs {
        dataset_filters.push(build_dataset_join_filter(cluster, &mut s, d, cfg));
    }
    // (2) AND at the master (worker 0) — cheap word-wise AND
    let mut join_filter = dataset_filters.pop().unwrap();
    s.task(0, || {
        for f in &dataset_filters {
            join_filter.intersect_with(f);
        }
    });
    // (3) broadcast the join filter
    s.broadcast(0, join_filter.size_bytes());
    let d_dt = s.finish(cluster);
    (join_filter, d_dt)
}

/// Steps (4)-(5) of stage 1: probe local records against an already-built
/// join filter, shuffle the survivors, and cogroup per worker. `d_dt0`
/// carries the build stage's simulated seconds into [`Filtered::d_dt`]
/// (zero when the filter was replayed from a cache — the cost dial then
/// sees the build as already paid).
pub fn probe_and_shuffle(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    join_filter: JoinFilter,
    d_dt0: f64,
    prober: &mut dyn KeyProber,
) -> anyhow::Result<Filtered> {
    let n = inputs.len();
    let mut d_dt = d_dt0;

    // (4) probe local records, (5) shuffle survivors
    let mut s = cluster.stage("filter_shuffle");
    let mut shuffled_inputs: Vec<Vec<Vec<crate::data::Record>>> = Vec::with_capacity(n);
    let mut survivors = Vec::with_capacity(n);
    for d in inputs {
        // hoist the u32 key folding: each partition's keys fold exactly
        // once per run into a flat buffer (data-parallel, attributed to
        // the owning worker), instead of re-collecting inside every
        // probe call
        let folded_timed: Vec<(Vec<u32>, f64)> = cluster.exec.map(d.partitions.len(), |j| {
            let t0 = Instant::now();
            let keys: Vec<u32> = d.partitions[j].iter().map(|r| fold_key(r.key)).collect();
            (keys, t0.elapsed().as_secs_f64())
        });
        let mut folded: Vec<Vec<u32>> = Vec::with_capacity(folded_timed.len());
        for (j, (keys, secs)) in folded_timed.into_iter().enumerate() {
            s.add_compute(cluster.worker_of_partition(j), secs);
            folded.push(keys);
        }
        // probe per partition (data-parallel where safe), attributed to
        // the owning worker
        let mut keep: Vec<Vec<bool>> = Vec::with_capacity(d.partitions.len());
        for (j, (mask, secs)) in probe_partitions(cluster, &folded, &join_filter, prober)?
            .into_iter()
            .enumerate()
        {
            s.add_compute(cluster.worker_of_partition(j), secs);
            keep.push(mask);
        }
        // shuffle only the records the mask kept (explicit walk in the
        // same partition order the mask was computed in)
        let mut kept = 0u64;
        let k = cluster.k;
        let mut out: Vec<Vec<crate::data::Record>> = vec![Vec::new(); k];
        for (j, part) in d.partitions.iter().enumerate() {
            let src = cluster.worker_of_partition(j);
            for (i, r) in part.iter().enumerate() {
                if keep[j][i] {
                    let dst = crate::data::partition_of(r.key, k);
                    s.transfer(src, dst, d.record_bytes);
                    out[dst].push(*r);
                    kept += 1;
                }
            }
        }
        s.add_items(kept);
        survivors.push(kept);
        shuffled_inputs.push(out);
    }
    d_dt += s.finish(cluster);

    // cogroup per worker into flat columns (data-parallel; each worker
    // owns its shard). The columnar joinable directory only lists keys
    // present in every input, so false-positive survivors missing from
    // some input drop out here — exactly the old retain()
    let per_worker: Vec<CogroupColumns> = cluster.exec.map(cluster.k, |w| {
        let per_input: Vec<&[crate::data::Record]> = shuffled_inputs
            .iter()
            .map(|inp| inp[w].as_slice())
            .collect();
        CogroupColumns::from_slices(&per_input)
    });

    Ok(Filtered {
        per_worker,
        d_dt,
        join_filter,
        survivors,
    })
}

/// The exact cross-product stage over filtered survivors — the second half
/// of the Bloom join, also used by the engine when the cost function says
/// the exact join fits the budget (§3.1.1).
pub fn cross_product_stage(
    cluster: &mut SimCluster,
    filtered: &Filtered,
    op: CombineOp,
) -> HashMap<u64, StratumAgg> {
    let mut s = cluster.stage("crossproduct");
    let exec = cluster.exec;
    // each worker streams its own keys' cross products in parallel over
    // contiguous columnar runs; the hash shuffle put every key on exactly
    // one worker, so the merged map is identical for any thread count
    let per_worker = exec.map(filtered.per_worker.len(), |w| {
        let cg = &filtered.per_worker[w];
        let t0 = Instant::now();
        let mut local: HashMap<u64, StratumAgg> = HashMap::with_capacity(cg.num_keys());
        let mut pairs = 0u64;
        let mut sides: Vec<&[f64]> = Vec::with_capacity(cg.n_inputs());
        for idx in 0..cg.num_keys() {
            cg.sides_into(idx, &mut sides);
            let agg = super::cross_product_agg(&sides, op);
            pairs += agg.population as u64;
            local.insert(cg.key(idx), agg);
        }
        (local, pairs, t0.elapsed().as_secs_f64())
    });
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    for (w, (local, pairs, secs)) in per_worker.into_iter().enumerate() {
        strata.extend(local);
        s.add_compute(w, secs);
        s.add_items(pairs);
    }
    s.finish(cluster);
    strata
}

/// Exact Bloom join (§5.2 "filtering stage only"): stage 1 + full cross
/// product over the survivors.
pub fn bloom_join(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    cfg: FilterConfig,
    prober: &mut dyn KeyProber,
) -> Result<JoinRun, JoinError> {
    let filtered = filter_and_shuffle(cluster, inputs, cfg, prober)?;
    let report = filtered.join_filter.report();
    let strata = cross_product_stage(cluster, &filtered, op);
    let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
    crate::faults::finalize_run(
        JoinRun::exact(strata, metrics)
            .with_ledger(ledger)
            .with_filter_report(report),
        cluster,
    )
}

/// Semi/anti join on Bloom membership alone (no stage-2 shuffle): stage 1's
/// join filter decides which keys *may* join, the workers send one 8-byte
/// key fingerprint per distinct surviving key to the master, and the master
/// intersects the two surviving key sets. The intersection is **exact**
/// despite Bloom false positives — a false-positive key of one input
/// survives only on that input, and the other set contains nothing but real
/// keys of the other input, so `surv(L) ∩ surv(R) = keys(L) ∩ keys(R)`.
/// The resolved joinable set broadcasts back and each worker scores its
/// left-input records locally; no record ever crosses the wire, so the
/// `ShuffleLedger` shows zero bytes in any shuffle/crossproduct stage.
pub fn bloom_membership_join(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    cfg: FilterConfig,
    variant: JoinVariant,
    prober: &mut dyn KeyProber,
) -> Result<JoinRun, JoinError> {
    assert_eq!(inputs.len(), 2, "membership join is binary");
    assert!(
        variant.membership_only(),
        "bloom_membership_join handles SEMI/ANTI only"
    );
    assert!(
        !cfg.is_auto_sized(),
        "auto-sized FilterConfig must be resolved against the inputs \
         (FilterConfig::resolved) before filtering"
    );
    let (join_filter, _d_dt) = build_join_filter(cluster, inputs, cfg);
    let report = join_filter.report();

    let mut s = cluster.stage("membership");
    // per input: probe locally, then ship one fingerprint per distinct
    // surviving key to the master (worker 0)
    let mut surviving: Vec<HashSet<u64>> = Vec::with_capacity(2);
    for d in inputs {
        let folded_timed: Vec<(Vec<u32>, f64)> = cluster.exec.map(d.partitions.len(), |j| {
            let t0 = Instant::now();
            let keys: Vec<u32> = d.partitions[j].iter().map(|r| fold_key(r.key)).collect();
            (keys, t0.elapsed().as_secs_f64())
        });
        let mut folded: Vec<Vec<u32>> = Vec::with_capacity(folded_timed.len());
        for (j, (keys, secs)) in folded_timed.into_iter().enumerate() {
            s.add_compute(cluster.worker_of_partition(j), secs);
            folded.push(keys);
        }
        let mut keep: Vec<Vec<bool>> = Vec::with_capacity(d.partitions.len());
        for (j, (mask, secs)) in probe_partitions(cluster, &folded, &join_filter, prober)?
            .into_iter()
            .enumerate()
        {
            s.add_compute(cluster.worker_of_partition(j), secs);
            keep.push(mask);
        }
        let mut set: HashSet<u64> = HashSet::new();
        for (j, part) in d.partitions.iter().enumerate() {
            let src = cluster.worker_of_partition(j);
            for (i, r) in part.iter().enumerate() {
                if keep[j][i] && set.insert(r.key) {
                    s.transfer(src, 0, 8);
                }
            }
        }
        surviving.push(set);
    }
    // exact joinable key set at the master (intersection kills every fp)
    let joinable: HashSet<u64> = surviving[0]
        .intersection(&surviving[1])
        .copied()
        .collect();
    s.broadcast(0, 8 * joinable.len() as u64);

    // score left-input records against the broadcast set, locally per
    // partition; SEMI keeps members, ANTI keeps the complement (exact in
    // both directions: the joinable set is fp-free, and anti members that
    // failed their own Bloom probe still fail `joinable.contains`)
    let want_member = variant == JoinVariant::Semi;
    let left = &inputs[0];
    let per_part = cluster.exec.map(left.partitions.len(), |j| {
        let t0 = Instant::now();
        let mut local: HashMap<u64, StratumAgg> = HashMap::new();
        let mut rows = 0u64;
        for r in &left.partitions[j] {
            if joinable.contains(&r.key) == want_member {
                let e = local.entry(r.key).or_default();
                e.population += 1.0;
                e.push(super::padded_value(op, 0, r.value));
                rows += 1;
            }
        }
        (local, rows, t0.elapsed().as_secs_f64())
    });
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    let mut total_rows = 0u64;
    for (j, (local, rows, secs)) in per_part.into_iter().enumerate() {
        s.add_compute(cluster.worker_of_partition(j), secs);
        total_rows += rows;
        // additive field merge in partition order — a key's rows can span
        // partitions, and the partial strata carry partial populations
        // (StratumAgg::merge assumes full-population halves)
        for (k, agg) in local {
            let e = strata.entry(k).or_default();
            e.population += agg.population;
            e.count += agg.count;
            e.sum += agg.sum;
            e.sumsq += agg.sumsq;
        }
    }
    s.add_items(total_rows);
    s.finish(cluster);

    let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
    crate::faults::finalize_run(
        JoinRun::exact(strata, metrics)
            .with_ledger(ledger)
            .with_filter_report(report),
        cluster,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;
    use crate::join::native::native_join;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn ds(name: &str, recs: Vec<(u64, f64)>) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
            4,
            100,
        )
    }

    #[test]
    fn matches_native_join_result() {
        let a = ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]);
        let b = ds("b", vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]);
        let bj = bloom_join(
            &mut cluster(),
            &[a.clone(), b.clone()],
            CombineOp::Sum,
            FilterConfig::default(),
            &mut NativeProber,
        )
        .unwrap();
        let nat = native_join(&mut cluster(), &[a, b], CombineOp::Sum, u64::MAX).unwrap();
        assert!((bj.exact_sum() - nat.exact_sum()).abs() < 1e-9);
        assert_eq!(bj.output_cardinality(), nat.output_cardinality());
        let report = bj.filter_report.expect("bloom join reports its filter");
        assert_eq!(report.kind, FilterKind::Standard);
        assert_eq!(report.log2_bits, 20);
    }

    #[test]
    fn blocked_kind_matches_standard_results() {
        let a = ds("a", (0..500u64).map(|i| (i, i as f64)).collect());
        let b = ds("b", (250..750u64).map(|i| (i, 2.0 * i as f64)).collect());
        let run_kind = |kind: FilterKind| {
            bloom_join(
                &mut cluster(),
                &[a.clone(), b.clone()],
                CombineOp::Sum,
                FilterConfig::for_inputs_kind(&[a.clone(), b.clone()], 0.01, kind),
                &mut NativeProber,
            )
            .unwrap()
        };
        let std_run = run_kind(FilterKind::Standard);
        let blk_run = run_kind(FilterKind::Blocked);
        // the cogroup stage drops false positives, so the *results* are
        // identical — only shuffle traffic may differ
        assert_eq!(std_run.strata, blk_run.strata);
        assert_eq!(blk_run.filter_report.unwrap().kind, FilterKind::Blocked);
    }

    #[test]
    fn shuffles_far_less_at_low_overlap() {
        // 2% overlap: bloom join should move ~2% of the bytes (+ filters)
        let n = 5000u64;
        let a = ds(
            "a",
            (0..n).map(|i| (if i < 100 { i } else { i + 10_000 }, 1.0)).collect(),
        );
        let b = ds(
            "b",
            (0..n).map(|i| (if i < 100 { i } else { i + 20_000 }, 1.0)).collect(),
        );
        // size the filter for the input (eq 27) — the fixed 2^20 default
        // would dominate the byte count on an input this small
        let cfg = FilterConfig::for_inputs(&[a.clone(), b.clone()], 0.01);
        let bj = bloom_join(
            &mut cluster(),
            &[a.clone(), b.clone()],
            CombineOp::Sum,
            cfg,
            &mut NativeProber,
        )
        .unwrap();
        let nat = native_join(&mut cluster(), &[a, b], CombineOp::Sum, u64::MAX).unwrap();
        let rb = bj.metrics.total_shuffled_bytes() as f64;
        let nb = nat.metrics.total_shuffled_bytes() as f64;
        assert!(rb < nb, "bloom {rb} vs native {nb}");
        // record movement portion must be ~2%; filters add a constant
        let record_bytes: u64 = bj
            .metrics
            .stage("filter_shuffle")
            .map(|s| s.shuffled_bytes)
            .unwrap();
        assert!(
            (record_bytes as f64) < 0.05 * (2.0 * n as f64 * 100.0),
            "record bytes {record_bytes}"
        );
    }

    #[test]
    fn three_way_filtering() {
        let a = ds("a", vec![(1, 1.0), (2, 2.0), (7, 1.0)]);
        let b = ds("b", vec![(1, 10.0), (1, 20.0), (2, 30.0), (8, 1.0)]);
        let c3 = ds("c", vec![(1, 100.0), (3, 0.0), (2, 1.0)]);
        let bj = bloom_join(
            &mut cluster(),
            &[a.clone(), b.clone(), c3.clone()],
            CombineOp::Sum,
            FilterConfig::default(),
            &mut NativeProber,
        )
        .unwrap();
        let nat = native_join(&mut cluster(), &[a, b, c3], CombineOp::Sum, u64::MAX).unwrap();
        assert!((bj.exact_sum() - nat.exact_sum()).abs() < 1e-9);
    }

    #[test]
    fn d_dt_positive_and_filter_reports_survivors() {
        let a = ds("a", (0..2000).map(|i| (i, 1.0)).collect());
        let b = ds("b", (1900..4000).map(|i| (i, 1.0)).collect());
        let mut c = cluster();
        let f = filter_and_shuffle(
            &mut c,
            &[a, b],
            FilterConfig::default(),
            &mut NativeProber,
        )
        .unwrap();
        assert!(f.d_dt > 0.0);
        // ~100 truly-common keys per input (+ false positives)
        assert!((100..300).contains(&f.survivors[0]), "{:?}", f.survivors);
        assert!((100..300).contains(&f.survivors[1]), "{:?}", f.survivors);
        let keys: usize = f.per_worker.iter().map(|g| g.num_keys()).sum();
        assert!((90..=220).contains(&keys), "cogrouped keys {keys}");
        // total_pairs is the exact joinable cardinality: 100 shared keys,
        // one record each side
        assert_eq!(f.total_pairs(), 100.0);
    }

    #[test]
    fn membership_join_is_exact_with_zero_record_shuffle() {
        let a = ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]);
        let b = ds("b", vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]);
        let run = |variant: JoinVariant| {
            bloom_membership_join(
                &mut cluster(),
                &[a.clone(), b.clone()],
                CombineOp::Left,
                FilterConfig::default(),
                variant,
                &mut NativeProber,
            )
            .unwrap()
        };
        let semi = run(JoinVariant::Semi);
        // left rows with a joinable key: (1,1.0) (1,2.0) (2,10.0)
        assert_eq!(semi.output_cardinality(), 3.0);
        assert!((semi.exact_sum() - 13.0).abs() < 1e-9);
        let anti = run(JoinVariant::Anti);
        // the complement: (3,5.0)
        assert_eq!(anti.output_cardinality(), 1.0);
        assert!((anti.exact_sum() - 5.0).abs() < 1e-9);
        for r in [&semi, &anti] {
            assert!(!r.sampled);
            // only filter construction + key fingerprints travel: no
            // record shuffle stage exists at all
            for stage in ["filter_shuffle", "crossproduct", "shuffle", "sample"] {
                assert_eq!(r.ledger.stage_bytes(stage), 0, "stage {stage}");
            }
            assert!(r.ledger.stage_bytes("membership") > 0);
            assert!(r.filter_report.is_some());
        }
    }

    #[test]
    fn filter_config_for_inputs() {
        let a = ds("a", (0..10_000).map(|i| (i, 1.0)).collect());
        let b = ds("b", (0..100).map(|i| (i, 1.0)).collect());
        let cfg = FilterConfig::for_inputs(&[a.clone(), b.clone()], 0.01);
        // sized for the largest input (10k): >= 96k bits -> log2 >= 17
        assert!(cfg.log2_bits >= 17, "log2={}", cfg.log2_bits);
        assert_eq!(cfg.kind, FilterKind::Standard);
        // auto-sized sentinel resolves to the same geometry
        let auto = FilterConfig::auto_sized(FilterKind::Blocked);
        assert!(auto.is_auto_sized());
        let resolved = auto.resolved(&[a, b], 0.01);
        assert!(!resolved.is_auto_sized());
        assert_eq!(resolved.kind, FilterKind::Blocked);
        assert!(resolved.log2_bits >= 17);
    }
}
