//! Join strategies behind one trait. Five implementations:
//!
//! * [`native`] — native Spark RDD join: chained binary cogroups, full
//!   shuffle of every input *and* every intermediate, full cross products.
//! * [`repartition`] — Spark repartition join: one tagged shuffle of all n
//!   inputs, then a streamed n-way cross product per key (no materialized
//!   intermediates).
//! * [`broadcast`] — broadcast join: ships the n−1 smaller inputs to every
//!   worker; no shuffle of the largest input.
//! * [`bloom`] (bloom_join.rs) — ApproxJoin stage 1 only (§3.1): multi-way
//!   Bloom join filter, filtered shuffle, exact cross product.
//! * [`approx`] — full ApproxJoin (§3.2-3.4): stage 1 + stratified edge
//!   sampling during the join + CLT/HT estimation, optionally pushing the
//!   per-stratum aggregation through the AOT `join_agg` artifact.
//!
//! Two centralized sample-first baselines from "Joins on Samples" ride in
//! [`sample_first`] (Bernoulli row sampling and universe key sampling,
//! joined *after* sampling at the master) — registered alongside the
//! distributed strategies for quality-vs-cost comparisons, never chosen
//! by `Auto` planning. Every strategy also answers the non-inner
//! [`JoinVariant`]s (outer/semi/anti) through
//! [`JoinStrategy::execute_variant`]; semi/anti ride the stage-1 Bloom
//! membership with zero stage-2 shuffle.
//!
//! All five implement the [`JoinStrategy`] trait ([`strategy`]) and live in
//! a [`StrategyRegistry`]; the cost-based [`Planner`] ([`planner`]) ranks
//! them per workload and the [`crate::session::Session`] front end is how
//! callers reach them. Every strategy returns a [`JoinRun`]: per-key
//! aggregates (population + sampled moments — an exact join is the
//! b_i = B_i special case) plus the stage metrics the figures report, or a
//! [`JoinError`] when execution is impossible.

pub mod approx;
pub mod bloom_join;
pub mod broadcast;
pub mod join_graph;
pub mod native;
pub mod order;
pub mod planner;
pub mod repartition;
pub mod sample_first;
pub mod strategy;

pub use join_graph::JoinGraph;
pub use order::{JoinOrderReport, TableStats};
pub use planner::{JoinPlan, Planner, StrategyChoice};
pub use sample_first::{BernoulliJoin, SampleFirstReport, UniverseJoin};
pub use strategy::{
    ApproxJoin, BloomJoin, BroadcastJoin, CostEstimate, InputStats, JoinStrategy, NativeJoin,
    RepartitionJoin, StrategyRegistry,
};

use crate::bloom::FilterReport;
use crate::cluster::{JoinMetrics, ShuffleLedger};
use crate::data::Dataset;
use crate::stats::StratumAgg;
use std::collections::{BTreeMap, HashMap, HashSet};

/// How the values of the n joined sides combine into the aggregated value
/// (the expression inside the query's SUM/AVG/...).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombineOp {
    /// v₁ + v₂ + … + vₙ (the paper's running example SUM(R1.V + R2.V + …)).
    Sum,
    /// v₁ · v₂ · … · vₙ.
    Product,
    /// v₁ (left side only — COUNT-style queries where values are markers).
    Left,
}

impl CombineOp {
    #[inline]
    pub fn combine(&self, values: &[f64]) -> f64 {
        match self {
            CombineOp::Sum => values.iter().sum(),
            CombineOp::Product => values.iter().product(),
            CombineOp::Left => values.first().copied().unwrap_or(0.0),
        }
    }

    /// Fold an additional value into an already-combined prefix — used by
    /// chained binary joins and by the runtime path's pre-reduction.
    #[inline]
    pub fn fold(&self, acc: f64, v: f64) -> f64 {
        match self {
            CombineOp::Sum => acc + v,
            CombineOp::Product => acc * v,
            CombineOp::Left => acc,
        }
    }
}

/// Which rows of a two-table equi-join survive into the output.
///
/// `Inner` is the n-way join every strategy always supported; the five
/// non-inner variants are binary (exactly two inputs) and are resolved
/// *exactly* even on the sampling strategies:
///
/// * `Semi` / `Anti` are pure membership questions — the stage-1 Bloom
///   pre-filter the paper already pays for answers them with **no stage-2
///   shuffle at all** (a `membership` ledger stage replaces
///   `filter_shuffle` + `crossproduct` / `sample`). Bloom false positives
///   are removed by one exact key-set intersection at the master, so the
///   answer is exact, not approximate.
/// * `LeftOuter` / `RightOuter` / `FullOuter` run the strategy's inner
///   join unchanged, then pad every unmatched key of the padded side(s)
///   as a dedicated fully-enumerated stratum with neutral-fill values
///   (missing side contributes the combine op's identity). Fully
///   enumerated strata have zero CLT variance (fpc = 0) and inclusion
///   probability 1 under Horvitz-Thompson, so approximate outer joins
///   stay unbiased and their CIs still cover.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JoinVariant {
    #[default]
    Inner,
    LeftOuter,
    RightOuter,
    FullOuter,
    Semi,
    Anti,
}

impl JoinVariant {
    /// Every variant, in a fixed order (tests and benches sweep this).
    pub const ALL: [JoinVariant; 6] = [
        JoinVariant::Inner,
        JoinVariant::LeftOuter,
        JoinVariant::RightOuter,
        JoinVariant::FullOuter,
        JoinVariant::Semi,
        JoinVariant::Anti,
    ];

    /// Short stable tag — enters query fingerprints and serve cache keys.
    pub fn tag(&self) -> &'static str {
        match self {
            JoinVariant::Inner => "inner",
            JoinVariant::LeftOuter => "left_outer",
            JoinVariant::RightOuter => "right_outer",
            JoinVariant::FullOuter => "full_outer",
            JoinVariant::Semi => "semi",
            JoinVariant::Anti => "anti",
        }
    }

    /// The SQL spelling of the variant's JOIN keyword(s).
    pub fn sql(&self) -> &'static str {
        match self {
            JoinVariant::Inner => "JOIN",
            JoinVariant::LeftOuter => "LEFT OUTER JOIN",
            JoinVariant::RightOuter => "RIGHT OUTER JOIN",
            JoinVariant::FullOuter => "FULL OUTER JOIN",
            JoinVariant::Semi => "SEMI JOIN",
            JoinVariant::Anti => "ANTI JOIN",
        }
    }

    pub fn is_inner(&self) -> bool {
        matches!(self, JoinVariant::Inner)
    }

    /// Does the output keep unmatched LEFT rows (padded)?
    pub fn pads_left(&self) -> bool {
        matches!(self, JoinVariant::LeftOuter | JoinVariant::FullOuter)
    }

    /// Does the output keep unmatched RIGHT rows (padded)?
    pub fn pads_right(&self) -> bool {
        matches!(self, JoinVariant::RightOuter | JoinVariant::FullOuter)
    }

    /// Semi/anti: the output is decided by key membership alone, so the
    /// stage-1 filter answers it without any stage-2 shuffle.
    pub fn membership_only(&self) -> bool {
        matches!(self, JoinVariant::Semi | JoinVariant::Anti)
    }
}

impl std::fmt::Display for JoinVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// The combined value of a single-side (padded or membership) row: the
/// missing side contributes the combine op's identity, so Sum keeps v,
/// Product keeps v, and Left keeps v only when the surviving side IS the
/// left input (COUNT-style markers stay 0 for right-padded rows).
#[inline]
pub(crate) fn padded_value(op: CombineOp, input: usize, v: f64) -> f64 {
    match op {
        CombineOp::Sum | CombineOp::Product => v,
        CombineOp::Left => {
            if input == 0 {
                v
            } else {
                0.0
            }
        }
    }
}

/// Non-inner variants are binary joins — reject anything else with a
/// typed error so fuzzed plans never panic.
pub(crate) fn require_binary(
    strategy: &str,
    n_inputs: usize,
    variant: JoinVariant,
) -> Result<(), JoinError> {
    if n_inputs == 2 {
        Ok(())
    } else {
        Err(JoinError::Unsupported {
            strategy: strategy.to_string(),
            reason: format!(
                "{} join is binary: got {n_inputs} inputs (chain inner joins first)",
                variant.tag()
            ),
        })
    }
}

/// The exact per-key key set of a dataset.
pub(crate) fn key_set(d: &Dataset) -> HashSet<u64> {
    let mut s = HashSet::new();
    for part in &d.partitions {
        for r in part {
            s.insert(r.key);
        }
    }
    s
}

/// Exact semi/anti strata, computed sequentially from the raw inputs:
/// one fully-enumerated stratum per surviving LEFT key (population ==
/// count == the key's left multiplicity). Deterministic regardless of
/// thread count — accumulation follows partition/record order.
pub(crate) fn exact_semi_anti_strata(
    inputs: &[Dataset],
    op: CombineOp,
    variant: JoinVariant,
) -> HashMap<u64, StratumAgg> {
    debug_assert!(variant.membership_only() && inputs.len() == 2);
    let right_keys = key_set(&inputs[1]);
    let want_member = variant == JoinVariant::Semi;
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    for part in &inputs[0].partitions {
        for r in part {
            if right_keys.contains(&r.key) == want_member {
                let e = strata.entry(r.key).or_default();
                e.population += 1.0;
                e.push(padded_value(op, 0, r.value));
            }
        }
    }
    strata
}

/// Pad an inner-join run into an outer-join run: every key of a padded
/// side that has no partner on the other side becomes a dedicated,
/// fully-enumerated stratum of neutral-fill values. On the
/// Horvitz-Thompson path the padded keys get `draws = ∞` so their
/// inclusion probability is exactly 1 (zero variance contribution).
pub(crate) fn pad_outer_strata(
    run: &mut JoinRun,
    inputs: &[Dataset],
    op: CombineOp,
    variant: JoinVariant,
) {
    debug_assert!(inputs.len() == 2);
    let ht = !run.draws.is_empty();
    let mut pad_side = |side: usize| {
        let other_keys = key_set(&inputs[1 - side]);
        for part in &inputs[side].partitions {
            for r in part {
                if !other_keys.contains(&r.key) {
                    let e = run.strata.entry(r.key).or_default();
                    e.population += 1.0;
                    e.push(padded_value(op, side, r.value));
                    if ht {
                        run.draws.insert(r.key, f64::INFINITY);
                    }
                }
            }
        }
    };
    if variant.pads_left() {
        pad_side(0);
    }
    if variant.pads_right() {
        pad_side(1);
    }
}

/// Resolve a variant's exact per-key strata from one binary cogroup that
/// holds EVERY key of both inputs (i.e. a full, unfiltered shuffle) — the
/// streaming window join's exact path. Keys are walked in ascending
/// order on both the joinable directory and the per-input runs, so the
/// result is bit-identical for any thread count.
pub fn variant_strata_from_cogroup(
    cg: &crate::runtime::columnar::CogroupColumns,
    op: CombineOp,
    variant: JoinVariant,
) -> BTreeMap<u64, StratumAgg> {
    assert_eq!(cg.n_inputs(), 2, "variant cogroup resolution is binary");
    let mut strata: BTreeMap<u64, StratumAgg> = BTreeMap::new();
    // matched keys: the cogroup directory is exactly keys(L) ∩ keys(R)
    for i in 0..cg.num_keys() {
        if let Some(agg) =
            variant_stratum_for_key(Some(cg.side(i, 0)), Some(cg.side(i, 1)), op, variant)
        {
            strata.insert(cg.key(i), agg);
        }
    }
    // single-side keys: walk each input's full run directory and keep
    // the keys absent from the matched directory
    let mut pad_input = |input: usize, strata: &mut BTreeMap<u64, StratumAgg>| {
        for ri in 0..cg.num_runs(input) {
            let (k, vals) = cg.run(input, ri);
            if cg.contains_key(k) {
                continue;
            }
            let (l, r) = if input == 0 {
                (Some(vals), None)
            } else {
                (None, Some(vals))
            };
            if let Some(agg) = variant_stratum_for_key(l, r, op, variant) {
                strata.insert(k, agg);
            }
        }
    };
    if variant.pads_left() || variant == JoinVariant::Anti {
        pad_input(0, &mut strata);
    }
    if variant.pads_right() {
        pad_input(1, &mut strata);
    }
    strata
}

/// One key's variant stratum from its per-input value runs (either side
/// absent when the key is missing from that input) — the per-key unit
/// [`variant_strata_from_cogroup`] is built from, factored out so the
/// continuous engine updates only the keys a delta touched. Returns
/// `None` when the key contributes no stratum under `variant` (matched
/// key under ANTI, right-only key under LEFT, ...).
pub(crate) fn variant_stratum_for_key(
    left: Option<&[f64]>,
    right: Option<&[f64]>,
    op: CombineOp,
    variant: JoinVariant,
) -> Option<StratumAgg> {
    let pad = |input: usize, vals: &[f64]| {
        let mut agg = StratumAgg {
            population: vals.len() as f64,
            ..Default::default()
        };
        for &v in vals {
            agg.push(padded_value(op, input, v));
        }
        agg
    };
    match (left, right) {
        (Some(l), Some(r)) => {
            if !variant.membership_only() {
                Some(cross_product_agg(&[l, r], op))
            } else if variant == JoinVariant::Semi {
                Some(pad(0, l))
            } else {
                None // ANTI: matched keys contribute nothing
            }
        }
        (Some(l), None) => (variant.pads_left() || variant == JoinVariant::Anti).then(|| pad(0, l)),
        (None, Some(r)) => variant.pads_right().then(|| pad(1, r)),
        (None, None) => None,
    }
}

/// The outcome of a join execution.
#[derive(Clone, Debug)]
pub struct JoinRun {
    /// Per-join-key aggregates. For exact joins, count == population and
    /// the moments cover every output pair; for approximate joins, count is
    /// the per-stratum sample size b_i.
    pub strata: HashMap<u64, StratumAgg>,
    pub metrics: JoinMetrics,
    /// Measured per-stage / per-worker shuffle traffic — the ground truth
    /// the cost model's shuffle predictions are checked against.
    pub ledger: ShuffleLedger,
    /// True when the strategy sampled (strata are estimates, not totals).
    pub sampled: bool,
    /// Raw draw counts per key for the Horvitz-Thompson path (empty for
    /// exact joins and for the CLT path).
    pub draws: HashMap<u64, f64>,
    /// The join filter this run built (kind, geometry, measured-fill fp
    /// rate) — `None` for the strategies that do not filter.
    pub filter_report: Option<FilterReport>,
    /// Present only for the centralized sample-first baselines ("Joins on
    /// Samples"): their estimator is join-level, not stratum-level, so the
    /// run carries the closed-form estimates alongside the sampled strata.
    pub baseline: Option<SampleFirstReport>,
    /// What the injected fault plan did to this run (`None` when the
    /// cluster had no plan): injected/recovered/degraded counts, retry
    /// bytes, priced extra sim-seconds, and any degradation re-weighting.
    pub fault_report: Option<crate::faults::FaultReport>,
}

impl JoinRun {
    pub fn exact(strata: HashMap<u64, StratumAgg>, metrics: JoinMetrics) -> Self {
        Self {
            strata,
            metrics,
            ledger: ShuffleLedger::default(),
            sampled: false,
            draws: HashMap::new(),
            filter_report: None,
            baseline: None,
            fault_report: None,
        }
    }

    /// Attach the measured shuffle ledger of the run.
    pub fn with_ledger(mut self, ledger: ShuffleLedger) -> Self {
        self.ledger = ledger;
        self
    }

    /// Attach the built join filter's post-build report.
    pub fn with_filter_report(mut self, report: FilterReport) -> Self {
        self.filter_report = Some(report);
        self
    }

    /// Total measured shuffled bytes (== `metrics.total_shuffled_bytes()`).
    pub fn measured_shuffle_bytes(&self) -> u64 {
        self.ledger.total_bytes()
    }

    /// Exact SUM of the combined values over the full join output — only
    /// meaningful when `!sampled`. Summed in key order so the f64 result
    /// is identical across runs (HashMap iteration order is not).
    pub fn exact_sum(&self) -> f64 {
        self.strata_vec().iter().map(|s| s.sum).sum()
    }

    /// Total join-output cardinality Σ B_i (exact in both modes: the
    /// filter stage knows every stratum's bipartite size).
    pub fn output_cardinality(&self) -> f64 {
        self.strata.values().map(|s| s.population).sum()
    }

    /// Stratum aggregates as a vector in ascending key order — a
    /// deterministic order so every estimator's f64 accumulation is
    /// reproducible run-to-run and thread-count independent.
    pub fn strata_vec(&self) -> Vec<StratumAgg> {
        let mut keys: Vec<u64> = self.strata.keys().copied().collect();
        keys.sort_unstable();
        keys.into_iter().map(|k| self.strata[&k]).collect()
    }
}

/// Errors a join can hit. Every strategy entry point returns
/// `Result<JoinRun, JoinError>` uniformly — `OutOfMemory` mirrors the
/// paper's native-join OOM at 8-10% overlap (Fig 9a's missing bars),
/// `Unsupported` is the planner rejecting a strategy for a workload, and
/// `Runtime` folds lower-layer (prober / aggregator) failures in.
#[derive(Debug)]
pub enum JoinError {
    /// Materialized intermediate exceeded the per-worker memory budget.
    OutOfMemory { stage: String, bytes: u64 },
    /// The requested strategy cannot serve this query — unknown name, or
    /// predicted infeasible on these inputs.
    Unsupported { strategy: String, reason: String },
    /// A lower layer (Bloom prober, batch aggregator, runtime) failed.
    Runtime(String),
    /// The serving layer's admission controller refused the query: the
    /// predicted queue wait already exceeds the hard limit, so even a
    /// maximally degraded sampling budget could not meet the latency SLO.
    Overloaded {
        predicted_wait_secs: f64,
        hard_limit_secs: f64,
    },
    /// Injected faults exhausted the failure budget and the lost data
    /// cannot be absorbed: exact (unsampled) runs lost output strata with
    /// their workers, or a sampled run lost *every* stratum. Sampled runs
    /// that keep at least one stratum degrade gracefully (wider CIs, a
    /// populated `FaultReport`) instead of raising this.
    Degraded {
        dead_workers: usize,
        dropped_strata: u64,
        reason: String,
    },
}

impl std::fmt::Display for JoinError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JoinError::OutOfMemory { stage, bytes } => {
                write!(f, "out of memory in {stage}: {bytes} bytes")
            }
            JoinError::Unsupported { strategy, reason } => {
                write!(f, "strategy {strategy} unsupported: {reason}")
            }
            JoinError::Runtime(msg) => write!(f, "join runtime error: {msg}"),
            JoinError::Overloaded {
                predicted_wait_secs,
                hard_limit_secs,
            } => write!(
                f,
                "server overloaded: predicted queue wait {predicted_wait_secs:.3}s \
                 exceeds the hard limit {hard_limit_secs:.3}s"
            ),
            JoinError::Degraded {
                dead_workers,
                dropped_strata,
                reason,
            } => write!(
                f,
                "degraded past recovery: {dead_workers} dead worker(s), \
                 {dropped_strata} stratum/strata lost — {reason}"
            ),
        }
    }
}

impl std::error::Error for JoinError {}

impl From<anyhow::Error> for JoinError {
    fn from(e: anyhow::Error) -> Self {
        JoinError::Runtime(format!("{e:#}"))
    }
}

/// Group shuffled records of n inputs by key: key → one value-vector per
/// input. Shared by every strategy's final phase.
pub(crate) fn group_by_key(
    per_input_records: &[Vec<crate::data::Record>],
) -> HashMap<u64, Vec<Vec<f64>>> {
    let n = per_input_records.len();
    let mut groups: HashMap<u64, Vec<Vec<f64>>> = HashMap::new();
    for (i, recs) in per_input_records.iter().enumerate() {
        for r in recs {
            groups.entry(r.key).or_insert_with(|| vec![Vec::new(); n])[i].push(r.value);
        }
    }
    groups
}

/// Stream the full n-way cross product of one key group into a stratum
/// aggregate. Cost is Π |side_i| combined-value evaluations — the honest
/// cross-product work the paper's latency figures measure.
/// Generic over the side container so both the legacy `&[Vec<f64>]`
/// cogroups and the columnar `&[&[f64]]` run views share one
/// implementation (identical f64 evaluation order either way).
/// Public for benches and diagnostics.
pub fn cross_product_agg<S: AsRef<[f64]>>(sides: &[S], op: CombineOp) -> StratumAgg {
    let population: f64 = sides.iter().map(|s| s.as_ref().len() as f64).product();
    let mut agg = StratumAgg {
        population,
        ..Default::default()
    };
    if sides.iter().any(|s| s.as_ref().is_empty()) {
        return agg;
    }
    // odometer over the n sides
    let n = sides.len();
    let mut idx = vec![0usize; n];
    let mut vals: Vec<f64> = idx.iter().zip(sides).map(|(&i, s)| s.as_ref()[i]).collect();
    loop {
        agg.push(op.combine(&vals));
        // increment odometer
        let mut d = n;
        loop {
            if d == 0 {
                return agg;
            }
            d -= 1;
            idx[d] += 1;
            let side = sides[d].as_ref();
            if idx[d] < side.len() {
                vals[d] = side[idx[d]];
                break;
            }
            idx[d] = 0;
            vals[d] = side[0];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Record;

    #[test]
    fn combine_ops() {
        assert_eq!(CombineOp::Sum.combine(&[1.0, 2.0, 3.0]), 6.0);
        assert_eq!(CombineOp::Product.combine(&[2.0, 3.0, 4.0]), 24.0);
        assert_eq!(CombineOp::Left.combine(&[7.0, 9.0]), 7.0);
        assert_eq!(CombineOp::Sum.fold(10.0, 5.0), 15.0);
        assert_eq!(CombineOp::Product.fold(10.0, 5.0), 50.0);
        assert_eq!(CombineOp::Left.fold(10.0, 5.0), 10.0);
    }

    #[test]
    fn group_by_key_shapes() {
        let a = vec![Record::new(1, 10.0), Record::new(2, 20.0)];
        let b = vec![Record::new(1, 1.0), Record::new(1, 2.0)];
        let g = group_by_key(&[a, b]);
        assert_eq!(g[&1][0], vec![10.0]);
        assert_eq!(g[&1][1], vec![1.0, 2.0]);
        assert_eq!(g[&2][0], vec![20.0]);
        assert!(g[&2][1].is_empty());
    }

    #[test]
    fn cross_product_two_way() {
        // {1,2} x {10,20,30} with Sum: pairs sums = 11,21,31,12,22,32
        let agg = cross_product_agg(&[vec![1.0, 2.0], vec![10.0, 20.0, 30.0]], CombineOp::Sum);
        assert_eq!(agg.population, 6.0);
        assert_eq!(agg.count, 6.0);
        assert_eq!(agg.sum, 129.0);
    }

    #[test]
    fn cross_product_three_way_product_op() {
        let agg = cross_product_agg(
            &[vec![1.0, 2.0], vec![3.0], vec![4.0, 5.0]],
            CombineOp::Product,
        );
        assert_eq!(agg.population, 4.0);
        // 1*3*4 + 1*3*5 + 2*3*4 + 2*3*5 = 12+15+24+30 = 81
        assert_eq!(agg.sum, 81.0);
    }

    #[test]
    fn cross_product_empty_side() {
        let agg = cross_product_agg(&[vec![1.0], vec![]], CombineOp::Sum);
        assert_eq!(agg.population, 0.0);
        assert_eq!(agg.count, 0.0);
    }

    #[test]
    fn join_run_exact_sum() {
        let mut strata = HashMap::new();
        strata.insert(
            1,
            cross_product_agg(&[vec![1.0], vec![2.0]], CombineOp::Sum),
        );
        strata.insert(
            2,
            cross_product_agg(&[vec![5.0], vec![5.0]], CombineOp::Sum),
        );
        let run = JoinRun::exact(strata, JoinMetrics::default());
        assert_eq!(run.exact_sum(), 13.0);
        assert_eq!(run.output_cardinality(), 2.0);
    }
}
