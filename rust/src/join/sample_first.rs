//! Centralized sample-first baselines from "Joins on Samples": sample the
//! *inputs* first, ship the sampled rows to one node, and join there —
//! the opposite order of the paper's join-then-sample ApproxJoin. Two
//! samplers:
//!
//! * [`BernoulliJoin`] — independent per-row Bernoulli(q) sampling. A join
//!   output pair survives with probability q², so estimates blow up in
//!   variance at small q, and sampled rows can never prove a key's
//!   *absence* — only the inner variant is answerable.
//! * [`UniverseJoin`] — universe (key) sampling: both inputs keep exactly
//!   the keys whose seeded hash falls under the fraction-p threshold. The
//!   sampled join is the true join restricted to sampled keys, so every
//!   [`JoinVariant`] (outer/semi/anti included) is answerable.
//!
//! Both register in the [`super::StrategyRegistry`] as explicit-name-only
//! baselines ([`super::JoinStrategy::is_baseline`]) for quality-vs-cost
//! comparison against the distributed strategies; `Auto` planning never
//! picks them. Their estimators are join-level closed forms, not
//! per-stratum CLT/HT sums, so runs carry a [`SampleFirstReport`] in
//! [`JoinRun::baseline`] and the session reads the estimate from there.

use super::strategy::{CostEstimate, InputStats, JoinStrategy};
use super::{
    cross_product_agg, padded_value, require_binary, CombineOp, JoinError, JoinRun, JoinVariant,
};
use crate::cluster::SimCluster;
use crate::cost::CostModel;
use crate::data::Dataset;
use crate::query::AggFunc;
use crate::stats::{z_critical, ApproxResult, StratumAgg};
use crate::util::fmt;
use crate::util::rng::splitmix64;
use std::collections::{BTreeMap, HashMap};

/// Map a 64-bit hash to [0,1) with 53 uniform bits.
#[inline]
fn u01(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Closed-form join-level estimates of a sample-first run. The SUM and
/// COUNT estimators are unbiased under the sampler's inclusion
/// probabilities; AVG is their ratio with a delta-method variance, which
/// needs the covariance term.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleFirstReport {
    /// Sampler name (`"bernoulli"` or `"universe"`).
    pub method: &'static str,
    /// Row fraction q (Bernoulli) or key fraction p (universe).
    pub fraction: f64,
    /// Unbiased estimate of the full-output SUM of combined values.
    pub est_sum: f64,
    /// Estimated variance of `est_sum`.
    pub var_sum: f64,
    /// Unbiased estimate of the full-output cardinality.
    pub est_count: f64,
    /// Estimated variance of `est_count`.
    pub var_count: f64,
    /// Estimated covariance of (`est_sum`, `est_count`) — AVG's delta
    /// method needs it.
    pub cov_sum_count: f64,
    /// Sampled input rows the estimate is based on.
    pub samples: u64,
}

impl SampleFirstReport {
    /// Resolve the report into an [`ApproxResult`] for one aggregate at a
    /// confidence level (normal critical values — the estimators are
    /// join-level sums, not small-sample stratum means).
    pub fn result_for(&self, agg: AggFunc, confidence: f64) -> Result<ApproxResult, JoinError> {
        let z = z_critical(confidence);
        let (estimate, variance) = match agg {
            AggFunc::Sum => (self.est_sum, self.var_sum),
            AggFunc::Count => (self.est_count, self.var_count),
            AggFunc::Avg => {
                if self.est_count <= 0.0 {
                    return Err(JoinError::Unsupported {
                        strategy: self.method.to_string(),
                        reason: "sample produced no join output; AVG undefined".to_string(),
                    });
                }
                let r = self.est_sum / self.est_count;
                // delta method on the ratio of two correlated estimators
                let var = (self.var_sum - 2.0 * r * self.cov_sum_count
                    + r * r * self.var_count)
                    / (self.est_count * self.est_count);
                (r, var)
            }
            AggFunc::Stdev => {
                return Err(JoinError::Unsupported {
                    strategy: self.method.to_string(),
                    reason: "STDEV has no closed-form sample-first estimator".to_string(),
                })
            }
        };
        Ok(ApproxResult {
            estimate,
            error_bound: z * variance.max(0.0).sqrt(),
            confidence,
            degrees_of_freedom: f64::INFINITY,
            samples: self.samples,
        })
    }
}

/// Sampled rows of every input, shipped to the master in (input,
/// partition, row) order — the honest centralization the ledger prices.
fn centralize_sampled(
    cluster: &mut SimCluster,
    stage: &mut crate::cluster::Stage,
    inputs: &[Dataset],
    mut keep: impl FnMut(usize, usize, usize, u64) -> bool,
) -> (Vec<Vec<crate::data::Record>>, u64) {
    let mut sampled: Vec<Vec<crate::data::Record>> = Vec::with_capacity(inputs.len());
    let mut total = 0u64;
    for (i, d) in inputs.iter().enumerate() {
        let mut rows = Vec::new();
        for (j, part) in d.partitions.iter().enumerate() {
            let src = cluster.worker_of_partition(j);
            let kept = stage.task(src, || {
                part.iter()
                    .enumerate()
                    .filter(|(ri, r)| keep(i, j, *ri, r.key))
                    .map(|(_, r)| *r)
                    .collect::<Vec<_>>()
            });
            for _ in &kept {
                stage.transfer(src, 0, d.record_bytes);
            }
            rows.extend(kept);
        }
        total += rows.len() as u64;
        sampled.push(rows);
    }
    (sampled, total)
}

/// Group one input's sampled rows by key, in ascending key order (row
/// order within a key follows arrival order — deterministic).
fn by_key(rows: &[crate::data::Record]) -> BTreeMap<u64, Vec<f64>> {
    let mut m: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
    for r in rows {
        m.entry(r.key).or_default().push(r.value);
    }
    m
}

/// The sampled join's per-key strata for a variant, computed at the master
/// over the centralized sample. Binary for the non-inner variants (the
/// callers enforce it); inner handles n inputs.
fn sampled_variant_strata(
    sampled: &[Vec<crate::data::Record>],
    op: CombineOp,
    variant: JoinVariant,
) -> BTreeMap<u64, StratumAgg> {
    let groups: Vec<BTreeMap<u64, Vec<f64>>> = sampled.iter().map(|r| by_key(r)).collect();
    let mut strata: BTreeMap<u64, StratumAgg> = BTreeMap::new();
    if variant.is_inner() {
        'keys: for (k, left) in &groups[0] {
            let mut sides: Vec<&[f64]> = Vec::with_capacity(groups.len());
            sides.push(left.as_slice());
            for g in &groups[1..] {
                match g.get(k) {
                    Some(v) => sides.push(v.as_slice()),
                    None => continue 'keys,
                }
            }
            strata.insert(*k, cross_product_agg(&sides, op));
        }
        return strata;
    }
    let (lg, rg) = (&groups[0], &groups[1]);
    let single_side = |vals: &[f64], input: usize| {
        let mut agg = StratumAgg {
            population: vals.len() as f64,
            ..Default::default()
        };
        for &v in vals {
            agg.push(padded_value(op, input, v));
        }
        agg
    };
    match variant {
        JoinVariant::Semi | JoinVariant::Anti => {
            let want_member = variant == JoinVariant::Semi;
            for (k, left) in lg {
                if rg.contains_key(k) == want_member {
                    strata.insert(*k, single_side(left, 0));
                }
            }
        }
        _ => {
            for (k, left) in lg {
                if let Some(right) = rg.get(k) {
                    strata.insert(
                        *k,
                        cross_product_agg(&[left.as_slice(), right.as_slice()], op),
                    );
                }
            }
            if variant.pads_left() {
                for (k, left) in lg {
                    if !rg.contains_key(k) {
                        strata.insert(*k, single_side(left, 0));
                    }
                }
            }
            if variant.pads_right() {
                for (k, right) in rg {
                    if !lg.contains_key(k) {
                        strata.insert(*k, single_side(right, 1));
                    }
                }
            }
        }
    }
    strata
}

/// Universe (key) sampling baseline: both inputs keep the keys whose
/// seeded hash lands under the fraction-p threshold, so the sampled join
/// is the exact join restricted to a p-fraction of the key universe.
#[derive(Clone, Copy, Debug)]
pub struct UniverseJoin {
    /// Key-universe inclusion fraction p in (0, 1].
    pub fraction: f64,
    /// Seed of the key-hash threshold predicate.
    pub seed: u64,
}

impl Default for UniverseJoin {
    fn default() -> Self {
        Self {
            fraction: 0.1,
            seed: 0x5EED_u64,
        }
    }
}

impl UniverseJoin {
    /// The shared inclusion predicate — identical on every input, which is
    /// what makes key sampling join-compatible.
    #[inline]
    pub fn key_sampled(&self, key: u64) -> bool {
        let mut st = key ^ self.seed;
        u01(splitmix64(&mut st)) < self.fraction
    }

    fn run(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        variant: JoinVariant,
    ) -> Result<JoinRun, JoinError> {
        if !variant.is_inner() {
            require_binary(self.name(), inputs.len(), variant)?;
        }
        assert!(inputs.len() >= 2);
        let p = self.fraction.clamp(f64::MIN_POSITIVE, 1.0);
        let mut s = cluster.stage("sample_inputs");
        let (sampled, n_rows) =
            centralize_sampled(cluster, &mut s, inputs, |_, _, _, key| self.key_sampled(key));
        s.add_items(n_rows);
        s.finish(cluster);

        let mut s = cluster.stage("centralized_join");
        let strata = s.task(0, || sampled_variant_strata(&sampled, op, variant));
        // per-key Horvitz-Thompson over Poisson key sampling: inclusion
        // probability p, independent across keys
        let (mut st1, mut st2, mut sc1, mut sc2, mut stc) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for agg in strata.values() {
            let (t, c) = (agg.sum, agg.population);
            st1 += t;
            st2 += t * t;
            sc1 += c;
            sc2 += c * c;
            stc += t * c;
        }
        let scale = (1.0 - p) / (p * p);
        let report = SampleFirstReport {
            method: "universe",
            fraction: p,
            est_sum: st1 / p,
            var_sum: scale * st2,
            est_count: sc1 / p,
            var_count: scale * sc2,
            cov_sum_count: scale * stc,
            samples: n_rows,
        };
        s.add_items(strata.len() as u64);
        s.finish(cluster);

        let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
        let run = JoinRun {
            strata: strata.into_iter().collect::<HashMap<_, _>>(),
            metrics,
            ledger,
            sampled: true,
            draws: HashMap::new(),
            filter_report: None,
            baseline: Some(report),
            fault_report: None,
        };
        crate::faults::finalize_run(run, cluster)
    }
}

impl JoinStrategy for UniverseJoin {
    fn name(&self) -> &'static str {
        "universe"
    }

    fn is_approximate(&self) -> bool {
        true
    }

    fn is_baseline(&self) -> bool {
        true
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        self.run(cluster, inputs, op, JoinVariant::Inner)
    }

    fn execute_variant(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        variant: JoinVariant,
    ) -> Result<JoinRun, JoinError> {
        self.run(cluster, inputs, op, variant)
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        baseline_cost(
            stats,
            cost,
            self.fraction,
            self.fraction * stats.est_output_pairs,
            "universe key sample centralized at the master",
        )
    }

    fn stage_names(&self, _n_inputs: usize) -> Vec<String> {
        vec!["sample_inputs".to_string(), "centralized_join".to_string()]
    }
}

/// Bernoulli per-row sampling baseline. Inner, binary only: an output pair
/// needs both of its rows sampled (probability q²), and a sampled row set
/// cannot certify key absence, so outer/semi/anti are refused with a typed
/// error rather than a biased answer.
#[derive(Clone, Copy, Debug)]
pub struct BernoulliJoin {
    /// Per-row inclusion probability q in (0, 1].
    pub fraction: f64,
    /// Seed of the per-row inclusion predicate.
    pub seed: u64,
}

impl Default for BernoulliJoin {
    fn default() -> Self {
        Self {
            fraction: 0.1,
            seed: 0xB0B_u64,
        }
    }
}

impl BernoulliJoin {
    /// Deterministic per-row inclusion: hashes the row's (input,
    /// partition, index) coordinates, so resampling under a different
    /// thread count keeps the identical sample.
    #[inline]
    pub fn row_sampled(&self, input: usize, part: usize, idx: usize) -> bool {
        let mut st = self.seed
            ^ ((input as u64) << 58)
            ^ ((part as u64) << 36)
            ^ (idx as u64);
        u01(splitmix64(&mut st)) < self.fraction
    }
}

impl JoinStrategy for BernoulliJoin {
    fn name(&self) -> &'static str {
        "bernoulli"
    }

    fn is_approximate(&self) -> bool {
        true
    }

    fn is_baseline(&self) -> bool {
        true
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        if inputs.len() != 2 {
            return Err(JoinError::Unsupported {
                strategy: self.name().to_string(),
                reason: format!(
                    "bernoulli baseline is a binary join: got {} inputs",
                    inputs.len()
                ),
            });
        }
        let q = self.fraction.clamp(f64::MIN_POSITIVE, 1.0);
        let mut s = cluster.stage("sample_inputs");
        let (sampled, n_rows) = centralize_sampled(cluster, &mut s, inputs, |i, j, ri, _| {
            self.row_sampled(i, j, ri)
        });
        s.add_items(n_rows);
        s.finish(cluster);

        let mut s = cluster.stage("centralized_join");
        let strata = s.task(0, || {
            sampled_variant_strata(&sampled, op, JoinVariant::Inner)
        });
        // unbiased SUM/COUNT over pair-inclusion probability q², with the
        // "Joins on Samples" covariance correction for output pairs that
        // share an input row (inclusions correlate through the shared row)
        let (lg, rg) = (by_key(&sampled[0]), by_key(&sampled[1]));
        let (mut s1, mut s2, mut c1) = (0.0, 0.0, 0.0);
        let (mut share_tt, mut share_t1, mut share_11) = (0.0, 0.0, 0.0);
        let pair_value = |l: f64, r: f64| match op {
            CombineOp::Sum => l + r,
            CombineOp::Product => l * r,
            CombineOp::Left => l,
        };
        for (k, left) in &lg {
            let Some(right) = rg.get(k) else { continue };
            let (nl, nr) = (left.len() as f64, right.len() as f64);
            c1 += nl * nr;
            // row-wise pass: totals + pairs sharing a left row
            for &lv in left {
                let (mut row_t, mut row_t2) = (0.0, 0.0);
                for &rv in right {
                    let t = pair_value(lv, rv);
                    s1 += t;
                    s2 += t * t;
                    row_t += t;
                    row_t2 += t * t;
                }
                share_tt += row_t * row_t - row_t2;
                share_t1 += row_t * nr - row_t;
                share_11 += nr * nr - nr;
            }
            // column-wise pass: pairs sharing a right row
            for &rv in right {
                let (mut col_t, mut col_t2) = (0.0, 0.0);
                for &lv in left {
                    let t = pair_value(lv, rv);
                    col_t += t;
                    col_t2 += t * t;
                }
                share_tt += col_t * col_t - col_t2;
                share_t1 += col_t * nl - col_t;
                share_11 += nl * nl - nl;
            }
        }
        let q2 = q * q;
        let q4 = q2 * q2;
        let report = SampleFirstReport {
            method: "bernoulli",
            fraction: q,
            est_sum: s1 / q2,
            var_sum: s2 * (1.0 - q2) / q4 + share_tt * (1.0 - q) / q4,
            est_count: c1 / q2,
            var_count: c1 * (1.0 - q2) / q4 + share_11 * (1.0 - q) / q4,
            cov_sum_count: s1 * (1.0 - q2) / q4 + share_t1 * (1.0 - q) / q4,
            samples: n_rows,
        };
        s.add_items(c1 as u64);
        s.finish(cluster);

        let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
        let run = JoinRun {
            strata: strata.into_iter().collect::<HashMap<_, _>>(),
            metrics,
            ledger,
            sampled: true,
            draws: HashMap::new(),
            filter_report: None,
            baseline: Some(report),
            fault_report: None,
        };
        crate::faults::finalize_run(run, cluster)
    }

    fn execute_variant(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        variant: JoinVariant,
    ) -> Result<JoinRun, JoinError> {
        if variant.is_inner() {
            self.execute(cluster, inputs, op)
        } else {
            Err(JoinError::Unsupported {
                strategy: self.name().to_string(),
                reason: format!(
                    "bernoulli row sampling cannot answer {} joins (sampled rows \
                     cannot prove a key's absence); use the universe baseline",
                    variant.tag()
                ),
            })
        }
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        baseline_cost(
            stats,
            cost,
            self.fraction,
            self.fraction * self.fraction * stats.est_output_pairs,
            "bernoulli row sample centralized at the master (pairs survive at q^2)",
        )
    }

    fn stage_names(&self, _n_inputs: usize) -> Vec<String> {
        vec!["sample_inputs".to_string(), "centralized_join".to_string()]
    }
}

/// Shared cost shape of both baselines: a fraction of every input crosses
/// the network to one node, and that node joins alone.
fn baseline_cost(
    stats: &InputStats,
    cost: &CostModel,
    fraction: f64,
    joined_pairs: f64,
    what: &str,
) -> CostEstimate {
    let k = stats.workers as f64;
    let centralize = fraction * stats.total_bytes() as f64 * (k - 1.0) / k;
    let pairs = joined_pairs + fraction * stats.total_rows() as f64;
    let mut e = CostEstimate::build(
        stats,
        cost,
        centralize,
        pairs,
        2,
        format!("{what}: {} to one worker", fmt::bytes(centralize as u64)),
    );
    e.approximate = true;
    e.baseline = true;
    // the whole sample is resident on the master
    e.peak_intermediate_bytes = fraction * stats.total_bytes() as f64;
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;
    use crate::join::native::native_join;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn ds(name: &str, recs: Vec<(u64, f64)>) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
            4,
            100,
        )
    }

    fn wide_inputs() -> Vec<Dataset> {
        let a: Vec<(u64, f64)> = (0..4000u64).map(|i| (i % 800, (i % 13) as f64)).collect();
        let b: Vec<(u64, f64)> = (0..3000u64)
            .map(|i| (i % 1000, (i % 7) as f64))
            .collect();
        vec![ds("a", a), ds("b", b)]
    }

    #[test]
    fn full_fraction_universe_matches_exact_join() {
        let ins = wide_inputs();
        let u = UniverseJoin {
            fraction: 1.0,
            seed: 1,
        };
        let run = u.execute(&mut cluster(), &ins, CombineOp::Sum).unwrap();
        let nat = native_join(&mut cluster(), &ins, CombineOp::Sum, u64::MAX).unwrap();
        let b = run.baseline.expect("baseline report");
        assert!((b.est_sum - nat.exact_sum()).abs() < 1e-6 * nat.exact_sum().abs());
        assert!((b.est_count - nat.output_cardinality()).abs() < 1e-9);
        // p = 1 leaves no sampling variance
        assert!(b.var_sum.abs() < 1e-9);
        assert!(b.var_count.abs() < 1e-9);
    }

    #[test]
    fn full_fraction_bernoulli_matches_exact_join() {
        let ins = wide_inputs();
        let bj = BernoulliJoin {
            fraction: 1.0,
            seed: 1,
        };
        let run = bj.execute(&mut cluster(), &ins, CombineOp::Sum).unwrap();
        let nat = native_join(&mut cluster(), &ins, CombineOp::Sum, u64::MAX).unwrap();
        let b = run.baseline.expect("baseline report");
        assert!((b.est_sum - nat.exact_sum()).abs() < 1e-6 * nat.exact_sum().abs());
        assert!((b.est_count - nat.output_cardinality()).abs() < 1e-9);
        assert!(b.var_sum.abs() < 1e-6);
    }

    #[test]
    fn sampling_moves_roughly_the_sampled_fraction() {
        let ins = wide_inputs();
        let u = UniverseJoin {
            fraction: 0.2,
            seed: 3,
        };
        let run = u.execute(&mut cluster(), &ins, CombineOp::Sum).unwrap();
        let moved = run.ledger.stage_bytes("sample_inputs") as f64;
        // <= total bytes * fraction * 2 slack (hash predicate noise, and
        // worker-0-local rows are free so it can also undershoot)
        let total = 7000.0 * 100.0;
        assert!(moved < total * 0.4, "moved {moved}");
        assert!(moved > total * 0.05, "moved {moved}");
        assert!(run.sampled);
        assert!(run.baseline.is_some());
    }

    #[test]
    fn universe_answers_variants_bernoulli_refuses() {
        let a = ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]);
        let b = ds("b", vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]);
        let ins = vec![a, b];
        let u = UniverseJoin {
            fraction: 1.0,
            seed: 9,
        };
        let semi = u
            .execute_variant(&mut cluster(), &ins, CombineOp::Left, JoinVariant::Semi)
            .unwrap();
        let br = semi.baseline.unwrap();
        assert!((br.est_count - 3.0).abs() < 1e-9);
        assert!((br.est_sum - 13.0).abs() < 1e-9);
        let anti = u
            .execute_variant(&mut cluster(), &ins, CombineOp::Left, JoinVariant::Anti)
            .unwrap();
        assert!((anti.baseline.unwrap().est_sum - 5.0).abs() < 1e-9);
        let fo = u
            .execute_variant(&mut cluster(), &ins, CombineOp::Sum, JoinVariant::FullOuter)
            .unwrap();
        assert!((fo.baseline.unwrap().est_sum - 729.0).abs() < 1e-9);

        let bj = BernoulliJoin::default();
        assert!(matches!(
            bj.execute_variant(&mut cluster(), &ins, CombineOp::Left, JoinVariant::Semi),
            Err(JoinError::Unsupported { .. })
        ));
    }

    #[test]
    fn report_resolves_aggregates() {
        let r = SampleFirstReport {
            method: "universe",
            fraction: 0.5,
            est_sum: 100.0,
            var_sum: 4.0,
            est_count: 50.0,
            var_count: 1.0,
            cov_sum_count: 1.5,
            samples: 10,
        };
        let sum = r.result_for(AggFunc::Sum, 0.95).unwrap();
        assert_eq!(sum.estimate, 100.0);
        assert!((sum.error_bound - z_critical(0.95) * 2.0).abs() < 1e-12);
        let avg = r.result_for(AggFunc::Avg, 0.95).unwrap();
        assert!((avg.estimate - 2.0).abs() < 1e-12);
        assert!(avg.error_bound > 0.0);
        assert!(matches!(
            r.result_for(AggFunc::Stdev, 0.95),
            Err(JoinError::Unsupported { .. })
        ));
    }

    #[test]
    fn baseline_cost_is_flagged_and_fraction_scaled() {
        let ins = wide_inputs();
        let stats = InputStats::collect(&ins, 4, &TimeModel::default());
        let cost = CostModel::default();
        let small = UniverseJoin {
            fraction: 0.1,
            seed: 0,
        }
        .estimate_cost(&stats, &cost);
        let big = UniverseJoin {
            fraction: 0.9,
            seed: 0,
        }
        .estimate_cost(&stats, &cost);
        assert!(small.baseline && big.baseline);
        assert!(small.approximate);
        assert!(small.shuffle_bytes < big.shuffle_bytes);
    }
}
