//! The [`JoinStrategy`] trait: one uniform interface over the five join
//! implementations, plus a [`StrategyRegistry`] for lookup by name and the
//! [`InputStats`] / [`CostEstimate`] machinery the [`super::planner`] uses
//! to rank strategies.
//!
//! Strategy *selection* is the part of a distributed join users should not
//! do by hand: the best strategy depends on input sizes, key overlap and
//! multiplicity skew (Fig 4/8/9 crossovers). Every strategy answers
//! [`JoinStrategy::estimate_cost`] from cheap input statistics so the
//! planner can rank candidates before moving a byte, and
//! [`JoinStrategy::execute`] runs the join through the shared
//! [`SimCluster`] substrate. New strategies are a registry entry, not a new
//! code path.

use super::approx::{approx_join, ApproxConfig, BatchAggregator, NativeAggregator, SamplingParams};
use super::bloom_join::{bloom_join, bloom_membership_join, FilterConfig, KeyProber, NativeProber};
use super::broadcast::broadcast_join;
use super::native::{native_join, DEFAULT_MEMORY_BUDGET};
use super::repartition::repartition_join;
use super::sample_first::{BernoulliJoin, UniverseJoin};
use super::{CombineOp, JoinError, JoinRun, JoinVariant};
use crate::cluster::{SimCluster, TimeModel};
use crate::cost::CostModel;
use crate::data::Dataset;
use crate::util::fmt;
use std::collections::{HashMap, HashSet};

/// Pre-join input statistics the planner feeds to `estimate_cost`.
///
/// Collection is one hashing pass over the inputs (exact key-overlap and
/// output-cardinality accounting) — far cheaper than any shuffle, and the
/// same information the paper's filtering stage derives as a side effect.
#[derive(Clone, Debug)]
pub struct InputStats {
    /// Cluster size k.
    pub workers: usize,
    /// Per-node network bandwidth (bytes/s) of the target cluster.
    pub bandwidth: f64,
    /// Per-stage scheduling latency (seconds) of the target cluster.
    pub stage_latency: f64,
    /// Records per input.
    pub rows: Vec<u64>,
    /// Wire width of one record, per input.
    pub record_bytes: Vec<u64>,
    /// Distinct join keys per input.
    pub distinct_keys: Vec<u64>,
    /// Records per input whose key appears in *every* input.
    pub participating: Vec<u64>,
    /// Join keys common to all inputs.
    pub common_keys: u64,
    /// Participating ÷ total records (the §3.1.1 overlap definition).
    pub overlap_fraction: f64,
    /// Σ B_i — the exact join-output cardinality.
    pub est_output_pairs: f64,
}

impl InputStats {
    /// Collect statistics for `inputs` on a `workers`-node cluster with
    /// the given [`TimeModel`]'s network parameters.
    pub fn collect(inputs: &[Dataset], workers: usize, time_model: &TimeModel) -> Self {
        assert!(!inputs.is_empty());
        let counts: Vec<HashMap<u64, u64>> = inputs
            .iter()
            .map(|d| {
                let mut m: HashMap<u64, u64> = HashMap::new();
                for r in d.iter() {
                    *m.entry(r.key).or_insert(0) += 1;
                }
                m
            })
            .collect();
        let mut common: HashSet<u64> = counts[0].keys().copied().collect();
        for c in &counts[1..] {
            common.retain(|k| c.contains_key(k));
        }
        let mut est_output_pairs = 0.0;
        for k in &common {
            est_output_pairs += counts.iter().map(|c| c[k] as f64).product::<f64>();
        }
        let participating: Vec<u64> = counts
            .iter()
            .map(|c| common.iter().map(|k| c[k]).sum())
            .collect();
        let rows: Vec<u64> = inputs.iter().map(|d| d.len()).collect();
        let total: u64 = rows.iter().sum();
        let participating_total: u64 = participating.iter().sum();
        Self {
            workers,
            bandwidth: time_model.bandwidth,
            stage_latency: time_model.stage_latency,
            record_bytes: inputs.iter().map(|d| d.record_bytes).collect(),
            distinct_keys: counts.iter().map(|c| c.len() as u64).collect(),
            participating,
            common_keys: common.len() as u64,
            overlap_fraction: if total == 0 {
                0.0
            } else {
                participating_total as f64 / total as f64
            },
            est_output_pairs,
            rows,
        }
    }

    pub fn n_inputs(&self) -> usize {
        self.rows.len()
    }

    pub fn total_rows(&self) -> u64 {
        self.rows.iter().sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.rows
            .iter()
            .zip(&self.record_bytes)
            .map(|(&r, &b)| r * b)
            .sum()
    }

    pub fn max_input_bytes(&self) -> u64 {
        self.rows
            .iter()
            .zip(&self.record_bytes)
            .map(|(&r, &b)| r * b)
            .max()
            .unwrap_or(0)
    }

    /// Simulated seconds to move `bytes` through the shuffle fabric: the
    /// most-loaded node carries ~in + out = 2·bytes/k at `bandwidth`.
    pub fn net_secs(&self, bytes: f64) -> f64 {
        2.0 * bytes / (self.workers as f64 * self.bandwidth)
    }

    /// These stats with the per-input vectors permuted into a new join
    /// order (`order[i]` = original position of the i-th input). The
    /// aggregate fields (common keys, overlap, output cardinality) are
    /// order-invariant and carry over unchanged.
    pub fn permuted(&self, order: &[usize]) -> Self {
        let mut s = self.clone();
        s.rows = order.iter().map(|&i| self.rows[i]).collect();
        s.record_bytes = order.iter().map(|&i| self.record_bytes[i]).collect();
        s.distinct_keys = order.iter().map(|&i| self.distinct_keys[i]).collect();
        s.participating = order.iter().map(|&i| self.participating[i]).collect();
        s
    }

    /// Record bytes a full shuffle moves: (k−1)/k of every input.
    pub fn full_shuffle_bytes(&self) -> f64 {
        let k = self.workers as f64;
        self.rows
            .iter()
            .zip(&self.record_bytes)
            .map(|(&r, &b)| r as f64 * b as f64)
            .sum::<f64>()
            * (k - 1.0)
            / k
    }
}

/// A strategy's predicted cost on one set of inputs — what the planner
/// ranks and what `JoinPlan::explain` renders.
#[derive(Clone, Debug)]
pub struct CostEstimate {
    /// Registry name of the strategy (filled in by the planner).
    pub strategy: String,
    /// Whether this strategy returns a sampled estimate.
    pub approximate: bool,
    /// Whether this strategy is a centralized sample-first baseline —
    /// never chosen by `Auto` planning, only by explicit name.
    pub baseline: bool,
    /// False when the strategy is predicted to fail on these inputs
    /// (e.g. native-join intermediates exceeding the memory budget).
    pub feasible: bool,
    /// Predicted bytes crossing the network (records + control traffic).
    pub shuffle_bytes: f64,
    /// Work items priced at β_compute: cross-product (or sampled) pairs
    /// plus strategy-specific extras (probes, materialized intermediates).
    pub compute_pairs: f64,
    /// Predicted peak per-worker intermediate materialization (bytes).
    pub peak_intermediate_bytes: f64,
    /// Predicted end-to-end latency on the modeled cluster (seconds).
    pub est_secs: f64,
    /// One-line rationale for plan explanation.
    pub note: String,
}

impl CostEstimate {
    pub(crate) fn build(
        stats: &InputStats,
        cost: &CostModel,
        shuffle_bytes: f64,
        compute_pairs: f64,
        stages: usize,
        note: String,
    ) -> Self {
        let est_secs = cost.beta_compute * compute_pairs
            + stats.net_secs(shuffle_bytes)
            + stages as f64 * stats.stage_latency
            + cost.epsilon;
        Self {
            strategy: String::new(),
            approximate: false,
            baseline: false,
            feasible: true,
            shuffle_bytes,
            compute_pairs,
            peak_intermediate_bytes: 0.0,
            est_secs,
            note,
        }
    }
}

/// One join execution strategy. All five implementations (native,
/// repartition, broadcast, bloom, approx) expose exactly this interface;
/// the [`crate::session::Session`] and the CLI reach them only through it.
pub trait JoinStrategy {
    /// Registry name (`"native"`, `"repartition"`, `"broadcast"`,
    /// `"bloom"`, `"approx"`).
    fn name(&self) -> &'static str;

    /// Run the join on the simulated cluster. Every implementation routes
    /// its per-worker loops (filter build, probing, cross products,
    /// sampling) through the cluster's partition-parallel executor
    /// ([`crate::runtime::ParallelExecutor`]) and fills the returned run's
    /// [`crate::cluster::ShuffleLedger`] with measured traffic; output is
    /// bit-identical for any thread count.
    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError>;

    /// Run a specific [`JoinVariant`]. `Inner` delegates to
    /// [`JoinStrategy::execute`] unchanged (n-way); the non-inner variants
    /// are binary joins. The default implementation resolves outer
    /// variants by running the inner join and padding each unmatched key
    /// of the padded side(s) as an exact neutral-fill stratum, and
    /// semi/anti by the exact key-set membership; the Bloom-filtering
    /// strategies override semi/anti to answer them from stage 1 alone —
    /// zero stage-2 shuffle, visible in the returned ledger.
    fn execute_variant(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        variant: JoinVariant,
    ) -> Result<JoinRun, JoinError> {
        run_variant(self, cluster, inputs, op, variant)
    }

    /// Predict this strategy's cost on inputs described by `stats`.
    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate;

    /// Whether the result is a sampled estimate rather than an exact join.
    fn is_approximate(&self) -> bool {
        false
    }

    /// Whether this strategy is a centralized sample-first baseline
    /// ("Joins on Samples") — registered for quality-vs-cost comparison,
    /// skipped by `Auto` planning.
    fn is_baseline(&self) -> bool {
        false
    }

    /// The stage names `execute` records, for plan explanation.
    fn stage_names(&self, n_inputs: usize) -> Vec<String>;
}

/// The default [`JoinStrategy::execute_variant`] body, shared so overrides
/// can fall back to it for the variants they do not specialize.
pub(crate) fn run_variant<S: JoinStrategy + ?Sized>(
    s: &S,
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    variant: JoinVariant,
) -> Result<JoinRun, JoinError> {
    match variant {
        JoinVariant::Inner => s.execute(cluster, inputs, op),
        JoinVariant::Semi | JoinVariant::Anti => {
            super::require_binary(s.name(), inputs.len(), variant)?;
            // pay the strategy's usual data movement, then reduce the run
            // to the membership answer: exact key sets decide stratum fate
            let mut run = s.execute(cluster, inputs, op)?;
            run.strata = super::exact_semi_anti_strata(inputs, op, variant);
            run.sampled = false;
            run.draws.clear();
            Ok(run)
        }
        JoinVariant::LeftOuter | JoinVariant::RightOuter | JoinVariant::FullOuter => {
            super::require_binary(s.name(), inputs.len(), variant)?;
            let mut run = s.execute(cluster, inputs, op)?;
            super::pad_outer_strata(&mut run, inputs, op, variant);
            Ok(run)
        }
    }
}

/// Native Spark RDD join: chained binary cogroups, materialized
/// intermediates, OOM risk at high overlap (Fig 9a).
pub struct NativeJoin {
    /// Per-worker memory budget for materialized intermediates.
    pub memory_budget: u64,
}

impl Default for NativeJoin {
    fn default() -> Self {
        Self {
            memory_budget: DEFAULT_MEMORY_BUDGET,
        }
    }
}

/// Bytes one materialized (key, combined value) intermediate pair costs —
/// mirrors `native_join`'s accounting (shared with the join-order
/// optimizer's per-step shuffle model in [`super::order`]).
pub(crate) const INTERMEDIATE_PAIR_BYTES: f64 = 24.0;

impl JoinStrategy for NativeJoin {
    fn name(&self) -> &'static str {
        "native"
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        native_join(cluster, inputs, op, self.memory_budget)
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        let k = stats.workers as f64;
        let n = stats.n_inputs();
        // chained binary joins materialize the prefix join after every step
        // but the last; prefix sizes follow from per-input mean multiplicity
        // over the common keys
        let mut intermediate_rows = 0.0;
        let mut peak_rows = 0.0;
        if n > 2 && stats.common_keys > 0 {
            let common = stats.common_keys as f64;
            let mult = |i: usize| stats.participating[i] as f64 / common;
            let mut prefix = common * mult(0);
            for j in 1..n {
                prefix *= mult(j);
                if j + 1 < n {
                    intermediate_rows += prefix;
                    peak_rows = peak_rows.max(prefix);
                }
            }
        }
        let shuffle = stats.full_shuffle_bytes()
            + intermediate_rows * INTERMEDIATE_PAIR_BYTES * (k - 1.0) / k;
        let pairs = stats.est_output_pairs + intermediate_rows;
        let peak = peak_rows * INTERMEDIATE_PAIR_BYTES / k;
        let mut e = CostEstimate::build(
            stats,
            cost,
            shuffle,
            pairs,
            2 * (n - 1),
            "chained binary cogroups; full shuffle, materialized intermediates".to_string(),
        );
        e.peak_intermediate_bytes = peak;
        if peak > self.memory_budget as f64 {
            e.feasible = false;
            e.note = format!(
                "predicted per-worker intermediate {} exceeds the {} memory budget",
                fmt::bytes(peak as u64),
                fmt::bytes(self.memory_budget)
            );
        }
        e
    }

    fn stage_names(&self, n_inputs: usize) -> Vec<String> {
        (0..n_inputs.saturating_sub(1))
            .flat_map(|s| [format!("shuffle_{s}"), format!("crossproduct_{s}")])
            .collect()
    }
}

/// Spark repartition join: one tagged shuffle, streamed n-way cross
/// product — the strongest exact baseline.
pub struct RepartitionJoin;

impl JoinStrategy for RepartitionJoin {
    fn name(&self) -> &'static str {
        "repartition"
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        repartition_join(cluster, inputs, op)
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        CostEstimate::build(
            stats,
            cost,
            stats.full_shuffle_bytes(),
            stats.est_output_pairs,
            2,
            "single tagged shuffle of all inputs, streamed cross product".to_string(),
        )
    }

    fn stage_names(&self, _n_inputs: usize) -> Vec<String> {
        vec!["shuffle".to_string(), "crossproduct".to_string()]
    }
}

/// Broadcast join: ship the n−1 smaller inputs to every worker; the
/// largest input never moves (eq 18).
pub struct BroadcastJoin;

impl JoinStrategy for BroadcastJoin {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        broadcast_join(cluster, inputs, op)
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        let k = stats.workers as f64;
        let small_bytes = (stats.total_bytes() - stats.max_input_bytes()) as f64;
        let mut e = CostEstimate::build(
            stats,
            cost,
            small_bytes * (k - 1.0),
            stats.est_output_pairs,
            2,
            format!(
                "ships the n-1 smaller inputs ({}) to every worker",
                fmt::bytes(small_bytes as u64)
            ),
        );
        // the replicated small inputs are resident on every worker
        e.peak_intermediate_bytes = small_bytes;
        e
    }

    fn stage_names(&self, _n_inputs: usize) -> Vec<String> {
        vec!["broadcast".to_string(), "crossproduct".to_string()]
    }
}

/// Exact Bloom join (ApproxJoin stage 1 only, §3.1): multi-way join-filter
/// construction, filtered shuffle, exact cross product.
pub struct BloomJoin {
    /// Target false-positive rate when sizing the filter (eq 27).
    pub fp_rate: f64,
    /// Explicit filter geometry; `None` sizes from the inputs.
    pub filter: Option<FilterConfig>,
}

impl Default for BloomJoin {
    fn default() -> Self {
        Self {
            fp_rate: 0.01,
            filter: None,
        }
    }
}

impl BloomJoin {
    fn filter_config(&self, inputs: &[Dataset]) -> FilterConfig {
        // explicit geometries pass through; auto-sized configs (kind-only,
        // the engine filter-kind switch) and None size from the inputs
        self.filter
            .map(|f| f.resolved(inputs, self.fp_rate))
            .unwrap_or_else(|| FilterConfig::for_inputs(inputs, self.fp_rate))
    }

    /// Predicted bytes of filter control traffic: treeReduce of n dataset
    /// filters plus the join-filter broadcast (eq 24's filter terms).
    fn filter_traffic_bytes(&self, stats: &InputStats) -> f64 {
        let k = stats.workers as f64;
        let n = stats.n_inputs() as f64;
        let max_rows = stats.rows.iter().copied().max().unwrap_or(1).max(1);
        let bits = crate::bloom::hashing::bits_for_fp_rate(max_rows, self.fp_rate);
        (bits as f64 / 8.0) * (k - 1.0) * (n + 1.0)
    }

    /// Predicted record bytes surviving the filter: participating records
    /// plus the false-positive leakage of non-participating ones.
    fn filtered_record_bytes(&self, stats: &InputStats) -> f64 {
        let k = stats.workers as f64;
        let mut bytes = 0.0;
        for i in 0..stats.n_inputs() {
            let participating = stats.participating[i] as f64;
            let leaked = (stats.rows[i] - stats.participating[i]) as f64 * self.fp_rate;
            bytes += (participating + leaked) * stats.record_bytes[i] as f64 * (k - 1.0) / k;
        }
        bytes
    }
}

impl JoinStrategy for BloomJoin {
    fn name(&self) -> &'static str {
        "bloom"
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        bloom_join(
            cluster,
            inputs,
            op,
            self.filter_config(inputs),
            &mut NativeProber,
        )
    }

    fn execute_variant(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        variant: JoinVariant,
    ) -> Result<JoinRun, JoinError> {
        if variant.membership_only() {
            super::require_binary(self.name(), inputs.len(), variant)?;
            bloom_membership_join(
                cluster,
                inputs,
                op,
                self.filter_config(inputs),
                variant,
                &mut NativeProber,
            )
        } else {
            run_variant(self, cluster, inputs, op, variant)
        }
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        let filter_bytes = self.filter_traffic_bytes(stats);
        // every record is probed once; priced like one cross-product pair
        let pairs = stats.est_output_pairs + stats.total_rows() as f64;
        CostEstimate::build(
            stats,
            cost,
            self.filtered_record_bytes(stats) + filter_bytes,
            pairs,
            3,
            format!(
                "join filter drops non-participating records pre-shuffle ({} filter traffic)",
                fmt::bytes(filter_bytes as u64)
            ),
        )
    }

    fn stage_names(&self, _n_inputs: usize) -> Vec<String> {
        vec![
            "build_filter".to_string(),
            "filter_shuffle".to_string(),
            "crossproduct".to_string(),
        ]
    }
}

/// Full ApproxJoin (§3.2-3.4): stage-1 filtering + stratified sampling
/// during the join + CLT / Horvitz-Thompson estimation.
pub struct ApproxJoin {
    /// Target false-positive rate when sizing the filter.
    pub fp_rate: f64,
    /// Explicit filter geometry; `None` sizes from the inputs.
    pub filter: Option<FilterConfig>,
    /// Sampling parameters, estimator kind and seed.
    pub config: ApproxConfig,
}

impl Default for ApproxJoin {
    fn default() -> Self {
        Self {
            fp_rate: 0.01,
            filter: None,
            config: ApproxConfig::default(),
        }
    }
}

impl ApproxJoin {
    pub fn with_config(config: ApproxConfig) -> Self {
        Self {
            config,
            ..Default::default()
        }
    }

    /// The sampling fraction the cost estimate assumes. Error-bound and
    /// fixed-per-key plans size per stratum, so a nominal 10% stands in.
    fn assumed_fraction(&self) -> f64 {
        match self.config.params {
            SamplingParams::Fraction(f) => f.min(1.0),
            SamplingParams::ErrorBound { .. } | SamplingParams::FixedPerKey(_) => 0.1,
        }
    }

    /// Execute with explicit prober / aggregator implementations — the AOT
    /// XLA executors on the production path, the native fallbacks
    /// otherwise. The trait's `execute` delegates here with the native
    /// implementations.
    pub fn execute_with(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        prober: &mut dyn KeyProber,
        aggregator: &mut dyn BatchAggregator,
    ) -> Result<JoinRun, JoinError> {
        let filter = self
            .filter
            .map(|f| f.resolved(inputs, self.fp_rate))
            .unwrap_or_else(|| FilterConfig::for_inputs(inputs, self.fp_rate));
        approx_join(cluster, inputs, op, filter, &self.config, prober, aggregator)
    }
}

impl JoinStrategy for ApproxJoin {
    fn name(&self) -> &'static str {
        "approx"
    }

    fn is_approximate(&self) -> bool {
        true
    }

    fn execute(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
    ) -> Result<JoinRun, JoinError> {
        self.execute_with(
            cluster,
            inputs,
            op,
            &mut NativeProber,
            &mut NativeAggregator::default(),
        )
    }

    fn execute_variant(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        op: CombineOp,
        variant: JoinVariant,
    ) -> Result<JoinRun, JoinError> {
        if variant.membership_only() {
            // semi/anti need no stage-2 sampling at all: the stage-1
            // membership answer is already exact
            super::require_binary(self.name(), inputs.len(), variant)?;
            let filter = self
                .filter
                .map(|f| f.resolved(inputs, self.fp_rate))
                .unwrap_or_else(|| FilterConfig::for_inputs(inputs, self.fp_rate));
            bloom_membership_join(cluster, inputs, op, filter, variant, &mut NativeProber)
        } else {
            run_variant(self, cluster, inputs, op, variant)
        }
    }

    fn estimate_cost(&self, stats: &InputStats, cost: &CostModel) -> CostEstimate {
        let bloom = BloomJoin {
            fp_rate: self.fp_rate,
            filter: self.filter,
        };
        let fraction = self.assumed_fraction();
        let pairs = fraction * stats.est_output_pairs + stats.total_rows() as f64;
        let mut e = CostEstimate::build(
            stats,
            cost,
            bloom.filtered_record_bytes(stats) + bloom.filter_traffic_bytes(stats),
            pairs,
            3,
            format!(
                "filtering + stratified sampling during the join (assumed fraction {})",
                fmt::pct(fraction)
            ),
        );
        e.approximate = true;
        e
    }

    fn stage_names(&self, _n_inputs: usize) -> Vec<String> {
        vec![
            "build_filter".to_string(),
            "filter_shuffle".to_string(),
            "sample".to_string(),
        ]
    }
}

/// Name-indexed strategy collection. The default registry holds all five
/// paper strategies; callers can register replacements or additions (a new
/// strategy is a registry entry, not a new code path).
pub struct StrategyRegistry {
    items: Vec<Box<dyn JoinStrategy>>,
}

impl StrategyRegistry {
    /// An empty registry.
    pub fn empty() -> Self {
        Self { items: Vec::new() }
    }

    /// All five paper strategies with default configurations, plus the
    /// two sample-first baselines (explicit-name only). Order is the
    /// planner's tie-break: bloom, repartition, broadcast, native, approx.
    pub fn with_defaults() -> Self {
        let mut r = Self::empty();
        r.register(Box::new(BloomJoin::default()));
        r.register(Box::new(RepartitionJoin));
        r.register(Box::new(BroadcastJoin));
        r.register(Box::new(NativeJoin::default()));
        r.register(Box::new(ApproxJoin::default()));
        r.register(Box::new(BernoulliJoin::default()));
        r.register(Box::new(UniverseJoin::default()));
        r
    }

    /// Register a strategy, replacing any existing entry with the same name.
    pub fn register(&mut self, strategy: Box<dyn JoinStrategy>) {
        if let Some(slot) = self.items.iter_mut().find(|s| s.name() == strategy.name()) {
            *slot = strategy;
        } else {
            self.items.push(strategy);
        }
    }

    pub fn get(&self, name: &str) -> Option<&dyn JoinStrategy> {
        self.items
            .iter()
            .find(|s| s.name() == name)
            .map(|b| b.as_ref())
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.items.iter().map(|s| s.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn JoinStrategy> {
        self.items.iter().map(|b| b.as_ref())
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

impl Default for StrategyRegistry {
    fn default() -> Self {
        Self::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Record;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn ds(name: &str, recs: Vec<(u64, f64)>) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
            4,
            100,
        )
    }

    fn inputs() -> Vec<Dataset> {
        vec![
            ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]),
            ds("b", vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]),
        ]
    }

    #[test]
    fn registry_defaults_and_lookup() {
        let r = StrategyRegistry::with_defaults();
        assert_eq!(r.len(), 7);
        assert_eq!(
            r.names(),
            vec![
                "bloom",
                "repartition",
                "broadcast",
                "native",
                "approx",
                "bernoulli",
                "universe"
            ]
        );
        assert!(r.get("bloom").is_some());
        assert!(r.get("hash").is_none());
        assert!(r.get("approx").unwrap().is_approximate());
        assert!(!r.get("bloom").unwrap().is_approximate());
        // the sample-first baselines are approximate AND baseline-flagged
        for name in ["bernoulli", "universe"] {
            let s = r.get(name).unwrap();
            assert!(s.is_approximate(), "{name}");
            assert!(s.is_baseline(), "{name}");
        }
        assert!(!r.get("approx").unwrap().is_baseline());
    }

    #[test]
    fn registry_register_replaces_by_name() {
        let mut r = StrategyRegistry::with_defaults();
        r.register(Box::new(NativeJoin { memory_budget: 7 }));
        assert_eq!(r.len(), 7);
        let e = r.get("native").unwrap().estimate_cost(
            &InputStats::collect(&inputs(), 4, &TimeModel::default()),
            &CostModel::default(),
        );
        // two-way joins have no intermediates, so the tiny budget is fine
        assert!(e.feasible);
    }

    #[test]
    fn approximate_flags() {
        let r = StrategyRegistry::with_defaults();
        let approx: Vec<&str> = r
            .iter()
            .filter(|s| s.is_approximate())
            .map(|s| s.name())
            .collect();
        assert_eq!(approx, vec!["approx", "bernoulli", "universe"]);
        // only the baselines carry the baseline flag
        let baselines: Vec<&str> = r
            .iter()
            .filter(|s| s.is_baseline())
            .map(|s| s.name())
            .collect();
        assert_eq!(baselines, vec!["bernoulli", "universe"]);
    }

    #[test]
    fn all_exact_strategies_agree_through_the_trait() {
        let ins = inputs();
        let r = StrategyRegistry::with_defaults();
        let mut sums = Vec::new();
        for s in r.iter().filter(|s| !s.is_approximate()) {
            let run = s.execute(&mut cluster(), &ins, CombineOp::Sum).unwrap();
            assert!(!run.sampled, "{}", s.name());
            sums.push((s.name(), run.exact_sum(), run.output_cardinality()));
        }
        // key 1: (1+100)+(2+100); key 2: (10+200)+(10+300) => 723, 4 pairs
        for (name, sum, card) in &sums {
            assert!((sum - 723.0).abs() < 1e-9, "{name}: {sum}");
            assert_eq!(*card, 4.0, "{name}");
        }
    }

    #[test]
    fn variant_execution_through_the_trait() {
        use super::super::JoinVariant as V;
        let ins = inputs();
        let r = StrategyRegistry::with_defaults();
        // every exact strategy resolves every variant to the same answer
        for s in r.iter().filter(|s| !s.is_approximate()) {
            let semi = s
                .execute_variant(&mut cluster(), &ins, CombineOp::Left, V::Semi)
                .unwrap();
            assert_eq!(semi.output_cardinality(), 3.0, "{} semi", s.name());
            assert!((semi.exact_sum() - 13.0).abs() < 1e-9, "{} semi", s.name());
            let anti = s
                .execute_variant(&mut cluster(), &ins, CombineOp::Left, V::Anti)
                .unwrap();
            assert_eq!(anti.output_cardinality(), 1.0, "{} anti", s.name());
            assert!((anti.exact_sum() - 5.0).abs() < 1e-9, "{} anti", s.name());
            // inner SUM 723; left pads a's key 3 (+5); full also pads b's
            // key 9 (+1)
            let lo = s
                .execute_variant(&mut cluster(), &ins, CombineOp::Sum, V::LeftOuter)
                .unwrap();
            assert!((lo.exact_sum() - 728.0).abs() < 1e-9, "{} louter", s.name());
            let fo = s
                .execute_variant(&mut cluster(), &ins, CombineOp::Sum, V::FullOuter)
                .unwrap();
            assert!((fo.exact_sum() - 729.0).abs() < 1e-9, "{} fouter", s.name());
            assert_eq!(fo.output_cardinality(), 6.0, "{} fouter", s.name());
            // non-inner variants are binary: typed error on 3 inputs
            let three = vec![ins[0].clone(), ins[1].clone(), ins[0].clone()];
            assert!(matches!(
                s.execute_variant(&mut cluster(), &three, CombineOp::Sum, V::Semi),
                Err(JoinError::Unsupported { .. })
            ));
        }
        // the Bloom path answers semi/anti from stage 1: a membership
        // stage replaces filter_shuffle + crossproduct entirely
        for name in ["bloom", "approx"] {
            let run = r
                .get(name)
                .unwrap()
                .execute_variant(&mut cluster(), &ins, CombineOp::Left, V::Semi)
                .unwrap();
            assert!(!run.sampled, "{name}");
            let stages: Vec<&str> =
                run.ledger.stages.iter().map(|s| s.stage.as_str()).collect();
            assert!(stages.contains(&"membership"), "{name}: {stages:?}");
            for gone in ["filter_shuffle", "crossproduct", "sample", "shuffle"] {
                assert!(!stages.contains(&gone), "{name} still runs {gone}");
            }
        }
    }

    #[test]
    fn ledger_is_populated_through_the_trait() {
        let ins = inputs();
        let r = StrategyRegistry::with_defaults();
        for s in r.iter() {
            let run = s.execute(&mut cluster(), &ins, CombineOp::Sum).unwrap();
            assert!(!run.ledger.stages.is_empty(), "{}", s.name());
            assert_eq!(
                run.measured_shuffle_bytes(),
                run.metrics.total_shuffled_bytes(),
                "{}: ledger and metrics disagree",
                s.name()
            );
        }
    }

    #[test]
    fn input_stats_exact_accounting() {
        let stats = InputStats::collect(&inputs(), 4, &TimeModel::default());
        assert_eq!(stats.n_inputs(), 2);
        assert_eq!(stats.rows, vec![4, 4]);
        assert_eq!(stats.common_keys, 2); // keys 1 and 2
        assert_eq!(stats.participating, vec![3, 3]);
        // key 1: 2x1, key 2: 1x2 => 4 output pairs
        assert_eq!(stats.est_output_pairs, 4.0);
        assert!((stats.overlap_fraction - 6.0 / 8.0).abs() < 1e-12);
        assert_eq!(stats.total_bytes(), 800);
    }

    #[test]
    fn native_estimate_flags_oom_on_deep_multiway() {
        // three-way with deep strata: ~100 * 100 = 10k intermediate rows/key
        let a = ds("a", (0..100).map(|_| (1, 1.0)).collect());
        let b = ds("b", (0..100).map(|_| (1, 1.0)).collect());
        let c = ds("c", vec![(1, 1.0)]);
        let stats = InputStats::collect(&[a, b, c], 4, &TimeModel::default());
        let tight = NativeJoin { memory_budget: 1000 };
        let e = tight.estimate_cost(&stats, &CostModel::default());
        assert!(!e.feasible, "{}", e.note);
        assert!(e.note.contains("memory budget"));
        let roomy = NativeJoin {
            memory_budget: u64::MAX,
        };
        assert!(roomy.estimate_cost(&stats, &CostModel::default()).feasible);
    }

    #[test]
    fn bloom_estimate_beats_repartition_at_low_overlap_only() {
        // low overlap: 2 of 2000 keys shared; high overlap: all shared
        let mk = |shared: u64| -> Vec<Dataset> {
            let a: Vec<(u64, f64)> = (0..2000u64)
                .map(|i| (if i < shared { i } else { i + 10_000 }, 1.0))
                .collect();
            let b: Vec<(u64, f64)> = (0..2000u64)
                .map(|i| (if i < shared { i } else { i + 20_000 }, 1.0))
                .collect();
            vec![ds("a", a), ds("b", b)]
        };
        let slow_net = TimeModel {
            bandwidth: 1e6,
            stage_latency: 0.0,
            compute_scale: 1.0,
        };
        let cost = CostModel::default();
        let low = InputStats::collect(&mk(20), 4, &slow_net);
        let high = InputStats::collect(&mk(2000), 4, &slow_net);
        let bloom = BloomJoin::default();
        let rep = RepartitionJoin;
        assert!(
            bloom.estimate_cost(&low, &cost).est_secs < rep.estimate_cost(&low, &cost).est_secs
        );
        assert!(
            bloom.estimate_cost(&high, &cost).est_secs > rep.estimate_cost(&high, &cost).est_secs
        );
    }
}
