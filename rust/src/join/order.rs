//! Join-order optimization: Selinger-style dynamic programming for small
//! relation counts, a greedy min-cost heuristic above, both driven by a
//! cardinality estimator that *learns* per-(table-pair, predicate-tag)
//! selectivities from measured executions via the [`FeedbackStore`].
//!
//! The planner has always ranked the five *strategies* over a fixed chain;
//! this module is the first pass that rewrites the chain itself. For 3+
//! relations the order dominates shuffle volume: chained binary joins
//! materialize and shuffle every intermediate prefix, so putting the
//! selective pairs first shrinks every downstream stage.
//!
//! Determinism contract: [`plan_query_order`] is a **pure function of
//! (tables, join clauses, per-table statistics, feedback snapshot)** — no
//! wall clock, no randomness, no thread-count dependence. DP iterates
//! masks and candidate tables in ascending order with strict-improvement
//! updates; greedy breaks ties lexicographically. Two calls with the same
//! inputs return the same permutation, so the 1/2/8-thread bit-identity
//! suites hold with ordering enabled by default.
//!
//! Calibration closes the predicted-vs-measured loop that `explain()`
//! already displays: after a run, [`calibrate`] records the *exact*
//! pairwise join selectivities (one counting pass, same machinery as
//! [`InputStats::collect`]) and the measured/predicted shuffle-byte ratio
//! under `joinsel:`/`joinbytes:` fingerprints in the same persistent
//! [`FeedbackStore`] the §3.2 sigma feedback uses. The next plan of the
//! same query shape sees them and can change its mind — and only then.

use super::join_graph::JoinGraph;
use super::strategy::{InputStats, INTERMEDIATE_PAIR_BYTES};
use crate::cost::FeedbackStore;
use crate::data::Dataset;
use crate::util::fmt;
use std::collections::HashMap;

/// Largest relation count the exhaustive left-deep DP enumerates;
/// above this the greedy heuristic takes over (DP is O(2^n · n^2)).
pub const DP_MAX_TABLES: usize = 8;

/// Per-relation statistics the order optimizer consumes — a projection of
/// [`InputStats`] onto one input, or collected directly from a dataset.
#[derive(Clone, Debug)]
pub struct TableStats {
    pub name: String,
    pub rows: f64,
    pub record_bytes: f64,
    pub distinct_keys: f64,
}

impl TableStats {
    /// Split an already-collected [`InputStats`] into per-table stats.
    pub fn from_input_stats(stats: &InputStats, tables: &[String]) -> Vec<TableStats> {
        (0..stats.n_inputs())
            .map(|i| TableStats {
                name: tables.get(i).cloned().unwrap_or_else(|| format!("r{i}")),
                rows: stats.rows[i] as f64,
                record_bytes: stats.record_bytes[i] as f64,
                distinct_keys: stats.distinct_keys[i] as f64,
            })
            .collect()
    }

    /// One pass per dataset: rows, wire width, distinct join keys.
    pub fn collect(inputs: &[Dataset], tables: &[String]) -> Vec<TableStats> {
        inputs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let mut keys = std::collections::HashSet::new();
                for r in d.iter() {
                    keys.insert(r.key);
                }
                TableStats {
                    name: tables.get(i).cloned().unwrap_or_else(|| format!("r{i}")),
                    rows: d.len() as f64,
                    record_bytes: d.record_bytes as f64,
                    distinct_keys: keys.len() as f64,
                }
            })
            .collect()
    }
}

/// Feedback fingerprint for the learned selectivity of one table pair
/// under one predicate tag. Symmetric and case-insensitive (sorted,
/// lowercased pair), so `a⋈b` and `b⋈a` share one entry.
pub fn pair_fingerprint(a: &str, b: &str, tag: &str) -> String {
    let (a, b) = (a.to_ascii_lowercase(), b.to_ascii_lowercase());
    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
    format!("joinsel:{lo}|{hi}:{tag}")
}

/// Feedback fingerprint for the measured/predicted shuffle-byte ratio of
/// one query shape (predicate tag).
pub fn bytes_fingerprint(tag: &str) -> String {
    format!("joinbytes:{tag}")
}

/// Slot within a feedback fingerprint where scalar calibration values live.
const CALIBRATION_SLOT: u64 = 0;

/// Cardinality estimator: per-pair selectivity, feedback-calibrated.
///
/// Cold (nothing learned), the classic containment assumption:
/// `sel(a, b) = 1 / max(distinct_a, distinct_b)` — exact under uniform
/// per-key multiplicity with full containment, and the standard default
/// when nothing better is known. Once [`calibrate`] has recorded a
/// measured selectivity for the pair under this predicate tag, the learned
/// value wins.
pub struct CardinalityEstimator<'a> {
    feedback: Option<&'a FeedbackStore>,
    tag: &'a str,
}

impl<'a> CardinalityEstimator<'a> {
    pub fn new(feedback: Option<&'a FeedbackStore>, tag: &'a str) -> Self {
        Self { feedback, tag }
    }

    /// `(selectivity, learned)` for joining `a` with `b` on the equi-join
    /// attribute; `learned` is true when the value came from feedback.
    pub fn selectivity(&self, a: &TableStats, b: &TableStats) -> (f64, bool) {
        if let Some(fb) = self.feedback {
            if let Some(v) = fb.value(&pair_fingerprint(&a.name, &b.name, self.tag), CALIBRATION_SLOT)
            {
                return (v.clamp(0.0, 1.0), true);
            }
        }
        (1.0 / a.distinct_keys.max(b.distinct_keys).max(1.0), false)
    }

    /// Multiplier on predicted shuffle bytes, learned from the measured /
    /// predicted ratio of past runs (1.0 cold).
    pub fn byte_scale(&self) -> f64 {
        self.feedback
            .and_then(|fb| fb.value(&bytes_fingerprint(self.tag), CALIBRATION_SLOT))
            .unwrap_or(1.0)
    }
}

/// One join step of a chosen order: which table joins in, the predicted
/// cumulative cardinality after the step, and (after execution) the
/// measured one.
#[derive(Clone, Debug)]
pub struct OrderStep {
    pub table: String,
    /// Predicted cumulative join cardinality after this step (for step 0,
    /// the base table's row count).
    pub predicted_rows: f64,
    /// Exact cumulative cardinality measured after execution.
    pub measured_rows: Option<f64>,
    /// Whether a feedback-learned selectivity drove this step's prediction.
    pub calibrated: bool,
}

/// Multi-objective cost of one join order.
#[derive(Clone, Copy, Debug, Default)]
pub struct OrderCost {
    /// Σ intermediate cardinalities (rows flowing between join steps).
    pub rows: f64,
    /// Cross-product pairs priced at β_compute.
    pub cpu: f64,
    /// Bytes of materialized intermediates.
    pub io: f64,
    /// Predicted shuffle bytes: the full input shuffle plus every
    /// non-final intermediate, scaled by the learned byte ratio.
    pub shuffle_bytes: f64,
}

/// The optimizer's decision, surfaced through `JoinPlan::explain()`,
/// `QueryOutcome::join_order`, and the CLI.
#[derive(Clone, Debug)]
pub struct JoinOrderReport {
    /// Chosen permutation of FROM positions (`order[0]` joins first).
    pub order: Vec<usize>,
    /// Table names in chosen order.
    pub tables: Vec<String>,
    /// `"dp"`, `"greedy"`, or `"from"` (identity kept by the guard).
    pub algorithm: String,
    /// True when the chosen order differs from the FROM order.
    pub reordered: bool,
    pub steps: Vec<OrderStep>,
    /// Predicted cost of the chosen order.
    pub cost: OrderCost,
    /// Predicted cost of the naive FROM order, for comparison.
    pub from_cost: OrderCost,
}

impl JoinOrderReport {
    /// Whether the FROM order was kept (either because it was already
    /// optimal or because no strictly better order was predicted).
    pub fn is_identity(&self) -> bool {
        self.order.iter().enumerate().all(|(i, &p)| i == p)
    }

    /// Fill per-step measured cardinalities (`measured[i]` is the exact
    /// cumulative cardinality after join step `i+1`, as returned by
    /// [`measure_step_cardinalities`] on the *reordered* inputs).
    pub fn set_measured(&mut self, measured: &[f64]) {
        for (i, m) in measured.iter().enumerate() {
            if let Some(s) = self.steps.get_mut(i + 1) {
                s.measured_rows = Some(*m);
            }
        }
    }

    /// One-line rendering for CLI output.
    pub fn render_inline(&self) -> String {
        format!(
            "{} [{}{}]",
            self.tables.join(" > "),
            self.algorithm,
            if self.reordered { ", reordered" } else { "" }
        )
    }

    /// Multi-line rendering for `explain()`.
    pub fn render(&self) -> Vec<String> {
        let mut out = Vec::new();
        out.push(format!(
            "join order: {}   ({}{})",
            self.tables.join(" > "),
            self.algorithm,
            if self.reordered {
                ", reordered from FROM order"
            } else {
                ", FROM order kept"
            }
        ));
        out.push(format!(
            "  predicted shuffle {} vs FROM-order {}  (cpu {:.0} vs {:.0} pairs)",
            fmt::bytes(self.cost.shuffle_bytes as u64),
            fmt::bytes(self.from_cost.shuffle_bytes as u64),
            self.cost.cpu,
            self.from_cost.cpu,
        ));
        for (i, s) in self.steps.iter().enumerate() {
            let role = if i == 0 { "base" } else { "join" };
            let measured = match s.measured_rows {
                Some(m) => format!("   measured {m:.0} rows"),
                None => String::new(),
            };
            out.push(format!(
                "  step {i}: {role} {:<12} predicted {:.0} rows{}{}",
                s.table,
                s.predicted_rows,
                measured,
                if s.calibrated { "   [calibrated]" } else { "" },
            ));
        }
        out
    }
}

/// Everything the optimizer needs besides the per-table stats.
pub struct OrderContext<'a> {
    /// Feedback snapshot for learned selectivities (`None` → cold).
    pub feedback: Option<&'a FeedbackStore>,
    /// Predicate tag scoping the learned values (same tag the sketch
    /// cache uses, so pushed predicates never alias calibrations).
    pub predicate_tag: String,
    /// β_compute of the engine's cost model.
    pub beta_compute: f64,
    pub workers: usize,
    pub bandwidth: f64,
    /// `EngineConfig::reorder_joins`; disabled → [`plan_query_order`]
    /// returns `None` and execution keeps the FROM order untouched.
    pub enabled: bool,
}

struct OrderPlanner<'a> {
    graph: &'a JoinGraph,
    stats: &'a [TableStats],
    est: CardinalityEstimator<'a>,
    ctx: &'a OrderContext<'a>,
}

impl<'a> OrderPlanner<'a> {
    /// Evaluate one complete order: multi-objective cost + per-step trace.
    fn evaluate(&self, order: &[usize]) -> (OrderCost, Vec<OrderStep>) {
        let k = self.ctx.workers.max(1) as f64;
        let n = order.len();
        // every input crosses the fabric once regardless of order
        let mut shuffle: f64 = self
            .stats
            .iter()
            .map(|t| t.rows * t.record_bytes)
            .sum::<f64>()
            * (k - 1.0)
            / k;
        let mut steps = vec![OrderStep {
            table: self.stats[order[0]].name.clone(),
            predicted_rows: self.stats[order[0]].rows,
            measured_rows: None,
            calibrated: false,
        }];
        let mut prefix_rows = self.stats[order[0]].rows;
        let (mut rows, mut cpu, mut io) = (0.0f64, 0.0f64, 0.0f64);
        for step in 1..n {
            let t = order[step];
            // tightest selectivity over edges into the already-joined set
            let mut sel = 1.0;
            let mut any = false;
            let mut calibrated = false;
            for &j in &order[..step] {
                if self.graph.adjacent(j, t) {
                    let (s, learned) = self.est.selectivity(&self.stats[j], &self.stats[t]);
                    if !any || s < sel {
                        sel = s;
                        calibrated = learned;
                    }
                    any = true;
                }
            }
            prefix_rows = (prefix_rows * self.stats[t].rows * sel).max(0.0);
            rows += prefix_rows;
            cpu += prefix_rows;
            if step + 1 < n {
                io += prefix_rows * INTERMEDIATE_PAIR_BYTES;
                shuffle += prefix_rows * INTERMEDIATE_PAIR_BYTES * (k - 1.0) / k;
            }
            steps.push(OrderStep {
                table: self.stats[t].name.clone(),
                predicted_rows: prefix_rows,
                measured_rows: None,
                calibrated,
            });
        }
        let scale = self.est.byte_scale();
        (
            OrderCost {
                rows,
                cpu,
                io,
                shuffle_bytes: shuffle * scale,
            },
            steps,
        )
    }

    fn cost_of(&self, order: &[usize]) -> OrderCost {
        self.evaluate(order).0
    }

    /// Collapse a multi-objective cost to simulated seconds for ranking.
    fn scalar_secs(&self, c: &OrderCost) -> f64 {
        self.ctx.beta_compute * c.cpu
            + 2.0 * c.shuffle_bytes / (self.ctx.workers.max(1) as f64 * self.ctx.bandwidth.max(1.0))
    }

    /// Like [`Self::evaluate`] but charging the final step's intermediate
    /// too — the monotone partial objective the DP compares prefixes with
    /// (a prefix that will be extended shuffles *all* its intermediates).
    fn partial_secs(&self, order: &[usize]) -> f64 {
        let k = self.ctx.workers.max(1) as f64;
        let mut prefix_rows = self.stats[order[0]].rows;
        let (mut cpu, mut shuffle) = (0.0f64, 0.0f64);
        for step in 1..order.len() {
            let t = order[step];
            let mut sel = 1.0;
            let mut any = false;
            for &j in &order[..step] {
                if self.graph.adjacent(j, t) {
                    let (s, _) = self.est.selectivity(&self.stats[j], &self.stats[t]);
                    if !any || s < sel {
                        sel = s;
                    }
                    any = true;
                }
            }
            prefix_rows = (prefix_rows * self.stats[t].rows * sel).max(0.0);
            cpu += prefix_rows;
            shuffle += prefix_rows * INTERMEDIATE_PAIR_BYTES * (k - 1.0) / k;
        }
        self.ctx.beta_compute * cpu
            + 2.0 * shuffle / (k * self.ctx.bandwidth.max(1.0))
    }

    /// Exhaustive left-deep DP over connected subsets (Selinger).
    /// Deterministic: masks ascending, candidates ascending, strict `<`
    /// improvement. Cross-product-free — a table only extends a prefix it
    /// shares a join edge with. Falls back to the identity order if the
    /// graph leaves the full set unreachable (disconnected input, which
    /// the parser rejects anyway).
    fn dp_order(&self) -> Vec<usize> {
        let n = self.stats.len();
        let full: usize = (1usize << n) - 1;
        let mut best: Vec<Option<(f64, Vec<usize>)>> = vec![None; 1usize << n];
        for i in 0..n {
            best[1usize << i] = Some((0.0, vec![i]));
        }
        for mask in 1..=full {
            let Some(entry) = best[mask].clone() else {
                continue;
            };
            let order = entry.1;
            for t in 0..n {
                if mask & (1usize << t) != 0 {
                    continue;
                }
                if !order.iter().any(|&j| self.graph.adjacent(j, t)) {
                    continue;
                }
                let mut next = order.clone();
                next.push(t);
                let nm = mask | (1usize << t);
                let secs = if nm == full {
                    self.scalar_secs(&self.cost_of(&next))
                } else {
                    self.partial_secs(&next)
                };
                let better = match &best[nm] {
                    Some((b, _)) => secs < *b,
                    None => true,
                };
                if better {
                    best[nm] = Some((secs, next));
                }
            }
        }
        best[full]
            .clone()
            .map(|(_, o)| o)
            .unwrap_or_else(|| (0..n).collect())
    }

    /// Greedy min-cost heuristic for n > [`DP_MAX_TABLES`]: start from the
    /// cheapest two-table join (lexicographic tie-break), then repeatedly
    /// append the adjacent table minimizing the partial objective
    /// (smallest-index tie-break). Disconnected leftovers (cannot happen
    /// through the parser) append in index order.
    fn greedy_order(&self) -> Vec<usize> {
        let n = self.stats.len();
        if n < 2 {
            return (0..n).collect();
        }
        let mut start: Option<(f64, usize, usize)> = None;
        for i in 0..n {
            for j in (i + 1)..n {
                if !self.graph.adjacent(i, j) {
                    continue;
                }
                let secs = self.partial_secs(&[i, j]);
                let better = match start {
                    Some((b, _, _)) => secs < b,
                    None => true,
                };
                if better {
                    start = Some((secs, i, j));
                }
            }
        }
        let mut order = match start {
            Some((_, i, j)) => vec![i, j],
            None => vec![0],
        };
        while order.len() < n {
            let mut pick: Option<(f64, usize)> = None;
            for t in 0..n {
                if order.contains(&t) {
                    continue;
                }
                if !order.iter().any(|&j| self.graph.adjacent(j, t)) {
                    continue;
                }
                let mut cand = order.clone();
                cand.push(t);
                let secs = self.partial_secs(&cand);
                let better = match pick {
                    Some((b, _)) => secs < b,
                    None => true,
                };
                if better {
                    pick = Some((secs, t));
                }
            }
            match pick {
                Some((_, t)) => order.push(t),
                None => {
                    // disconnected leftover: append smallest remaining
                    let t = (0..n).find(|t| !order.contains(t)).unwrap();
                    order.push(t);
                }
            }
        }
        order
    }

    /// Choose the order: DP for n ≤ [`DP_MAX_TABLES`], greedy above, then
    /// a never-worse-than-FROM guard — the candidate replaces the identity
    /// only when its predicted scalar cost is *strictly* lower.
    fn plan(&self, algo: Algorithm) -> JoinOrderReport {
        let n = self.stats.len();
        let identity: Vec<usize> = (0..n).collect();
        let from_cost = self.cost_of(&identity);
        let use_dp = match algo {
            Algorithm::Dp => true,
            Algorithm::Greedy => false,
            Algorithm::Auto => n <= DP_MAX_TABLES,
        };
        let (candidate, algorithm) = if use_dp {
            (self.dp_order(), "dp")
        } else {
            (self.greedy_order(), "greedy")
        };
        let use_candidate = candidate != identity
            && self.scalar_secs(&self.cost_of(&candidate)) < self.scalar_secs(&from_cost);
        let (order, algorithm) = if use_candidate {
            (candidate, algorithm.to_string())
        } else {
            // keep the FROM order but still report which search ran
            (identity, algorithm.to_string())
        };
        let (cost, steps) = self.evaluate(&order);
        let reordered = order.iter().enumerate().any(|(i, &p)| i != p);
        JoinOrderReport {
            tables: order.iter().map(|&i| self.stats[i].name.clone()).collect(),
            order,
            algorithm,
            reordered,
            steps,
            cost,
            from_cost,
        }
    }
}

/// Which search [`plan_query_order_with`] runs. `Auto` — what
/// [`plan_query_order`] uses — picks DP up to [`DP_MAX_TABLES`] relations
/// and greedy above. Forcing one lets tests and the CI cost-accuracy gate
/// cross-check the two searches on the same inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Auto,
    Dp,
    Greedy,
}

/// Plan a join order for one query. Returns `None` when ordering is
/// skipped entirely — disabled by config, fewer than three relations
/// (binary joins have one order up to the combine semantics), or a
/// non-commutative combine op (`CombineOp::Left` takes the *first*
/// input's value, so permuting would change answers). A `Some` report
/// with `reordered == false` means the optimizer ran and kept the FROM
/// order.
///
/// Pure function of its arguments — see the module docs' determinism
/// contract.
pub fn plan_query_order(
    tables: &[String],
    clauses: &[Vec<String>],
    commutative: bool,
    stats: &[TableStats],
    ctx: &OrderContext,
) -> Option<JoinOrderReport> {
    plan_query_order_with(tables, clauses, commutative, stats, ctx, Algorithm::Auto)
}

/// [`plan_query_order`] with the search algorithm forced.
pub fn plan_query_order_with(
    tables: &[String],
    clauses: &[Vec<String>],
    commutative: bool,
    stats: &[TableStats],
    ctx: &OrderContext,
    algo: Algorithm,
) -> Option<JoinOrderReport> {
    if !ctx.enabled || tables.len() < 3 || !commutative || stats.len() != tables.len() {
        return None;
    }
    let graph = JoinGraph::build(tables, clauses);
    let est = CardinalityEstimator::new(ctx.feedback, &ctx.predicate_tag);
    let planner = OrderPlanner {
        graph: &graph,
        stats,
        est,
        ctx,
    };
    Some(planner.plan(algo))
}

/// Apply a permutation: `out[i] = items[order[i]]`.
pub fn permute<T: Clone>(items: &[T], order: &[usize]) -> Vec<T> {
    order.iter().map(|&i| items[i].clone()).collect()
}

/// Exact cumulative join cardinality after each chained step, in the
/// given input order: entry `i` is `Σ_key Π_{j ≤ i+1} count_j(key)`.
/// One counting pass per input — the measured twin of the optimizer's
/// per-step predictions.
pub fn measure_step_cardinalities(inputs: &[Dataset]) -> Vec<f64> {
    if inputs.len() < 2 {
        return Vec::new();
    }
    let counts: Vec<HashMap<u64, f64>> = inputs
        .iter()
        .map(|d| {
            let mut m: HashMap<u64, f64> = HashMap::new();
            for r in d.iter() {
                *m.entry(r.key).or_insert(0.0) += 1.0;
            }
            m
        })
        .collect();
    let mut prefix = counts[0].clone();
    let mut out = Vec::new();
    for c in &counts[1..] {
        let mut next: HashMap<u64, f64> = HashMap::new();
        for (k, v) in &prefix {
            if let Some(w) = c.get(k) {
                next.insert(*k, v * w);
            }
        }
        out.push(next.values().sum());
        prefix = next;
    }
    out
}

/// Close the loop after a run: record the **exact** pairwise selectivities
/// of this execution's inputs and the measured/predicted shuffle-byte
/// ratio (clamped to [0.25, 4] so one outlier run cannot swing future
/// plans wildly) into the feedback store under this predicate tag.
/// `tables`/`inputs` are in *execution* order; pair fingerprints are
/// symmetric so the order does not matter.
pub fn calibrate(
    feedback: &mut FeedbackStore,
    tag: &str,
    tables: &[String],
    inputs: &[Dataset],
    predicted_shuffle_bytes: f64,
    measured_shuffle_bytes: f64,
) {
    let counts: Vec<HashMap<u64, f64>> = inputs
        .iter()
        .map(|d| {
            let mut m: HashMap<u64, f64> = HashMap::new();
            for r in d.iter() {
                *m.entry(r.key).or_insert(0.0) += 1.0;
            }
            m
        })
        .collect();
    let rows: Vec<f64> = inputs.iter().map(|d| d.len() as f64).collect();
    for i in 0..inputs.len().min(tables.len()) {
        for j in (i + 1)..inputs.len().min(tables.len()) {
            if tables[i].eq_ignore_ascii_case(&tables[j]) {
                continue; // self-join pair: selectivity of a table with itself
            }
            let pairs: f64 = counts[i]
                .iter()
                .map(|(k, c)| c * counts[j].get(k).copied().unwrap_or(0.0))
                .sum();
            let denom = rows[i] * rows[j];
            if denom > 0.0 {
                feedback.record_value(
                    &pair_fingerprint(&tables[i], &tables[j], tag),
                    CALIBRATION_SLOT,
                    (pairs / denom).clamp(0.0, 1.0),
                );
            }
        }
    }
    if predicted_shuffle_bytes > 0.0 && measured_shuffle_bytes > 0.0 {
        feedback.record_value(
            &bytes_fingerprint(tag),
            CALIBRATION_SLOT,
            (measured_shuffle_bytes / predicted_shuffle_bytes).clamp(0.25, 4.0),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Record};

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn ts(name: &str, rows: f64, distinct: f64) -> TableStats {
        TableStats {
            name: name.into(),
            rows,
            record_bytes: 100.0,
            distinct_keys: distinct,
        }
    }

    fn ctx<'a>(feedback: Option<&'a FeedbackStore>) -> OrderContext<'a> {
        OrderContext {
            feedback,
            predicate_tag: String::new(),
            beta_compute: 1e-7,
            workers: 4,
            bandwidth: 1e9,
            enabled: true,
        }
    }

    fn chain_clauses(tables: &[&str]) -> Vec<Vec<String>> {
        tables
            .windows(2)
            .map(|w| names(&[w[0], w[1]]))
            .collect()
    }

    #[test]
    fn dp_puts_small_tables_first_on_adversarial_from_order() {
        // FROM order joins the two largest first; DP should lead with the
        // small end of the chain
        let tables = names(&["big1", "big2", "mid", "tiny"]);
        let clauses = chain_clauses(&["big1", "big2", "mid", "tiny"]);
        let stats = vec![
            ts("big1", 10_000.0, 100.0),
            ts("big2", 10_000.0, 100.0),
            ts("mid", 1_000.0, 100.0),
            ts("tiny", 100.0, 100.0),
        ];
        let c = ctx(None);
        let r = plan_query_order(&tables, &clauses, true, &stats, &c).unwrap();
        assert!(r.reordered, "{:?}", r.order);
        assert_eq!(r.algorithm, "dp");
        assert!(r.cost.shuffle_bytes < r.from_cost.shuffle_bytes);
        // the chain must still be walked edge-by-edge (no cross products):
        // tiny > mid > big2 > big1 is the unique cheapest left-deep walk
        assert_eq!(r.tables, names(&["tiny", "mid", "big2", "big1"]));
    }

    #[test]
    fn identity_kept_when_from_order_is_optimal() {
        let tables = names(&["tiny", "mid", "big"]);
        let clauses = chain_clauses(&["tiny", "mid", "big"]);
        let stats = vec![
            ts("tiny", 10.0, 10.0),
            ts("mid", 100.0, 10.0),
            ts("big", 1_000.0, 10.0),
        ];
        let c = ctx(None);
        let r = plan_query_order(&tables, &clauses, true, &stats, &c).unwrap();
        assert!(!r.reordered);
        assert!(r.is_identity());
        assert_eq!(r.cost.shuffle_bytes, r.from_cost.shuffle_bytes);
    }

    #[test]
    fn skipped_when_disabled_small_or_noncommutative() {
        let tables = names(&["a", "b", "c"]);
        let clauses = chain_clauses(&["a", "b", "c"]);
        let stats = vec![ts("a", 10.0, 5.0), ts("b", 10.0, 5.0), ts("c", 10.0, 5.0)];
        let mut c = ctx(None);
        c.enabled = false;
        assert!(plan_query_order(&tables, &clauses, true, &stats, &c).is_none());
        let c = ctx(None);
        assert!(plan_query_order(&tables, &clauses, false, &stats, &c).is_none());
        assert!(plan_query_order(
            &names(&["a", "b"]),
            &[],
            true,
            &stats[..2],
            &c
        )
        .is_none());
    }

    #[test]
    fn planning_is_deterministic() {
        let tables = names(&["w", "x", "y", "z"]);
        let clauses = chain_clauses(&["w", "x", "y", "z"]);
        let stats = vec![
            ts("w", 5_000.0, 50.0),
            ts("x", 700.0, 50.0),
            ts("y", 9_000.0, 50.0),
            ts("z", 40.0, 40.0),
        ];
        let c = ctx(None);
        let a = plan_query_order(&tables, &clauses, true, &stats, &c).unwrap();
        let b = plan_query_order(&tables, &clauses, true, &stats, &c).unwrap();
        assert_eq!(a.order, b.order);
        assert_eq!(a.cost.shuffle_bytes, b.cost.shuffle_bytes);
    }

    #[test]
    fn feedback_overrides_default_selectivity() {
        let a = ts("a", 100.0, 50.0);
        let b = ts("b", 100.0, 50.0);
        let cold = CardinalityEstimator::new(None, "");
        let (s, learned) = cold.selectivity(&a, &b);
        assert!(!learned);
        assert!((s - 1.0 / 50.0).abs() < 1e-12);

        let mut fb = FeedbackStore::in_memory();
        fb.record_value(&pair_fingerprint("a", "b", ""), 0, 0.5);
        let warm = CardinalityEstimator::new(Some(&fb), "");
        let (s, learned) = warm.selectivity(&a, &b);
        assert!(learned);
        assert_eq!(s, 0.5);
        // symmetric + case-insensitive lookup
        let (s2, _) = warm.selectivity(&b, &a);
        assert_eq!(s2, 0.5);
        assert_eq!(
            pair_fingerprint("B", "a", "t"),
            pair_fingerprint("a", "b", "t")
        );
    }

    #[test]
    fn measured_cardinalities_and_calibration_roundtrip() {
        let ds = |name: &str, recs: Vec<(u64, f64)>| {
            Dataset::from_records_unpartitioned(
                name,
                recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
                2,
                100,
            )
        };
        let a = ds("a", vec![(1, 1.0), (1, 1.0), (2, 1.0)]);
        let b = ds("b", vec![(1, 1.0), (2, 1.0), (2, 1.0)]);
        let c = ds("c", vec![(2, 1.0), (3, 1.0)]);
        let inputs = vec![a, b, c];
        // a⋈b: key1 2·1 + key2 1·2 = 4; (a⋈b)⋈c: key2 2·1 = 2
        let m = measure_step_cardinalities(&inputs);
        assert_eq!(m, vec![4.0, 2.0]);

        let mut fb = FeedbackStore::in_memory();
        calibrate(&mut fb, "", &names(&["a", "b", "c"]), &inputs, 1000.0, 500.0);
        let sel_ab = fb.value(&pair_fingerprint("a", "b", ""), 0).unwrap();
        assert!((sel_ab - 4.0 / 9.0).abs() < 1e-12);
        let sel_bc = fb.value(&pair_fingerprint("b", "c", ""), 0).unwrap();
        assert!((sel_bc - 2.0 / 6.0).abs() < 1e-12);
        assert_eq!(fb.value(&bytes_fingerprint(""), 0), Some(0.5));

        // ratio clamped so one outlier cannot swing future plans
        calibrate(&mut fb, "", &names(&["a", "b", "c"]), &inputs, 1.0, 1e9);
        assert_eq!(fb.value(&bytes_fingerprint(""), 0), Some(4.0));
    }

    #[test]
    fn permute_applies_order() {
        let v = vec!["a", "b", "c"];
        assert_eq!(permute(&v, &[2, 0, 1]), vec!["c", "a", "b"]);
    }
}
