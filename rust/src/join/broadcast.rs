//! Broadcast join (paper §A.1 I): ship every smaller input in full to all
//! k workers; the largest input never moves. Wins when the small inputs
//! are tiny, loses catastrophically as n or k grows — eq 18's
//! (|R_1|+…+|R_{n−1}|)·(k−1) term, plotted in Fig 4a/14.

use super::{CombineOp, JoinError, JoinRun};
use crate::cluster::shuffle::broadcast_dataset;
use crate::cluster::SimCluster;
use crate::data::Dataset;
use crate::runtime::CogroupColumns;
use crate::stats::StratumAgg;
use std::collections::HashMap;
use std::time::Instant;

/// Broadcast join. Infallible in practice, but returns `Result` like every
/// other strategy entry point.
pub fn broadcast_join(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
) -> Result<JoinRun, JoinError> {
    assert!(inputs.len() >= 2);
    // largest input stays put; the rest broadcast
    let largest = inputs
        .iter()
        .enumerate()
        .max_by_key(|(_, d)| d.total_bytes())
        .map(|(i, _)| i)
        .unwrap();

    let mut s = cluster.stage("broadcast");
    for (i, d) in inputs.iter().enumerate() {
        if i != largest {
            broadcast_dataset(cluster, &mut s, d);
        }
    }
    s.finish(cluster);

    // per worker: join the local partitions of the largest input against
    // the fully-replicated small inputs
    let mut s = cluster.stage("crossproduct");
    let small_all: Vec<Vec<crate::data::Record>> = inputs
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != largest)
        .map(|(_, d)| d.iter().copied().collect())
        .collect();

    // data-parallel across the big input's partitions; partial aggregates
    // merge back in partition order, so the per-key f64 addition sequence
    // matches the sequential walk exactly
    let n_inputs = inputs.len();
    let per_partition = cluster
        .exec
        .map(inputs[largest].partitions.len(), |j| {
            let part = &inputs[largest].partitions[j];
            let t0 = Instant::now();
            // cogroup the local slice of the big input with the fully
            // replicated small inputs into flat columns, ordered so
            // combine() sees sides in input order — no per-partition
            // clones of the replicated inputs
            let mut per_input: Vec<&[crate::data::Record]> = Vec::with_capacity(n_inputs);
            let mut si = 0;
            for i in 0..n_inputs {
                if i == largest {
                    per_input.push(part.as_slice());
                } else {
                    per_input.push(small_all[si].as_slice());
                    si += 1;
                }
            }
            let cg = CogroupColumns::from_slices(&per_input);
            let mut local: HashMap<u64, StratumAgg> = HashMap::with_capacity(cg.num_keys());
            let mut pairs = 0u64;
            let mut sides: Vec<&[f64]> = Vec::with_capacity(n_inputs);
            for idx in 0..cg.num_keys() {
                cg.sides_into(idx, &mut sides);
                let agg = super::cross_product_agg(&sides, op);
                pairs += agg.population as u64;
                local.insert(cg.key(idx), agg);
            }
            (local, pairs, t0.elapsed().as_secs_f64())
        });
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    for (j, (local, pairs, secs)) in per_partition.into_iter().enumerate() {
        // the big input's values for one key are split across partitions,
        // so B_i and the moments ADD across partitions (in j order)
        for (key, agg) in local {
            let e = strata.entry(key).or_default();
            e.population += agg.population;
            e.count += agg.count;
            e.sum += agg.sum;
            e.sumsq += agg.sumsq;
        }
        s.add_compute(cluster.worker_of_partition(j), secs);
        s.add_items(pairs);
    }
    s.finish(cluster);

    let (metrics, ledger) = (cluster.take_metrics(), cluster.take_ledger());
    crate::faults::finalize_run(JoinRun::exact(strata, metrics).with_ledger(ledger), cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;
    use crate::join::native::native_join;

    fn cluster(k: usize) -> SimCluster {
        SimCluster::new(
            k,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn ds(name: &str, recs: Vec<(u64, f64)>, parts: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.into_iter().map(|(k, v)| Record::new(k, v)).collect(),
            parts,
            100,
        )
    }

    #[test]
    fn matches_native_join_result() {
        let a = ds("a", vec![(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)], 4);
        let big = ds(
            "b",
            vec![(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0), (5, 4.0), (6, 4.0)],
            4,
        );
        let bc = broadcast_join(&mut cluster(4), &[a.clone(), big.clone()], CombineOp::Sum)
            .unwrap();
        let nat = native_join(&mut cluster(4), &[a, big], CombineOp::Sum, u64::MAX).unwrap();
        assert!(
            (bc.exact_sum() - nat.exact_sum()).abs() < 1e-9,
            "{} vs {}",
            bc.exact_sum(),
            nat.exact_sum()
        );
        assert_eq!(bc.output_cardinality(), nat.output_cardinality());
    }

    #[test]
    fn big_input_never_shuffles() {
        let small = ds("s", (0..10).map(|k| (k, 1.0)).collect(), 4);
        let big = ds("b", (0..10_000).map(|k| (k % 100, 1.0)).collect(), 4);
        let mut c = cluster(4);
        let run = broadcast_join(&mut c, &[small.clone(), big], CombineOp::Sum).unwrap();
        // shuffled = small broadcast only: 10 recs x 100B x 3 receivers
        assert_eq!(run.metrics.total_shuffled_bytes(), 10 * 100 * 3);
        let _ = small;
    }

    #[test]
    fn broadcast_bytes_scale_with_k() {
        let small = ds("s", (0..100).map(|k| (k, 1.0)).collect(), 8);
        let big = ds("b", (0..1000).map(|k| (k, 1.0)).collect(), 8);
        let b2 = broadcast_join(&mut cluster(2), &[small.clone(), big.clone()], CombineOp::Sum)
            .unwrap()
            .metrics
            .total_shuffled_bytes();
        let b8 = broadcast_join(&mut cluster(8), &[small, big], CombineOp::Sum)
            .unwrap()
            .metrics
            .total_shuffled_bytes();
        assert!(b8 > 3 * b2, "b2={b2} b8={b8}");
    }

    #[test]
    fn three_way_broadcast() {
        let a = ds("a", vec![(1, 1.0), (2, 2.0)], 2);
        let b = ds("b", vec![(1, 10.0), (1, 20.0), (2, 30.0)], 2);
        let big = ds("c", vec![(1, 100.0), (3, 0.0), (4, 1.0), (5, 1.0)], 2);
        let bc = broadcast_join(
            &mut cluster(2),
            &[a.clone(), b.clone(), big.clone()],
            CombineOp::Sum,
        )
        .unwrap();
        let nat = native_join(&mut cluster(2), &[a, b, big], CombineOp::Sum, u64::MAX).unwrap();
        assert!((bc.exact_sum() - nat.exact_sum()).abs() < 1e-9);
    }
}
