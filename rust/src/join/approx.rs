//! The full ApproxJoin (paper §3.2-3.4, Algorithm 2): stage-1 filtering,
//! then *stratified edge sampling during the join* instead of the cross
//! product, then CLT / Horvitz-Thompson error estimation.
//!
//! The per-stratum aggregation of the sampled pair values — the inner loop
//! of Alg 2's sampleAndExecute — is expressed against the [`BatchAggregator`]
//! trait: the production implementation is the AOT `join_agg` XLA artifact
//! (runtime/batch.rs), with a pure-Rust fallback for tests and
//! artifact-less builds.

use super::bloom_join::{filter_and_shuffle, FilterConfig, KeyProber};
use super::{CombineOp, JoinError, JoinRun};
use crate::cluster::SimCluster;
use crate::data::Dataset;
use crate::sampling::edge_sampling::{
    population, sample_edges_dedup, sample_pairs_with_replacement, SampledPairs,
};
use crate::stats::{EstimatorKind, StratumAgg};
use crate::util::Rng;
use std::collections::HashMap;
use std::time::Instant;

/// How per-stratum sample sizes b_i are chosen.
#[derive(Clone, Debug)]
pub enum SamplingParams {
    /// Uniform fraction s of each stratum: b_i = ceil(s · B_i) (eq 7).
    Fraction(f64),
    /// Error-bound driven (eq 10): b_i = (z_{α/2} σ_i / err)², with σ_i
    /// from the feedback store; strata without a stored σ use
    /// `default_sigma` (first execution of a query).
    ErrorBound {
        err_desired: f64,
        confidence: f64,
        sigmas: HashMap<u64, f64>,
        default_sigma: f64,
    },
    /// Fixed b per stratum (diagnostics).
    FixedPerKey(u64),
}

impl SamplingParams {
    /// b_i for a stratum of population B_i.
    pub fn sample_size(&self, key: u64, population: f64) -> u64 {
        match self {
            SamplingParams::Fraction(s) => ((s * population).ceil() as u64).min(u64::MAX),
            SamplingParams::ErrorBound {
                err_desired,
                confidence,
                sigmas,
                default_sigma,
            } => {
                let sigma = sigmas.get(&key).copied().unwrap_or(*default_sigma);
                crate::stats::estimators::sample_size_for_error(sigma, *err_desired, *confidence)
                    .min(population.ceil() as u64 * 4)
            }
            SamplingParams::FixedPerKey(b) => *b,
        }
        // floor of 2: stratified sampling needs b_i >= 2 for the per-stratum
        // variance s_i^2 (eq 14) to be estimable at all
        .max(2)
    }
}

/// Configuration of the approximation stage.
#[derive(Clone, Debug)]
pub struct ApproxConfig {
    pub params: SamplingParams,
    pub estimator: EstimatorKind,
    pub seed: u64,
}

impl Default for ApproxConfig {
    fn default() -> Self {
        Self {
            params: SamplingParams::Fraction(0.1),
            estimator: EstimatorKind::Clt,
            seed: 7,
        }
    }
}

/// Batched per-stratum aggregation of sampled pair values — the contract
/// of the AOT `join_agg` artifact. `seg[i]` assigns row i to a stratum
/// slot; rows with mask 0 are padding. Returns per-slot
/// (counts, sums, sumsqs).
pub trait BatchAggregator {
    fn run(
        &mut self,
        left: &[f64],
        right: &[f64],
        seg: &[i32],
        mask: &[f64],
        op: CombineOp,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>, Vec<f64>)>;

    /// Rows per batch (the artifact's BATCH).
    fn batch_rows(&self) -> usize;

    /// Stratum slots per batch (the artifact's STRATA).
    fn strata_slots(&self) -> usize;

    /// An independent same-geometry aggregator for a parallel worker, when
    /// aggregation is safe to run concurrently. `None` (the default) keeps
    /// the aggregation phase sequential — the XLA executor owns mutable
    /// device buffers and stays on this path.
    fn fork(&self) -> Option<Box<dyn BatchAggregator + Send>> {
        None
    }
}

/// Pure-Rust aggregator with the same geometry as the artifact.
pub struct NativeAggregator {
    pub rows: usize,
    pub slots: usize,
}

impl Default for NativeAggregator {
    fn default() -> Self {
        Self {
            rows: 4096,
            slots: 256,
        }
    }
}

impl BatchAggregator for NativeAggregator {
    fn run(
        &mut self,
        left: &[f64],
        right: &[f64],
        seg: &[i32],
        mask: &[f64],
        op: CombineOp,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let mut counts = vec![0.0; self.slots];
        let mut sums = vec![0.0; self.slots];
        let mut sumsqs = vec![0.0; self.slots];
        for i in 0..left.len() {
            if mask[i] == 0.0 {
                continue;
            }
            let v = op.fold(left[i], right[i]);
            // fold(Left) keeps left; Sum/Product combine — same semantics
            // as the artifact's one-hot op selector
            let slot = seg[i] as usize;
            counts[slot] += 1.0;
            sums[slot] += v;
            sumsqs[slot] += v * v;
        }
        Ok((counts, sums, sumsqs))
    }

    fn batch_rows(&self) -> usize {
        self.rows
    }

    fn strata_slots(&self) -> usize {
        self.slots
    }

    fn fork(&self) -> Option<Box<dyn BatchAggregator + Send>> {
        Some(Box::new(NativeAggregator {
            rows: self.rows,
            slots: self.slots,
        }))
    }
}

/// Run the full approximate join.
pub fn approx_join(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    filter_cfg: FilterConfig,
    cfg: &ApproxConfig,
    prober: &mut dyn KeyProber,
    agg: &mut dyn BatchAggregator,
) -> Result<JoinRun, JoinError> {
    let filtered = filter_and_shuffle(cluster, inputs, filter_cfg, prober)?;
    let filter_report = filtered.join_filter.report();
    let (strata, draws) = sample_stage(cluster, &filtered, op, cfg, agg)?;
    let run = JoinRun {
        strata,
        metrics: cluster.take_metrics(),
        ledger: cluster.take_ledger(),
        sampled: true,
        draws,
        filter_report: Some(filter_report),
        baseline: None,
        fault_report: None,
    };
    crate::faults::finalize_run(run, cluster)
}

/// The sampling stage alone (Alg 2 over already-filtered groups) — used by
/// the engine after the exact-vs-approx decision.
///
/// Per-stratum sampling runs data-parallel across the workers through the
/// cluster's executor: the per-worker RNGs are forked **in worker order**
/// before any thread starts (the exact stream the sequential walk
/// produces), each worker owns its keys (hash-partitioned), and partial
/// results merge back in worker order — so the output is bit-identical to
/// the sequential path for a fixed seed, at any thread count. Forkable
/// aggregators (the native one) aggregate in parallel too; the XLA
/// `join_agg` executor aggregates sequentially over the parallel-drawn
/// samples.
pub fn sample_stage(
    cluster: &mut SimCluster,
    filtered: &super::bloom_join::Filtered,
    op: CombineOp,
    cfg: &ApproxConfig,
    agg: &mut dyn BatchAggregator,
) -> anyhow::Result<(HashMap<u64, StratumAgg>, HashMap<u64, f64>)> {
    let mut s = cluster.stage("sample");
    let exec = cluster.exec;
    let n_workers = filtered.per_worker.len();
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    let mut draws: HashMap<u64, f64> = HashMap::new();
    // fork per-worker RNGs sequentially, in worker order — the fork
    // sequence (and so every stream) matches the sequential walk exactly
    let mut rng = Rng::new(cfg.seed);
    let worker_rngs: Vec<Rng> = (0..n_workers).map(|w| rng.fork(w as u64 + 1)).collect();

    match cfg.estimator {
        EstimatorKind::Clt => {
            // with-replacement sampling streamed straight into the
            // BatchAggregator (AOT join_agg on the production path): one
            // stratum's pairs live only until its batch push, and every
            // worker owns a FRESH batch — batch boundaries decide where
            // partial f64 sums split, so a fixed per-worker geometry keeps
            // the addition tree identical for any thread count. Strata are
            // visited as the columnar directory's contiguous key runs —
            // already ascending, the same order the sorted hash-map walk
            // produced — so the per-worker RNG stream (shared across
            // strata) makes every run (and the XLA vs native paths)
            // replayable.
            let rows = agg.batch_rows();
            let slots = agg.strata_slots();
            let drain_worker = |w: usize,
                                local_agg: &mut dyn BatchAggregator|
             -> anyhow::Result<(HashMap<u64, StratumAgg>, u64, f64)> {
                let cg = &filtered.per_worker[w];
                let mut r = worker_rngs[w].clone();
                let t0 = Instant::now();
                let mut local: HashMap<u64, StratumAgg> = HashMap::new();
                let mut batch = Batch::new(rows, slots);
                let mut sampled_pairs = 0u64;
                let mut sides: Vec<&[f64]> = Vec::with_capacity(cg.n_inputs());
                for idx in 0..cg.num_keys() {
                    let key = cg.key(idx);
                    cg.sides_into(idx, &mut sides);
                    let pop = population(&sides);
                    if pop == 0.0 {
                        continue;
                    }
                    let b = cfg.params.sample_size(key, pop);
                    let mut pairs = SampledPairs::default();
                    sample_pairs_with_replacement(&mut r, &sides, b, op, &mut pairs);
                    sampled_pairs += pairs.len() as u64;
                    local
                        .entry(key)
                        .or_insert_with(|| StratumAgg {
                            population: pop,
                            ..Default::default()
                        })
                        .population = pop;
                    batch.push_key(key, &pairs, op, local_agg, &mut local)?;
                }
                batch.flush(op, local_agg, &mut local)?;
                Ok((local, sampled_pairs, t0.elapsed().as_secs_f64()))
            };

            let results: Vec<anyhow::Result<(HashMap<u64, StratumAgg>, u64, f64)>> =
                if agg.fork().is_some() && !exec.is_sequential() {
                    // forkable aggregator: each worker drains through its
                    // own instance, fully parallel
                    let forks: Vec<Box<dyn BatchAggregator + Send>> = (0..n_workers)
                        .map(|_| agg.fork().expect("forkable aggregator"))
                        .collect();
                    exec.map_with(forks, |w, local_agg| drain_worker(w, &mut **local_agg))
                } else {
                    // one shared aggregator (the XLA path): drain the
                    // workers sequentially, in worker order
                    (0..n_workers).map(|w| drain_worker(w, agg)).collect()
                };
            for (w, r) in results.into_iter().enumerate() {
                let (local, sampled_pairs, secs) = r?;
                strata.extend(local);
                s.add_compute(w, secs);
                s.add_items(sampled_pairs);
            }
        }
        EstimatorKind::HorvitzThompson => {
            // dedup sampling aggregates locally per worker (a hash set is
            // inherently sequential per stratum), fully parallel across
            // workers; the columnar directory is ascending, so the
            // per-worker RNG stream stays replayable
            type HtOut = (HashMap<u64, StratumAgg>, HashMap<u64, f64>, u64, f64);
            let results: Vec<HtOut> = exec.map(n_workers, |w| {
                let cg = &filtered.per_worker[w];
                let mut r = worker_rngs[w].clone();
                let t0 = Instant::now();
                let mut local_strata = HashMap::new();
                let mut local_draws = HashMap::new();
                let mut sampled_pairs = 0u64;
                let mut sides: Vec<&[f64]> = Vec::with_capacity(cg.n_inputs());
                for idx in 0..cg.num_keys() {
                    let key = cg.key(idx);
                    cg.sides_into(idx, &mut sides);
                    let pop = population(&sides);
                    if pop == 0.0 {
                        continue;
                    }
                    let b = cfg.params.sample_size(key, pop);
                    let (agg_k, dr) = sample_edges_dedup(&mut r, &sides, b, op);
                    sampled_pairs += dr as u64;
                    local_strata.insert(key, agg_k);
                    local_draws.insert(key, dr);
                }
                (
                    local_strata,
                    local_draws,
                    sampled_pairs,
                    t0.elapsed().as_secs_f64(),
                )
            });
            for (w, (local_strata, local_draws, sampled_pairs, secs)) in
                results.into_iter().enumerate()
            {
                strata.extend(local_strata);
                draws.extend(local_draws);
                s.add_compute(w, secs);
                s.add_items(sampled_pairs);
            }
        }
    }
    s.finish(cluster);

    Ok((strata, draws))
}

/// Fixed-geometry batch builder: packs sampled pairs of many strata into
/// artifact-shaped (left, right, seg, mask) tensors, tracking the
/// slot → join-key mapping per batch and scattering the per-slot results
/// back into the global stratum map on flush.
struct Batch {
    rows: usize,
    slots: usize,
    left: Vec<f64>,
    right: Vec<f64>,
    seg: Vec<i32>,
    slot_keys: Vec<u64>,
}

impl Batch {
    fn new(rows: usize, slots: usize) -> Self {
        Self {
            rows,
            slots,
            left: Vec::with_capacity(rows),
            right: Vec::with_capacity(rows),
            seg: Vec::with_capacity(rows),
            slot_keys: Vec::new(),
        }
    }

    fn push_key(
        &mut self,
        key: u64,
        pairs: &SampledPairs,
        op: CombineOp,
        agg: &mut dyn BatchAggregator,
        strata: &mut HashMap<u64, StratumAgg>,
    ) -> anyhow::Result<()> {
        let mut offset = 0;
        while offset < pairs.len() {
            if self.slot_keys.len() == self.slots || self.left.len() == self.rows {
                self.flush(op, agg, strata)?;
            }
            // one slot per (key, batch) occurrence
            let slot = self.slot_keys.len() as i32;
            self.slot_keys.push(key);
            let space = self.rows - self.left.len();
            let take = space.min(pairs.len() - offset);
            for i in offset..offset + take {
                self.left.push(pairs.left[i]);
                self.right.push(pairs.right[i]);
                self.seg.push(slot);
            }
            offset += take;
        }
        Ok(())
    }

    fn flush(
        &mut self,
        op: CombineOp,
        agg: &mut dyn BatchAggregator,
        strata: &mut HashMap<u64, StratumAgg>,
    ) -> anyhow::Result<()> {
        if self.left.is_empty() {
            self.slot_keys.clear();
            return Ok(());
        }
        let n = self.left.len();
        let mut mask = vec![1.0; n];
        // pad to full geometry
        self.left.resize(self.rows, 0.0);
        self.right.resize(self.rows, 0.0);
        self.seg.resize(self.rows, 0);
        mask.resize(self.rows, 0.0);
        let (counts, sums, sumsqs) = agg.run(&self.left, &self.right, &self.seg, &mask, op)?;
        for (slot, &key) in self.slot_keys.iter().enumerate() {
            if counts[slot] == 0.0 {
                continue;
            }
            let e = strata.entry(key).or_default();
            e.count += counts[slot];
            e.sum += sums[slot];
            e.sumsq += sumsqs[slot];
        }
        self.left.clear();
        self.right.clear();
        self.seg.clear();
        self.slot_keys.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;
    use crate::join::bloom_join::NativeProber;
    use crate::join::native::native_join;
    use crate::stats::clt_sum;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn skewed_inputs(n_keys: u64, per_key: u64) -> Vec<Dataset> {
        let mut r = Rng::new(42);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for key in 0..n_keys {
            for _ in 0..per_key {
                a.push(Record::new(key, r.range_f64(0.0, 10.0)));
                b.push(Record::new(key, r.range_f64(0.0, 10.0)));
            }
        }
        vec![
            Dataset::from_records_unpartitioned("a", a, 4, 100),
            Dataset::from_records_unpartitioned("b", b, 4, 100),
        ]
    }

    #[test]
    fn estimate_close_to_exact() {
        let inputs = skewed_inputs(20, 30); // 20 strata x 900 pairs
        let exact = native_join(&mut cluster(), &inputs, CombineOp::Sum, u64::MAX)
            .unwrap()
            .exact_sum();
        let cfg = ApproxConfig {
            params: SamplingParams::Fraction(0.2),
            ..Default::default()
        };
        let run = approx_join(
            &mut cluster(),
            &inputs,
            CombineOp::Sum,
            FilterConfig::default(),
            &cfg,
            &mut NativeProber,
            &mut NativeAggregator::default(),
        )
        .unwrap();
        assert!(run.sampled);
        let res = clt_sum(&run.strata_vec(), 0.95);
        let rel = (res.estimate - exact).abs() / exact;
        assert!(rel < 0.05, "rel err {rel}: {} vs {exact}", res.estimate);
        // the CI should usually cover the truth
        assert!(
            (res.estimate - exact).abs() < 3.0 * res.error_bound.max(1e-9),
            "bound {} error {}",
            res.error_bound,
            (res.estimate - exact).abs()
        );
    }

    #[test]
    fn ht_estimate_close_to_exact() {
        let inputs = skewed_inputs(10, 20);
        let exact = native_join(&mut cluster(), &inputs, CombineOp::Sum, u64::MAX)
            .unwrap()
            .exact_sum();
        let cfg = ApproxConfig {
            params: SamplingParams::Fraction(0.3),
            estimator: EstimatorKind::HorvitzThompson,
            seed: 5,
        };
        let run = approx_join(
            &mut cluster(),
            &inputs,
            CombineOp::Sum,
            FilterConfig::default(),
            &cfg,
            &mut NativeProber,
            &mut NativeAggregator::default(),
        )
        .unwrap();
        let strata: Vec<StratumAgg> = run.strata.values().copied().collect();
        let dr: Vec<f64> = run
            .strata
            .iter()
            .map(|(k, _)| run.draws[k])
            .collect();
        let res = crate::stats::horvitz_thompson_sum(&strata, &dr, 0.95);
        let rel = (res.estimate - exact).abs() / exact;
        assert!(rel < 0.1, "rel err {rel}");
    }

    #[test]
    fn samples_far_fewer_pairs_than_exact() {
        let inputs = skewed_inputs(10, 50); // 10 x 2500 pairs = 25k
        let cfg = ApproxConfig {
            params: SamplingParams::Fraction(0.05),
            ..Default::default()
        };
        let run = approx_join(
            &mut cluster(),
            &inputs,
            CombineOp::Sum,
            FilterConfig::default(),
            &cfg,
            &mut NativeProber,
            &mut NativeAggregator::default(),
        )
        .unwrap();
        let sampled: f64 = run.strata.values().map(|s| s.count).sum();
        assert!(
            (1000.0..2000.0).contains(&sampled),
            "sampled {sampled} (expect ~1250)"
        );
    }

    #[test]
    fn tiny_batch_geometry_still_correct() {
        // force many flushes: 8 rows, 2 slots
        let inputs = skewed_inputs(5, 10);
        let exact = native_join(&mut cluster(), &inputs, CombineOp::Sum, u64::MAX)
            .unwrap()
            .exact_sum();
        let cfg = ApproxConfig {
            params: SamplingParams::Fraction(0.5),
            seed: 11,
            ..Default::default()
        };
        let mut tiny = NativeAggregator { rows: 8, slots: 2 };
        let run = approx_join(
            &mut cluster(),
            &inputs,
            CombineOp::Sum,
            FilterConfig::default(),
            &cfg,
            &mut NativeProber,
            &mut tiny,
        )
        .unwrap();
        let res = clt_sum(&run.strata_vec(), 0.95);
        let rel = (res.estimate - exact).abs() / exact;
        assert!(rel < 0.15, "rel err {rel}");
        // every stratum population survived batching
        for agg in run.strata.values() {
            assert_eq!(agg.population, 100.0);
            assert!(agg.count > 0.0);
        }
    }

    #[test]
    fn error_bound_params_pick_bigger_samples_for_noisier_strata() {
        let mut sigmas = HashMap::new();
        sigmas.insert(1u64, 10.0);
        sigmas.insert(2u64, 1.0);
        let p = SamplingParams::ErrorBound {
            err_desired: 0.5,
            confidence: 0.95,
            sigmas,
            default_sigma: 5.0,
        };
        let b_noisy = p.sample_size(1, 1e9);
        let b_quiet = p.sample_size(2, 1e9);
        let b_unknown = p.sample_size(3, 1e9);
        assert!(b_noisy > b_quiet);
        assert!(b_unknown > b_quiet && b_unknown < b_noisy);
    }

    #[test]
    fn fraction_params_floor_two() {
        let p = SamplingParams::Fraction(0.001);
        assert_eq!(p.sample_size(0, 10.0), 2);
    }
}
