//! Statistics substrate: distributions (normal / Student-t quantiles),
//! running summaries, and the paper's two error estimators (§3.4).

pub mod distributions;
pub mod estimators;
pub mod summary;

pub use distributions::{normal_quantile, t_critical, z_critical};
pub use estimators::{
    clt_avg, clt_stdev, clt_sum, exact_count, horvitz_thompson_sum, ApproxResult, EstimatorKind,
};
pub use summary::{StratumAgg, Welford};
