//! Statistical distributions needed by the error-estimation stage (§3.4):
//! standard-normal quantiles (z_{α/2} in eq 8-10) and Student-t quantiles
//! (t_{f,1-α/2} in eq 12 / eq 16). The paper uses Apache Commons Math for
//! this; here it is implemented directly (log-gamma, regularized incomplete
//! beta via Lentz continued fractions, quantile by bisection+Newton) and
//! pinned against standard table values in the tests.

/// ln Γ(x) — Lanczos approximation (g=7, n=9), |err| < 1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta I_x(a, b) via the Lentz continued fraction.
pub fn betai(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // use the symmetry relation for faster convergence (<= so the
    // symmetric point x == (a+1)/(a+b+2) cannot recurse forever)
    if x <= (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - betai(b, a, 1.0 - x)
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const EPS: f64 = 1e-14;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Standard-normal CDF Φ(x) via erfc (Abramowitz-Stegun 7.1.26-style rational
/// approximation refined with one Newton step is overkill; use erf series
/// split — here: W. J. Cody's rational erf, |err| < 1e-15 over the real line
/// as implemented via the complementary form).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// erfc: Maclaurin series for |x| < 1.5 (fast convergence, ~1e-16), the
/// classic Chebyshev fit (|rel err| < 1.2e-7) for the tails where the CDF is
/// within 1.2e-7·e^{-x²} of 0/1 anyway.
pub fn erfc(x: f64) -> f64 {
    if x.abs() < 1.5 {
        return 1.0 - erf_series(x);
    }
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

fn erf_series(x: f64) -> f64 {
    // Maclaurin series, converges fast for |x| < 1.5
    let mut term = x;
    let mut sum = x;
    let x2 = x * x;
    for n in 1..80 {
        let n = n as f64;
        term *= -x2 / n;
        let add = term / (2.0 * n + 1.0);
        sum += add;
        if add.abs() < 1e-17 {
            break;
        }
    }
    2.0 / std::f64::consts::PI.sqrt() * sum
}

/// Standard-normal quantile Φ⁻¹(p) — Acklam's algorithm + one Halley step.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p={p} out of (0,1)");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    let mut x = if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Halley refinement
    let e = normal_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x -= u / (1.0 + x * u / 2.0);
    x
}

/// Student-t CDF with `df` degrees of freedom.
pub fn t_cdf(x: f64, df: f64) -> f64 {
    assert!(df > 0.0);
    let p = 0.5 * betai(df / 2.0, 0.5, df / (df + x * x));
    if x > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Student-t quantile (inverse CDF) with `df` degrees of freedom.
/// Falls back to the normal quantile for large df (they agree to <1e-4 by
/// df ~ 1e6); otherwise bisection + Newton on `t_cdf`.
pub fn t_quantile(p: f64, df: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p={p} out of (0,1)");
    assert!(df > 0.0);
    if df > 1e6 {
        return normal_quantile(p);
    }
    if (p - 0.5).abs() < 1e-15 {
        return 0.0;
    }
    // bracket
    let mut lo = -1e3;
    let mut hi = 1e3;
    let mut x = normal_quantile(p); // good starting point
    for _ in 0..200 {
        let c = t_cdf(x, df);
        if (c - p).abs() < 1e-13 {
            break;
        }
        if c < p {
            lo = x;
        } else {
            hi = x;
        }
        // Newton step with the t pdf
        let pdf = t_pdf(x, df);
        let mut nx = if pdf > 1e-300 { x - (c - p) / pdf } else { x };
        if !(nx > lo && nx < hi) {
            nx = 0.5 * (lo + hi);
        }
        if (nx - x).abs() < 1e-14 * (1.0 + x.abs()) {
            x = nx;
            break;
        }
        x = nx;
    }
    x
}

/// Student-t density.
pub fn t_pdf(x: f64, df: f64) -> f64 {
    let ln = ln_gamma((df + 1.0) / 2.0)
        - ln_gamma(df / 2.0)
        - 0.5 * (df * std::f64::consts::PI).ln()
        - (df + 1.0) / 2.0 * (1.0 + x * x / df).ln();
    ln.exp()
}

/// Two-sided critical value for a confidence level: z_{α/2} with
/// α = 1 - confidence. confidence ∈ (0, 1), e.g. 0.95 → 1.959964.
pub fn z_critical(confidence: f64) -> f64 {
    assert!(confidence > 0.0 && confidence < 1.0);
    normal_quantile(1.0 - (1.0 - confidence) / 2.0)
}

/// Two-sided t critical value t_{df, 1-α/2}.
pub fn t_critical(confidence: f64, df: f64) -> f64 {
    assert!(confidence > 0.0 && confidence < 1.0);
    if df < 1.0 {
        // degenerate sample; fall back to a wide normal bound
        return z_critical(confidence) * 10.0;
    }
    t_quantile(1.0 - (1.0 - confidence) / 2.0, df)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} != {b} (tol {tol})");
    }

    #[test]
    fn ln_gamma_table() {
        close(ln_gamma(1.0), 0.0, 1e-12);
        close(ln_gamma(2.0), 0.0, 1e-12);
        close(ln_gamma(5.0), 24f64.ln(), 1e-10);
        close(ln_gamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-10);
    }

    #[test]
    fn normal_cdf_table() {
        close(normal_cdf(0.0), 0.5, 1e-9);
        close(normal_cdf(1.96), 0.9750021, 1e-5);
        close(normal_cdf(-1.0), 0.1586553, 1e-5);
        close(normal_cdf(3.0), 0.9986501, 1e-5);
    }

    #[test]
    fn normal_quantile_table() {
        close(normal_quantile(0.975), 1.959964, 1e-5);
        close(normal_quantile(0.5), 0.0, 1e-9);
        close(normal_quantile(0.995), 2.575829, 1e-5);
        close(normal_quantile(0.05), -1.644854, 1e-5);
    }

    #[test]
    fn normal_quantile_roundtrip() {
        for &p in &[0.001, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 0.999] {
            close(normal_cdf(normal_quantile(p)), p, 1e-8);
        }
    }

    #[test]
    fn t_cdf_symmetry() {
        for &df in &[1.0, 3.0, 10.0, 100.0] {
            for &x in &[0.5, 1.0, 2.5] {
                close(t_cdf(x, df) + t_cdf(-x, df), 1.0, 1e-10);
            }
        }
    }

    #[test]
    fn t_quantile_table() {
        // standard two-sided 95% critical values (t_{df, 0.975})
        close(t_quantile(0.975, 1.0), 12.7062, 1e-3);
        close(t_quantile(0.975, 2.0), 4.30265, 1e-4);
        close(t_quantile(0.975, 5.0), 2.57058, 1e-4);
        close(t_quantile(0.975, 10.0), 2.22814, 1e-4);
        close(t_quantile(0.975, 30.0), 2.04227, 1e-4);
        close(t_quantile(0.975, 100.0), 1.98397, 1e-4);
        // 99% one-sided
        close(t_quantile(0.99, 10.0), 2.76377, 1e-4);
    }

    #[test]
    fn t_quantile_approaches_normal() {
        close(t_quantile(0.975, 1e5), normal_quantile(0.975), 1e-3);
    }

    #[test]
    fn t_quantile_roundtrip() {
        for &df in &[2.0, 7.0, 23.0, 350.0] {
            for &p in &[0.6, 0.9, 0.975, 0.999] {
                close(t_cdf(t_quantile(p, df), df), p, 1e-8);
            }
        }
    }

    #[test]
    fn critical_values() {
        close(z_critical(0.95), 1.959964, 1e-5);
        close(t_critical(0.95, 10.0), 2.22814, 1e-4);
        assert!(t_critical(0.95, 2.0) > t_critical(0.95, 50.0));
    }

    #[test]
    fn betai_edges() {
        assert_eq!(betai(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betai(2.0, 3.0, 1.0), 1.0);
        close(betai(0.5, 0.5, 0.5), 0.5, 1e-10); // arcsine distribution median
        close(betai(1.0, 1.0, 0.3), 0.3, 1e-10); // uniform
    }
}
