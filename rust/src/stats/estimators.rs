//! Error estimation for the approximate join output (§3.4): the stratified
//! CLT estimator (eq 12-14, sampling with replacement) and the
//! Horvitz-Thompson estimator (eq 15-17, deduplicated sampling).
//!
//! Both consume per-stratum aggregates (`StratumAgg`) — exactly what the
//! AOT `join_agg` artifact emits — and return `result ± error_bound` at the
//! requested confidence level.

use super::distributions::{t_critical, z_critical};
use super::summary::StratumAgg;

/// Which estimator closes the approximation loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstimatorKind {
    /// Central Limit Theorem over stratified with-replacement samples
    /// (paper §3.4 I). Duplicates in the sample are kept.
    Clt,
    /// Horvitz-Thompson over deduplicated samples (paper §3.4 II). Unbiased
    /// regardless of with/without replacement.
    HorvitzThompson,
}

/// An approximate aggregate with its confidence interval.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ApproxResult {
    pub estimate: f64,
    /// Half-width of the two-sided confidence interval.
    pub error_bound: f64,
    pub confidence: f64,
    /// Degrees of freedom used for the t critical value (CLT path).
    pub degrees_of_freedom: f64,
    /// Total samples the estimate is based on.
    pub samples: u64,
}

impl ApproxResult {
    /// Relative half-width |bound / estimate| (∞ if the estimate is 0).
    pub fn relative_error(&self) -> f64 {
        if self.estimate == 0.0 {
            if self.error_bound == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (self.error_bound / self.estimate).abs()
        }
    }
}

/// CLT stratified estimate of the population SUM (paper eq 12-14).
///
/// τ̂ = Σ_i (B_i / b_i) Σ_j v_ij, with
/// V̂ar(τ̂) = Σ_i B_i (B_i − b_i) s_i² / b_i and f = Σ b_i − m degrees of
/// freedom. The finite-population correction is clamped at zero because
/// with-replacement sampling can draw b_i > B_i on small strata.
pub fn clt_sum(strata: &[StratumAgg], confidence: f64) -> ApproxResult {
    let mut tau = 0.0;
    let mut var = 0.0;
    let mut total_b = 0.0;
    let mut m_sampled = 0.0;
    for s in strata {
        if s.count <= 0.0 {
            continue;
        }
        m_sampled += 1.0;
        total_b += s.count;
        tau += s.population / s.count * s.sum;
        if s.count > 1.0 {
            let fpc = (s.population - s.count).max(0.0);
            var += s.population * fpc * s.variance() / s.count;
        }
    }
    let df = (total_b - m_sampled).max(1.0);
    let t = t_critical(confidence, df);
    ApproxResult {
        estimate: tau,
        error_bound: t * var.max(0.0).sqrt(),
        confidence,
        degrees_of_freedom: df,
        samples: total_b as u64,
    }
}

/// CLT stratified estimate of the population MEAN: τ̂ / Σ B_i with the
/// error bound scaled accordingly.
pub fn clt_avg(strata: &[StratumAgg], confidence: f64) -> ApproxResult {
    let total_pop: f64 = strata.iter().map(|s| s.population).sum();
    let sum = clt_sum(strata, confidence);
    if total_pop <= 0.0 {
        return ApproxResult {
            estimate: 0.0,
            error_bound: 0.0,
            ..sum
        };
    }
    ApproxResult {
        estimate: sum.estimate / total_pop,
        error_bound: sum.error_bound / total_pop,
        ..sum
    }
}

/// Exact population COUNT of the join output (the filter stage knows every
/// B_i, so COUNT carries no sampling error).
pub fn exact_count(strata: &[StratumAgg], confidence: f64) -> ApproxResult {
    let total_pop: f64 = strata.iter().map(|s| s.population).sum();
    ApproxResult {
        estimate: total_pop,
        error_bound: 0.0,
        confidence,
        degrees_of_freedom: f64::INFINITY,
        samples: strata.iter().map(|s| s.count as u64).sum(),
    }
}

/// Stratified estimate of the population STANDARD DEVIATION. Point estimate
/// from the pooled within+between decomposition; the bound propagates the
/// SUM bound through the delta method (conservative).
pub fn clt_stdev(strata: &[StratumAgg], confidence: f64) -> ApproxResult {
    let total_pop: f64 = strata.iter().map(|s| s.population).sum();
    if total_pop <= 1.0 {
        return ApproxResult {
            estimate: 0.0,
            error_bound: 0.0,
            confidence,
            degrees_of_freedom: 1.0,
            samples: 0,
        };
    }
    let avg = clt_avg(strata, confidence);
    let grand_mean = avg.estimate;
    // E[X²] estimated stratified: Σ B_i/b_i Σ v² / Σ B_i
    let mut sumsq_hat = 0.0;
    let mut total_b = 0.0;
    for s in strata {
        if s.count > 0.0 {
            sumsq_hat += s.population / s.count * s.sumsq;
            total_b += s.count;
        }
    }
    let ex2 = sumsq_hat / total_pop;
    let var = (ex2 - grand_mean * grand_mean).max(0.0);
    let sd = var.sqrt();
    // delta method: sd(g(X)) ~ |g'| * bound; g = sqrt at var
    let bound = if sd > 1e-12 {
        avg.error_bound * grand_mean.abs() / sd + avg.error_bound
    } else {
        avg.error_bound
    };
    ApproxResult {
        estimate: sd,
        error_bound: bound,
        confidence,
        degrees_of_freedom: avg.degrees_of_freedom,
        samples: total_b as u64,
    }
}

/// Per-stratum inclusion probability of a *distinct* edge under b_i
/// with-replacement draws from a stratum of B_i edges:
/// π_i = 1 − (1 − 1/B_i)^{b_i}.
pub fn inclusion_probability(population: f64, draws: f64) -> f64 {
    if population <= 0.0 || draws <= 0.0 {
        return 0.0;
    }
    if population <= 1.0 {
        return 1.0;
    }
    1.0 - (1.0 - 1.0 / population).powf(draws)
}

/// Horvitz-Thompson estimate of the population SUM (paper eq 15-17).
///
/// Strata are sampled independently, so the joint inclusion probability
/// factorizes (π_ij = π_i π_j) and the cross term of eq 17 vanishes; the
/// variance reduces to Σ_i (1−π_i)/π_i² · y_i², with y_i the *deduplicated*
/// sample sum of stratum i scaled to a per-stratum total estimate.
///
/// `strata` must hold deduplicated aggregates (each distinct sampled edge
/// counted once); `draws[i]` is the number of raw draws b_i that produced
/// them (needed for π_i).
pub fn horvitz_thompson_sum(
    strata: &[StratumAgg],
    draws: &[f64],
    confidence: f64,
) -> ApproxResult {
    assert_eq!(strata.len(), draws.len());
    let mut tau = 0.0;
    let mut var = 0.0;
    let mut n_strata = 0.0;
    let mut samples = 0.0;
    for (s, &b) in strata.iter().zip(draws) {
        if s.count <= 0.0 {
            continue;
        }
        n_strata += 1.0;
        samples += s.count;
        // Each distinct edge within the stratum has inclusion prob π_edge;
        // y_i/π_edge estimates the stratum total.
        let pi = inclusion_probability(s.population, b);
        if pi <= 0.0 {
            continue;
        }
        tau += s.sum / pi;
        var += (1.0 - pi) / (pi * pi) * s.sumsq;
    }
    let df = (samples - n_strata).max(1.0);
    let t = t_critical(confidence, df);
    ApproxResult {
        estimate: tau,
        error_bound: t * var.max(0.0).sqrt(),
        confidence,
        degrees_of_freedom: df,
        samples: samples as u64,
    }
}

/// Required sample size for a target error bound (paper eq 10):
/// b_i = (z_{α/2} σ_i / err)². Returns at least 1.
pub fn sample_size_for_error(sigma: f64, err_desired: f64, confidence: f64) -> u64 {
    if err_desired <= 0.0 {
        return u64::MAX;
    }
    let z = z_critical(confidence);
    let b = (z * sigma / err_desired).powi(2);
    b.ceil().max(1.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn make_strata(r: &mut Rng, m: usize) -> (Vec<StratumAgg>, f64) {
        // ground-truth population: stratum i has B_i values ~ N(mu_i, sd_i)
        let mut strata = Vec::new();
        let mut true_total = 0.0;
        for _ in 0..m {
            let pop = 50 + r.index(200);
            let mu = r.range_f64(-10.0, 10.0);
            let sd = r.range_f64(0.5, 3.0);
            let values: Vec<f64> = (0..pop).map(|_| mu + sd * r.normal()).collect();
            true_total += values.iter().sum::<f64>();
            // sample 30% with replacement
            let b = (pop as f64 * 0.3).ceil() as usize;
            let mut agg = StratumAgg {
                population: pop as f64,
                ..Default::default()
            };
            for _ in 0..b {
                agg.push(values[r.index(pop)]);
            }
            strata.push(agg);
        }
        (strata, true_total)
    }

    #[test]
    fn clt_sum_unbiased_and_covered() {
        // Across repetitions the true total should fall inside the 95% CI
        // roughly 95% of the time; assert >= 80% to keep the test stable.
        let mut r = Rng::new(42);
        let mut covered = 0;
        let reps = 50;
        for _ in 0..reps {
            let (strata, truth) = make_strata(&mut r, 20);
            let res = clt_sum(&strata, 0.95);
            if (res.estimate - truth).abs() <= res.error_bound {
                covered += 1;
            }
        }
        assert!(covered >= (reps * 8) / 10, "coverage {covered}/{reps}");
    }

    #[test]
    fn clt_full_sample_has_zero_variance() {
        // b_i == B_i with distinct values -> fpc = 0 -> bound 0... only exact
        // when the sample IS the population; emulate by sampling every item.
        let mut agg = StratumAgg {
            population: 4.0,
            ..Default::default()
        };
        for v in [1.0, 2.0, 3.0, 4.0] {
            agg.push(v);
        }
        let res = clt_sum(&[agg], 0.95);
        assert!((res.estimate - 10.0).abs() < 1e-9);
        assert_eq!(res.error_bound, 0.0);
    }

    #[test]
    fn clt_skips_empty_strata() {
        let empty = StratumAgg {
            population: 100.0,
            ..Default::default()
        };
        let mut one = StratumAgg {
            population: 10.0,
            ..Default::default()
        };
        one.push(5.0);
        let res = clt_sum(&[empty, one], 0.95);
        assert!((res.estimate - 50.0).abs() < 1e-9);
        assert_eq!(res.samples, 1);
    }

    #[test]
    fn clt_avg_scales_sum() {
        let mut a = StratumAgg {
            population: 10.0,
            ..Default::default()
        };
        for v in [2.0, 4.0, 6.0] {
            a.push(v);
        }
        let s = clt_sum(&[a], 0.95);
        let m = clt_avg(&[a], 0.95);
        assert!((m.estimate - s.estimate / 10.0).abs() < 1e-12);
        assert!((m.error_bound - s.error_bound / 10.0).abs() < 1e-12);
    }

    #[test]
    fn exact_count_is_exact() {
        let a = StratumAgg {
            population: 123.0,
            ..Default::default()
        };
        let b = StratumAgg {
            population: 7.0,
            ..Default::default()
        };
        let res = exact_count(&[a, b], 0.95);
        assert_eq!(res.estimate, 130.0);
        assert_eq!(res.error_bound, 0.0);
    }

    #[test]
    fn stdev_estimates_population_sd() {
        let mut r = Rng::new(77);
        // one big stratum, values N(5, 2); sample 40%
        let pop = 5000;
        let values: Vec<f64> = (0..pop).map(|_| 5.0 + 2.0 * r.normal()).collect();
        let mut agg = StratumAgg {
            population: pop as f64,
            ..Default::default()
        };
        for _ in 0..2000 {
            agg.push(values[r.index(pop)]);
        }
        let res = clt_stdev(&[agg], 0.95);
        assert!((res.estimate - 2.0).abs() < 0.15, "sd={}", res.estimate);
    }

    #[test]
    fn inclusion_probability_properties() {
        assert_eq!(inclusion_probability(0.0, 10.0), 0.0);
        assert_eq!(inclusion_probability(1.0, 3.0), 1.0);
        let p1 = inclusion_probability(100.0, 10.0);
        let p2 = inclusion_probability(100.0, 50.0);
        assert!(p1 > 0.0 && p1 < 1.0);
        assert!(p2 > p1, "more draws -> higher inclusion");
        // b=1 -> exactly 1/B
        assert!((inclusion_probability(100.0, 1.0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn horvitz_thompson_unbiased() {
        // Average of HT estimates over many runs approaches the true total.
        let mut r = Rng::new(99);
        let pop = 200usize;
        let values: Vec<f64> = (0..pop).map(|_| r.range_f64(1.0, 9.0)).collect();
        let truth: f64 = values.iter().sum();
        let draws = 80.0;
        let reps = 400;
        let mut mean_est = 0.0;
        for _ in 0..reps {
            // with-replacement draws, dedup
            let mut seen = std::collections::HashSet::new();
            let mut agg = StratumAgg {
                population: pop as f64,
                ..Default::default()
            };
            for _ in 0..draws as usize {
                let j = r.index(pop);
                if seen.insert(j) {
                    agg.push(values[j]);
                }
            }
            let res = horvitz_thompson_sum(&[agg], &[draws], 0.95);
            mean_est += res.estimate;
        }
        mean_est /= reps as f64;
        assert!(
            (mean_est - truth).abs() / truth < 0.02,
            "mean {mean_est} vs truth {truth}"
        );
    }

    #[test]
    fn sample_size_for_error_matches_eq10() {
        // paper: b_i = 3.84 (σ/err)² at 95%
        let b = sample_size_for_error(2.0, 0.5, 0.95);
        let expected = (1.959964_f64 * 2.0 / 0.5).powi(2).ceil() as u64;
        assert_eq!(b, expected);
        assert!(b >= 61 && b <= 62, "b={b}");
        assert_eq!(sample_size_for_error(1.0, 0.0, 0.95), u64::MAX);
        assert_eq!(sample_size_for_error(0.0, 1.0, 0.95), 1);
    }

    #[test]
    fn relative_error() {
        let res = ApproxResult {
            estimate: 200.0,
            error_bound: 10.0,
            confidence: 0.95,
            degrees_of_freedom: 10.0,
            samples: 10,
        };
        assert!((res.relative_error() - 0.05).abs() < 1e-12);
    }
}
