//! Running summary statistics (Welford) and per-stratum aggregates — the
//! bookkeeping the sampling stage hands to the estimators (§3.4) and the
//! feedback mechanism stores between runs (§3.2 II).

/// Numerically stable running mean/variance (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (ddof=1); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Population variance (ddof=0); 0 for n == 0.
    pub fn variance_population(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    /// Merge another accumulator (Chan's parallel update) — used when
    /// workers return partial summaries to the master (Alg 2 lines 6-8).
    pub fn merge(&mut self, o: &Welford) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = (self.n + o.n) as f64;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n;
        self.m2 += o.m2 + d * d * (self.n as f64) * (o.n as f64) / n;
        self.n += o.n;
    }
}

/// Per-stratum sample aggregates in the exact shape the AOT `join_agg`
/// artifact produces: (count, sum, sum of squares), plus the stratum's
/// population size B_i (total bipartite edges for that join key).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StratumAgg {
    /// B_i: number of edges in the complete bipartite graph for this key.
    pub population: f64,
    /// b_i: samples drawn.
    pub count: f64,
    /// Σ v of sampled combined values.
    pub sum: f64,
    /// Σ v² of sampled combined values.
    pub sumsq: f64,
}

impl StratumAgg {
    pub fn push(&mut self, v: f64) {
        self.count += 1.0;
        self.sum += v;
        self.sumsq += v * v;
    }

    pub fn merge(&mut self, o: &StratumAgg) {
        debug_assert!(
            self.population == 0.0 || o.population == 0.0 || self.population == o.population,
            "merging aggregates of different strata"
        );
        self.population = self.population.max(o.population);
        self.count += o.count;
        self.sum += o.sum;
        self.sumsq += o.sumsq;
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0.0 {
            self.sum / self.count
        } else {
            0.0
        }
    }

    /// Unbiased sample variance from the moment form, clamped at 0 against
    /// catastrophic cancellation.
    pub fn variance(&self) -> f64 {
        if self.count < 2.0 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sumsq - self.count * m * m) / (self.count - 1.0)).max(0.0)
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance_population() - 4.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert!((w.sum() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..1000).map(|_| r.normal() * 3.0 + 1.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..300] {
            a.push(x);
        }
        for &x in &xs[300..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn welford_merge_empty_identity() {
        let mut a = Welford::new();
        a.push(1.0);
        a.push(2.0);
        let before = (a.mean(), a.variance(), a.count());
        a.merge(&Welford::new());
        assert_eq!(before, (a.mean(), a.variance(), a.count()));

        let mut e = Welford::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
        assert!((e.mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn stratum_agg_matches_welford() {
        let mut r = Rng::new(6);
        let mut agg = StratumAgg::default();
        let mut w = Welford::new();
        for _ in 0..500 {
            let v = r.normal() * 2.0 + 10.0;
            agg.push(v);
            w.push(v);
        }
        assert!((agg.mean() - w.mean()).abs() < 1e-9);
        assert!((agg.variance() - w.variance()).abs() / w.variance() < 1e-6);
    }

    #[test]
    fn stratum_agg_merge() {
        let mut a = StratumAgg {
            population: 100.0,
            ..Default::default()
        };
        let mut b = StratumAgg {
            population: 100.0,
            ..Default::default()
        };
        a.push(1.0);
        a.push(2.0);
        b.push(3.0);
        a.merge(&b);
        assert_eq!(a.count, 3.0);
        assert_eq!(a.sum, 6.0);
        assert_eq!(a.sumsq, 14.0);
    }

    #[test]
    fn variance_clamps_cancellation() {
        // huge mean + tiny variance: moment form would cancel; must stay >= 0
        let mut agg = StratumAgg::default();
        for _ in 0..10 {
            agg.push(1e9);
        }
        assert!(agg.variance() >= 0.0);
    }
}
