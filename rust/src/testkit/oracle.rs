//! The exact-twin oracle behind the differential test harness.
//!
//! [`ExactJoinOracle`] enumerates a join's ground truth — per-key strata,
//! output cardinality, and the exact aggregate — for **every**
//! [`JoinVariant`] by brute force over the raw per-input key groups,
//! completely independent of the engine's execution machinery (no
//! clusters, no shuffles, no filters, no sampling). Differential tests
//! (`tests/join_variants.rs`, `tests/estimator_soundness.rs`,
//! `tests/grouped_estimates.rs`, `tests/stream_windows.rs`) compare every
//! strategy's output against it: an agreement bug would have to exist in
//! both a one-screen enumeration and the distributed path to go unseen.

use crate::data::Dataset;
use crate::join::{cross_product_agg, padded_value, CombineOp, JoinVariant};
use crate::query::AggFunc;
use crate::stats::{ApproxResult, EstimatorKind, StratumAgg};
use std::collections::BTreeMap;

/// Brute-force ground truth of a join over concrete inputs.
///
/// Construction groups every input by key once; each query against the
/// oracle is then a pure function of those groups. `BTreeMap`s keep all
/// iteration in ascending key order, so repeated oracle calls are
/// bit-identical — the same determinism contract the engine itself is
/// tested for.
#[derive(Clone, Debug)]
pub struct ExactJoinOracle {
    groups: Vec<BTreeMap<u64, Vec<f64>>>,
}

impl ExactJoinOracle {
    /// Group each input's records by key (partitioning is irrelevant to
    /// the logical join result).
    pub fn new(inputs: &[Dataset]) -> Self {
        assert!(inputs.len() >= 2, "a join oracle needs >= 2 inputs");
        let groups = inputs
            .iter()
            .map(|d| {
                let mut g: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
                for p in &d.partitions {
                    for r in p {
                        g.entry(r.key).or_default().push(r.value);
                    }
                }
                g
            })
            .collect();
        Self { groups }
    }

    pub fn n_inputs(&self) -> usize {
        self.groups.len()
    }

    /// The exact per-key strata of `variant`: population is the variant's
    /// per-key output cardinality and the moments cover every output
    /// value. Inner joins are n-way; every other variant is binary, like
    /// the engine's `execute_variant`.
    pub fn strata(&self, op: CombineOp, variant: JoinVariant) -> BTreeMap<u64, StratumAgg> {
        if !variant.is_inner() {
            assert_eq!(
                self.n_inputs(),
                2,
                "{} oracle strata are binary",
                variant.tag()
            );
        }
        let mut strata: BTreeMap<u64, StratumAgg> = BTreeMap::new();
        match variant {
            JoinVariant::Inner
            | JoinVariant::LeftOuter
            | JoinVariant::RightOuter
            | JoinVariant::FullOuter => {
                // matched keys: the full cross product
                let mut sides: Vec<&[f64]> = Vec::with_capacity(self.n_inputs());
                'keys: for (&k, left) in &self.groups[0] {
                    sides.clear();
                    sides.push(left.as_slice());
                    for g in &self.groups[1..] {
                        match g.get(&k) {
                            Some(v) => sides.push(v.as_slice()),
                            None => continue 'keys,
                        }
                    }
                    strata.insert(k, cross_product_agg(&sides, op));
                }
                // unmatched keys of each padded side, one output row per
                // input row, neutral-filled through the combine op
                if variant.pads_left() {
                    self.pad_unmatched(&mut strata, op, 0);
                }
                if variant.pads_right() {
                    self.pad_unmatched(&mut strata, op, 1);
                }
            }
            JoinVariant::Semi | JoinVariant::Anti => {
                let want_member = variant == JoinVariant::Semi;
                let right = &self.groups[1];
                for (&k, left) in &self.groups[0] {
                    if right.contains_key(&k) != want_member {
                        continue;
                    }
                    strata.insert(k, Self::single_side(left, op, 0));
                }
            }
        }
        strata
    }

    fn pad_unmatched(
        &self,
        strata: &mut BTreeMap<u64, StratumAgg>,
        op: CombineOp,
        input: usize,
    ) {
        let other = &self.groups[1 - input];
        for (&k, vals) in &self.groups[input] {
            if !other.contains_key(&k) {
                strata.insert(k, Self::single_side(vals, op, input));
            }
        }
    }

    fn single_side(vals: &[f64], op: CombineOp, input: usize) -> StratumAgg {
        let mut agg = StratumAgg {
            population: vals.len() as f64,
            ..Default::default()
        };
        for &v in vals {
            agg.push(padded_value(op, input, v));
        }
        agg
    }

    /// Exact join-output cardinality of `variant` (Σ per-key populations;
    /// independent of the combine op).
    pub fn cardinality(&self, variant: JoinVariant) -> f64 {
        self.strata(CombineOp::Sum, variant)
            .values()
            .map(|s| s.population)
            .sum()
    }

    /// Exact Σ over every output value of `variant`.
    pub fn sum(&self, op: CombineOp, variant: JoinVariant) -> f64 {
        self.strata(op, variant).values().map(|s| s.sum).sum()
    }

    /// The exact answer as an [`ApproxResult`] (zero-width interval),
    /// through the same estimator dispatch the engine's exact path uses —
    /// so a coverage test's `|estimate - oracle| <= bound` comparison
    /// needs no special-casing per aggregate.
    pub fn result(
        &self,
        agg: AggFunc,
        op: CombineOp,
        variant: JoinVariant,
        confidence: f64,
    ) -> ApproxResult {
        let strata = self.strata(op, variant);
        let strata_vec: Vec<StratumAgg> = strata.into_values().collect();
        crate::relation::grouped::estimate_slice(
            agg,
            false,
            EstimatorKind::Clt,
            &strata_vec,
            &[],
            confidence,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Record;

    fn input(name: &str, recs: &[(u64, f64)]) -> Dataset {
        Dataset::from_records_unpartitioned(
            name,
            recs.iter().map(|&(k, v)| Record::new(k, v)).collect(),
            3,
            64,
        )
    }

    fn oracle() -> ExactJoinOracle {
        // a = {1:[1,2], 2:[10], 3:[5]}, b = {1:[100], 2:[200,300], 9:[1]}
        ExactJoinOracle::new(&[
            input("a", &[(1, 1.0), (1, 2.0), (2, 10.0), (3, 5.0)]),
            input("b", &[(1, 100.0), (2, 200.0), (2, 300.0), (9, 1.0)]),
        ])
    }

    #[test]
    fn hand_computed_variants() {
        let o = oracle();
        let op = CombineOp::Sum;
        // inner: key1 (1+100)+(2+100), key2 (10+200)+(10+300)
        assert_eq!(o.cardinality(JoinVariant::Inner), 4.0);
        assert!((o.sum(op, JoinVariant::Inner) - (203.0 + 520.0)).abs() < 1e-9);
        // left outer adds key3 padded with 5
        assert_eq!(o.cardinality(JoinVariant::LeftOuter), 5.0);
        assert!((o.sum(op, JoinVariant::LeftOuter) - 728.0).abs() < 1e-9);
        // right outer adds key9 padded with 1
        assert_eq!(o.cardinality(JoinVariant::RightOuter), 5.0);
        assert!((o.sum(op, JoinVariant::RightOuter) - 724.0).abs() < 1e-9);
        // full outer has both pads
        assert_eq!(o.cardinality(JoinVariant::FullOuter), 6.0);
        assert!((o.sum(op, JoinVariant::FullOuter) - 729.0).abs() < 1e-9);
        // semi keeps a's rows under matched keys {1, 2}
        assert_eq!(o.cardinality(JoinVariant::Semi), 3.0);
        assert!((o.sum(op, JoinVariant::Semi) - 13.0).abs() < 1e-9);
        // anti is the complement {3}
        assert_eq!(o.cardinality(JoinVariant::Anti), 1.0);
        assert!((o.sum(op, JoinVariant::Anti) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn variant_algebra_holds_on_random_inputs() {
        // the identities tests/join_variants.rs checks against the engine
        // must hold inside the oracle itself
        let mut r = crate::util::Rng::new(0xACE);
        for _ in 0..20 {
            let inputs = crate::testkit::gen::join_inputs(&mut r, 2, 4);
            let o = ExactJoinOracle::new(&inputs);
            let (inner, left, right, full) = (
                o.cardinality(JoinVariant::Inner),
                o.cardinality(JoinVariant::LeftOuter),
                o.cardinality(JoinVariant::RightOuter),
                o.cardinality(JoinVariant::FullOuter),
            );
            let semi = o.cardinality(JoinVariant::Semi);
            let anti = o.cardinality(JoinVariant::Anti);
            let left_rows: f64 = o.groups[0].values().map(|v| v.len() as f64).sum();
            assert_eq!(semi + anti, left_rows, "semi/anti partition the left");
            assert_eq!(left, inner + anti, "left outer = inner + left pads");
            assert_eq!(full, left + (right - inner), "full = left ∪ right pads");
        }
    }

    #[test]
    fn result_is_exact_with_zero_width_interval() {
        let o = oracle();
        let res = o.result(AggFunc::Sum, CombineOp::Sum, JoinVariant::FullOuter, 0.95);
        assert!((res.estimate - 729.0).abs() < 1e-9);
        assert_eq!(res.error_bound, 0.0);
        let count = o.result(AggFunc::Count, CombineOp::Sum, JoinVariant::Anti, 0.95);
        assert!((count.estimate - 1.0).abs() < 1e-9);
    }
}
