//! Minimal property-testing harness (the offline registry has no proptest;
//! hypothesis covers the Python side). Runs a check over many seeded cases
//! and, on failure, reports the case seed so the exact input reproduces
//! with `check_one`.

use crate::util::Rng;

pub mod oracle;
pub use oracle::ExactJoinOracle;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xA55,
        }
    }
}

/// Run `property` over `cfg.cases` independent cases. Each case gets its
/// own deterministic RNG; a panic inside the property is re-raised with
/// the case seed attached.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cfg: PropConfig, mut property: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (reproduce with \
                 check_one(\"{name}\", {case_seed:#x}, ..)):\n{msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn check_one<F: FnOnce(&mut Rng)>(_name: &str, case_seed: u64, property: F) {
    let mut rng = Rng::new(case_seed);
    property(&mut rng);
}

/// Everything about one streaming window that must be invariant under the
/// executor thread count: (window index, per-stratum aggregate bits,
/// per-stratum draw bits, per-stage per-worker ledger traffic, refreshed
/// count, carried count). Timings are measurements and are excluded.
pub type StreamWindowPrint = (
    u64,
    Vec<(u64, u64, u64, u64, u64)>,
    Vec<(u64, u64)>,
    Vec<(String, Vec<u64>, Vec<u64>)>,
    u64,
    u64,
);

/// The thread-invariance fingerprint of a streaming run — shared by
/// `tests/stream_windows.rs` and the `fig_stream_windows` bench so both
/// gates compare exactly the same surface (strata down to the last bit,
/// HT draw counts, and the per-worker byte vectors of every stage).
pub fn stream_fingerprint(run: &crate::stream::StreamRun) -> Vec<StreamWindowPrint> {
    run.windows
        .iter()
        .map(|w| {
            let mut strata: Vec<(u64, u64, u64, u64, u64)> = w
                .strata
                .iter()
                .map(|(&k, a)| {
                    (
                        k,
                        a.population.to_bits(),
                        a.count.to_bits(),
                        a.sum.to_bits(),
                        a.sumsq.to_bits(),
                    )
                })
                .collect();
            strata.sort_unstable();
            let mut draws: Vec<(u64, u64)> =
                w.draws.iter().map(|(&k, d)| (k, d.to_bits())).collect();
            draws.sort_unstable();
            let ledger: Vec<(String, Vec<u64>, Vec<u64>)> = w
                .ledger
                .stages
                .iter()
                .map(|s| (s.stage.clone(), s.bytes_in.clone(), s.bytes_out.clone()))
                .collect();
            (
                w.bounds.index,
                strata,
                draws,
                ledger,
                w.refreshed_strata,
                w.carried_strata,
            )
        })
        .collect()
}

/// Generators for common test inputs.
pub mod gen {
    use crate::data::{Dataset, Record};
    use crate::util::Rng;

    /// A random dataset: `keys` distinct keys, up to `max_per_key` copies,
    /// values uniform in [-10, 10).
    pub fn dataset(r: &mut Rng, name: &str, keys: u64, max_per_key: u64, parts: usize) -> Dataset {
        let mut recs = Vec::new();
        for key in 0..keys {
            let copies = 1 + r.below(max_per_key.max(1));
            for _ in 0..copies {
                recs.push(Record::new(key, r.range_f64(-10.0, 10.0)));
            }
        }
        Dataset::from_records_unpartitioned(name, recs, parts, 64)
    }

    /// n random datasets over overlapping key ranges (some keys common to
    /// all, some private per input).
    pub fn join_inputs(r: &mut Rng, n: usize, parts: usize) -> Vec<Dataset> {
        let common = 1 + r.below(20);
        (0..n)
            .map(|i| {
                let mut d = dataset(r, &format!("in{i}"), common, 6, parts);
                // private tail pool
                let private = r.below(30);
                let mut extra = Vec::new();
                for p in 0..private {
                    extra.push(Record::new(
                        (1 << 50) | ((i as u64) << 40) | p,
                        r.range_f64(-10.0, 10.0),
                    ));
                }
                for (j, rec) in extra.into_iter().enumerate() {
                    d.partitions[j % parts].push(rec);
                }
                d
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("counts", PropConfig { cases: 10, seed: 1 }, |_r| {
            count += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn failing_property_reports_case_seed() {
        let r = std::panic::catch_unwind(|| {
            check("fails", PropConfig { cases: 5, seed: 2 }, |r| {
                assert!(r.f64() < 2.0); // always true
                panic!("boom {}", r.below(10));
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("case 0"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("det", PropConfig { cases: 4, seed: 3 }, |r| {
            first.push(r.next_u64());
        });
        let mut second: Vec<u64> = Vec::new();
        check("det", PropConfig { cases: 4, seed: 3 }, |r| {
            second.push(r.next_u64());
        });
        assert_eq!(first, second);
    }

    #[test]
    fn generators_produce_joinable_inputs() {
        let mut r = crate::util::Rng::new(5);
        let inputs = gen::join_inputs(&mut r, 3, 4);
        assert_eq!(inputs.len(), 3);
        let f = crate::data::overlap_fraction(&inputs);
        assert!(f > 0.0, "inputs must share keys");
    }
}
