//! Appendix A analytic model: closed-form shuffled-data-volume formulas for
//! broadcast join (eq 18-20), repartition join (eq 21-23) and ApproxJoin's
//! Bloom-filtered join (eq 24-27), plus the Bloom-variant size model behind
//! Figure 15. These regenerate Figures 4 and 14 exactly as the paper does —
//! by model-driven simulation, not cluster execution.

use crate::bloom::hashing;

/// Inputs to the communication model.
#[derive(Clone, Debug)]
pub struct ShuffleModel {
    /// Input sizes |R_1| .. |R_n| in *records*.
    pub input_sizes: Vec<u64>,
    /// Bytes per record on the wire.
    pub record_bytes: u64,
    /// Cluster size k.
    pub k: u64,
    /// Overlap fraction (participating ÷ total items, §3.1.1).
    pub overlap_fraction: f64,
    /// Bloom filter false-positive rate (drives |BF| via eq 27 and adds
    /// fp·non-participating leakage to the filtered shuffle).
    pub fp_rate: f64,
}

impl ShuffleModel {
    fn total_records(&self) -> u64 {
        self.input_sizes.iter().sum()
    }

    /// Broadcast join (eq 18): all but the largest input go to k−1 nodes.
    pub fn broadcast_bytes(&self) -> u64 {
        let max = self.input_sizes.iter().max().copied().unwrap_or(0);
        let small: u64 = self.total_records() - max;
        small * self.record_bytes * (self.k - 1)
    }

    /// Repartition join (eq 21): every record moves with prob (k−1)/k.
    pub fn repartition_bytes(&self) -> u64 {
        (self.total_records() as f64 * self.record_bytes as f64 * (self.k - 1) as f64
            / self.k as f64) as u64
    }

    /// Bloom filter size in bits (eq 27) with N = |R_n| (largest input).
    pub fn filter_bits(&self) -> u64 {
        let n = self.input_sizes.iter().max().copied().unwrap_or(1).max(1);
        hashing::bits_for_fp_rate(n, self.fp_rate)
    }

    /// ApproxJoin filtering (eq 24): filter construction + broadcast +
    /// filtered record shuffle, including false-positive leakage.
    pub fn bloom_bytes(&self) -> u64 {
        let n = self.input_sizes.len() as u64;
        let bf_bytes = self.filter_bits().div_ceil(8);
        let filters = bf_bytes * (self.k - 1) * (n + 1);
        self.bloom_record_bytes(self.fp_rate) + filters
    }

    /// The record-movement part of eq 24: participating items plus the
    /// false-positive leakage of non-participating items.
    fn bloom_record_bytes(&self, fp: f64) -> u64 {
        let total = self.total_records() as f64;
        let participating = total * self.overlap_fraction;
        // a non-participating record must pass the AND of the other n−1
        // dataset filters' bits in the join filter: the classic per-filter
        // fp applies to the intersection filter once
        let leaked = (total - participating) * fp;
        ((participating + leaked) * self.record_bytes as f64 * (self.k - 1) as f64
            / self.k as f64) as u64
    }

    /// Optimal ApproxJoin (Fig 14's lower envelope): zero false positives,
    /// filters still paid.
    pub fn bloom_bytes_optimal(&self) -> u64 {
        let n = self.input_sizes.len() as u64;
        let bf_bytes = self.filter_bits().div_ceil(8);
        self.bloom_record_bytes(0.0) + bf_bytes * (self.k - 1) * (n + 1)
    }

    /// Marginal shuffled bytes of adding one more node (eq 19/22/25).
    pub fn marginal_per_node(&self) -> (f64, f64, f64) {
        let grow = |f: &dyn Fn(&ShuffleModel) -> u64| {
            let mut bigger = self.clone();
            bigger.k += 1;
            f(&bigger) as f64 - f(self) as f64
        };
        (
            grow(&|m| m.broadcast_bytes()),
            grow(&|m| m.repartition_bytes()),
            grow(&|m| m.bloom_bytes()),
        )
    }
}

/// Figure 15's size model: bytes of each Bloom-filter variant for `items`
/// keys at a target fp rate. Cell widths: standard 1 bit, counting 8 bits
/// (u8 counters), invertible 20 bytes (count + keySum + hashSum), scalable
/// ~1.2x standard (growth slack across slices).
pub fn variant_sizes(items: u64, fp_rate: f64) -> VariantSizes {
    let bits = hashing::bits_for_fp_rate(items, fp_rate);
    let standard = bits.div_ceil(8);
    VariantSizes {
        standard,
        // CBF: one u8 counter per cell -> 8x the bit vector
        counting: bits,
        // IBF: same cell count as the CBF keeps the "not found" failure
        // rate at the corresponding fp level (Appendix B I), but each cell
        // is (count, keySum, hashSum) = 20 bytes instead of one counter
        invertible: bits.saturating_mul(20),
        scalable: (standard as f64 * 1.2) as u64,
    }
}

/// Sizes in bytes of the four variants (Appendix B / Fig 15).
#[derive(Clone, Copy, Debug)]
pub struct VariantSizes {
    pub standard: u64,
    pub counting: u64,
    pub invertible: u64,
    pub scalable: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_model() -> ShuffleModel {
        // Appendix A.1 simulation setup: |R1|=1e4, |R2|=1e6, |R3|=1e7,
        // overlap 1%, k=100
        // Appendix A.1 records are full tuples (the paper's inputs are
        // KB-scale raw rows); 1000B keeps the filter term from dominating,
        // matching Fig 14's ordering
        ShuffleModel {
            input_sizes: vec![10_000, 1_000_000, 10_000_000],
            record_bytes: 1000,
            k: 100,
            overlap_fraction: 0.01,
            fp_rate: 0.01,
        }
    }

    #[test]
    fn bloom_beats_both_at_low_overlap() {
        let m = paper_model();
        let bc = m.broadcast_bytes();
        let re = m.repartition_bytes();
        let bf = m.bloom_bytes();
        assert!(bf < re, "bloom {bf} vs repartition {re}");
        assert!(bf < bc, "bloom {bf} vs broadcast {bc}");
        // paper's Fig 4: broadcast worst at k=100 with a huge R3 resident
        assert!(bc > re);
    }

    #[test]
    fn bloom_advantage_shrinks_with_overlap() {
        let mut m = paper_model();
        m.overlap_fraction = 0.01;
        let low = m.bloom_bytes() as f64 / m.repartition_bytes() as f64;
        m.overlap_fraction = 0.4;
        let high = m.bloom_bytes() as f64 / m.repartition_bytes() as f64;
        assert!(low < high);
        assert!(high > 0.35, "at 40% overlap the gap closes (got {high})");
    }

    #[test]
    fn fp_001_reaches_optimal() {
        // paper: "when the false positive rate is <= 0.01, ApproxJoin
        // reaches the optimal case"
        let mut m = paper_model();
        m.fp_rate = 0.01;
        let ratio_001 = m.bloom_bytes() as f64 / m.bloom_bytes_optimal() as f64;
        assert!(ratio_001 < 1.1, "ratio {ratio_001}");
        m.fp_rate = 0.5;
        let ratio_05 = m.bloom_bytes() as f64 / m.bloom_bytes_optimal() as f64;
        assert!(ratio_05 > 3.0, "ratio {ratio_05}");
    }

    #[test]
    fn repartition_grows_with_inputs_bloom_barely() {
        let m2 = ShuffleModel {
            input_sizes: vec![1_000_000; 2],
            ..paper_model()
        };
        let m8 = ShuffleModel {
            input_sizes: vec![1_000_000; 8],
            ..paper_model()
        };
        let re_growth = m8.repartition_bytes() as f64 / m2.repartition_bytes() as f64;
        let bf_growth = m8.bloom_bytes() as f64 / m2.bloom_bytes() as f64;
        assert!(re_growth > 3.5, "repartition x{re_growth}");
        assert!(bf_growth < re_growth, "bloom x{bf_growth}");
    }

    #[test]
    fn marginal_node_cost_ordering() {
        let m = paper_model();
        let (bc, re, bf) = m.marginal_per_node();
        // broadcast pays a full small-input copy per node; bloom pays
        // filters only; repartition pays ~1/k² of the data
        assert!(bc > bf);
        assert!(bc > re);
    }

    #[test]
    fn variant_size_ordering_matches_fig15() {
        let s = variant_sizes(100_000, 0.01);
        assert!(s.standard < s.scalable);
        assert!(s.scalable < s.counting);
        assert!(s.counting < s.invertible);
        // CBF is ~8x standard by construction (modulo byte rounding)
        assert!(s.counting >= s.standard * 8 - 8 && s.counting <= s.standard * 8);
    }
}
