//! # ApproxJoin
//!
//! Reproduction of *"Approximate Distributed Joins in Apache Spark"*
//! (Quoc et al., 2018) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-join coordinator: Bloom-filter
//!   join filtering (§3.1), budget-driven stratified sampling *during* the
//!   join (§3.2–3.3), CLT / Horvitz-Thompson error estimation (§3.4), on a
//!   simulated Spark-like cluster substrate with exact shuffle accounting.
//! * **L2/L1 (python/compile, build-time only)** — the numeric hot paths
//!   (Bloom probe, per-stratum sample aggregation, CLT moments) authored in
//!   JAX + Pallas, AOT-lowered to HLO text, and executed from Rust through
//!   the PJRT CPU client ([`runtime`]). Python never runs on the query path.
//!
//! ## Architecture: strategies, planner, session
//!
//! The paper's contribution is an *operator*: a drop-in join whose
//! execution strategy is chosen by a cost function, not by the caller. The
//! crate mirrors that shape:
//!
//! * [`join::JoinStrategy`] — one trait over the five join
//!   implementations (`native`, `repartition`, `broadcast`, `bloom`,
//!   `approx`), each answering `execute` and `estimate_cost`, collected in
//!   a [`join::StrategyRegistry`]. Adding a strategy is a registry entry,
//!   not a new code path.
//! * [`join::Planner`] — ranks the registered strategies on cheap
//!   [`join::InputStats`] with the [`cost::CostModel`] and produces an
//!   inspectable [`join::JoinPlan`] (`explain()` prints the ranking).
//! * [`session::Session`] — the fluent entry point:
//!
//! ```no_run
//! use approxjoin::coordinator::EngineConfig;
//! use approxjoin::data::{generate_overlapping, SyntheticSpec};
//! use approxjoin::session::{Session, StrategyChoice};
//!
//! # fn main() -> anyhow::Result<()> {
//! let inputs = generate_overlapping(&SyntheticSpec::default());
//! let outcome = Session::new(EngineConfig::default())?
//!     .with_data("a", inputs[0].clone())
//!     .with_data("b", inputs[1].clone())
//!     .sql("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 10 SECONDS")?
//!     .strategy(StrategyChoice::Auto)
//!     .run()?;
//! println!(
//!     "{} ± {} via {}",
//!     outcome.result.estimate, outcome.result.error_bound, outcome.strategy
//! );
//! # Ok(())
//! # }
//! ```
//!
//! Budget queries (`WITHIN … SECONDS`, `ERROR … CONFIDENCE …`) route
//! through the [`coordinator::ApproxJoinEngine`]'s §3.2 pipeline, which
//! sizes the sampling fraction from the measured filter time; unbudgeted
//! queries run the cheapest feasible exact strategy. The `approxjoin` CLI
//! (main.rs) exposes the same flow — `query`, `compare`, `explain`,
//! `profile`, `simulate` — and `examples/` are guided walkthroughs.
//!
//! ## Partition-parallel execution & shuffle accounting
//!
//! Every strategy executes its heavy loops — Bloom-shard construction,
//! filter probing, per-key cross products, per-stratum sampling — through
//! the [`runtime::ParallelExecutor`]: an order-preserving map over
//! partition/worker indices running on 1..=N OS threads
//! (`EngineConfig::parallelism`, CLI `--threads`, env
//! `APPROXJOIN_THREADS`). Per-worker RNGs are forked deterministically
//! before any thread starts and partial results merge in index order, so
//! **given the same sampling decisions (fixed seed + fixed sampling
//! params), the output is bit-identical to the sequential path at any
//! thread count** (asserted across all five strategies in
//! `tests/parallel_equivalence.rs`). The one exception: latency-budgeted
//! engine queries size their sampling fraction from *measured* filter
//! wall time, which legitimately varies with thread count and load.
//!
//! Alongside the analytic shuffle *predictions* of the cost model, every
//! run now carries a [`cluster::ShuffleLedger`] — measured bytes in/out
//! per stage per worker — surfaced through [`join::JoinRun`],
//! `QueryOutcome`, and `JoinPlan::explain()` (predicted vs measured side
//! by side).
//!
//! ## Streaming windowed execution
//!
//! The [`stream`] module drives the same pipeline incrementally over an
//! unbounded micro-batched stream (the StreamApprox direction, arXiv
//! 1709.02946): a [`stream::StreamingApproxJoin`] maintains persistent
//! per-input *counting*-Bloom sketches incrementally from worker-shipped
//! deltas — arriving tuples are inserted, expired tuples are **deleted**
//! on window eviction, the sketch is never rebuilt — probes each
//! tumbling/sliding window
//! ([`stream::WindowSpec`]) against the ANDed window join filter, shuffles
//! only the survivors (per-window measured [`cluster::ShuffleLedger`]),
//! and keeps **eviction-aware per-stratum reservoirs**
//! ([`sampling::stratified::StratumReservoir`]): only strata touched by
//! arriving/expiring batches re-draw their sample; untouched strata carry
//! it over verbatim. Every emitted window carries a
//! [`stats::ApproxResult`] from the same CLT / Horvitz-Thompson
//! estimators as the batch path, and window outputs (strata, draws,
//! ledger) are bit-identical for any thread count. Front ends:
//! [`session::StreamingSession`], the `approxjoin stream` CLI subcommand,
//! `examples/streaming_windows.rs`, and the `fig_stream_windows` bench.
//!
//! ## Join variants & sample-first baselines
//!
//! Beyond the inner equi-join, every strategy answers the binary variants
//! of [`join::JoinVariant`] through `JoinStrategy::execute_variant`:
//! `FROM a LEFT/RIGHT/FULL OUTER JOIN b ON a.k = b.k` pads each unmatched
//! key as a dedicated stratum (neutral-fill values via the combine op, so
//! padded estimates stay bit-identical at any thread count), and
//! `SEMI / ANTI JOIN` resolve from **stage-1 Bloom membership alone** — an
//! exact key-set intersection at the master cancels the filter's false
//! positives, the `membership` stage ships 8 bytes per distinct surviving
//! key, and the measured [`cluster::ShuffleLedger`] shows *zero* stage-2
//! shuffle bytes (no `filter_shuffle` / `shuffle` / `crossproduct` /
//! `sample` stages at all). The streaming operator answers the same
//! variants per window on its exact unfiltered path
//! (`StreamConfig::variant`). Alongside the sample-*during*-the-join
//! pipeline, the registry carries the centralized sample-*first* baselines
//! of "Joins on Samples": [`join::BernoulliJoin`] (row-level sampling,
//! inner only — a sampled row cannot prove a key's absence) and
//! [`join::UniverseJoin`] (shared-hash key sampling, all variants), each
//! shipping its sample to the master, joining there, and answering through
//! its own closed-form estimator — they never win `Auto` planning, but are
//! selectable by name for quality-vs-cost comparisons
//! (`benches/fig_join_variants.rs`). The exact twins live in
//! [`testkit::oracle::ExactJoinOracle`], which `tests/join_variants.rs`
//! uses to check differential algebra identities (left outer = inner +
//! anti-left pads; anti = semi's complement; full outer = left ∪ right)
//! and CI coverage for every variant.
//!
//! ## Relational front end
//!
//! The [`relation`] module generalizes the two-column `Dataset` into
//! typed multi-column [`relation::Relation`]s
//! (`Session::register_table(name, schema, rows)`) and a logical plan
//! `scan → filter → equi-join → group_by → aggregate` that *lowers* onto
//! the unchanged (key64, f64) join kernel:
//!
//! * **Predicate pushdown** — `WHERE a.x > c AND …` filters evaluate
//!   before Bloom sketching, so the join filter is built from
//!   post-filter keys only (`JoinPlan::explain()` shows each pushed
//!   predicate with its measured selectivity).
//! * **Per-aggregate projection** — every aggregate of the SELECT list
//!   (`SUM(a.v + b.v) AS total, AVG(a.x), COUNT(*)`) projects the inputs
//!   to kernel records over identical stratum keys.
//! * **GROUP BY with per-group error bounds** — group keys map onto the
//!   per-stratum sampling machinery via composite `(join key, group)`
//!   stratum ids; [`coordinator::QueryOutcome::grouped`] then carries a
//!   [`relation::GroupedApproxResult`]: one `estimate ± CI` per group
//!   per aggregate, from the same stratified CLT / Horvitz-Thompson
//!   estimators — bit-identical at any thread count.
//!
//! ## Serving layer
//!
//! The [`serve`] module turns the one-shot session API into a
//! multi-tenant front: a [`serve::Server`] runs scripted concurrent
//! clients ([`serve::Workload`]), each in an isolated session with its
//! own feedback scope and [`serve::ResultCache`] (staleness surfaces as
//! *widened* confidence intervals), while all clients share one
//! [`serve::SketchCache`] of stage-1 artifacts — built Bloom filters and
//! filtered cogroups keyed by `(tables@epoch, pushed predicates, filter
//! kind/geometry, workers)`, invalidated by re-registration, with hits
//! visible in `explain()`. An [`serve::AdmissionController`] schedules
//! under a latency SLO over deterministic virtual-time lanes: it admits,
//! then *degrades* (shrinks sampling budgets — the §3.2 dial — answers
//! get wider CIs, not slower), and only past a hard backlog limit
//! rejects with the typed `JoinError::Overloaded`. Admission never reads
//! host concurrency, and cached sketches replay bit-identically, so a
//! concurrent run's answers equal a sequential replay
//! ([`serve::ServeReport::signature`]). Front ends: `approxjoin serve`,
//! `examples/serving_workload.rs`, and the `fig_serving` bench.
//!
//! ## Join ordering
//!
//! Multi-way (3+ relation) joins are reordered before execution by
//! [`join::order`]: the AND-ed equi-join chains of the query become a
//! join graph ([`join::JoinGraph`], sharing one connectivity check with
//! the parser), and a Selinger-style dynamic program over connected
//! subsets (exhaustive for ≤ 8 relations, greedy min-cost above) picks
//! the left-deep order minimizing a multi-objective cost — intermediate
//! rows, cpu, io, and shuffled bytes — under the same time model the
//! strategy planner uses. Cardinalities come from a
//! [`join::order::CardinalityEstimator`] that starts from a containment
//! default (`1/max(distinct)`) and *learns*: after every run the
//! measured [`cluster::ShuffleLedger`] bytes and exact per-pair join
//! selectivities are written into the [`cost::FeedbackStore`] keyed by
//! (table pair, predicate tag), so later plans for the same shape are
//! calibrated by observation. Planning is a pure function of (query,
//! input stats, feedback snapshot) — never of thread count — so
//! reordered runs stay bit-identical at any parallelism; only
//! commutative combines (`Sum`, `Product`) are ever reordered, the
//! original FROM order is kept unless the optimizer's order is strictly
//! cheaper, and `explain()` prints the chosen order with per-step
//! predicted vs measured cardinality
//! ([`join::JoinOrderReport`]). `EngineConfig::reorder_joins` (default
//! on) disables it.
//!
//! ## Continuous standing queries
//!
//! The [`continuous`] module closes ROADMAP item 2 (DBSP-style delta
//! maintenance): a [`continuous::ContinuousEngine`] holds standing
//! relational queries registered once — predicates, group columns, and
//! join-variant checks resolved at registration — and updates every one
//! of them from each micro-batch's **arrival/eviction delta**. Columnar
//! cogroups are spliced in place ([`runtime::CogroupColumns::apply_delta`]
//! merges arriving runs and retracts evicted per-key prefixes), only the
//! strata of changed keys re-draw their CLT/HT samples, and only groups
//! owning a touched stratum re-estimate, emitting
//! [`continuous::Notification`]s in deterministic order when results
//! change bits. The standing invariant — incremental state after N
//! batches is **bit-identical** to a from-scratch window recompute
//! ([`continuous::ContinuousEngine::recompute`]) at any thread count —
//! is asserted per batch in `tests/continuous_queries.rs`. Front ends:
//! [`session::StreamingSession::open_continuous`], the `approxjoin
//! continuous` CLI subcommand, serving subscriptions
//! (`serve::SubscriptionWorkload`), `examples/continuous_queries.rs`,
//! and the `fig_continuous` bench.
//!
//! ## Fault injection & accuracy-preserving recovery
//!
//! The [`faults`] module makes the simulated cluster unreliable on
//! purpose — deterministically. A [`faults::FaultPlan`] decides crashes,
//! lost shuffle partitions, stragglers, and send failures as pure
//! hashes of `(seed, kind, stage, occurrence, worker)`, consulted at the
//! [`cluster::SimCluster::record`] chokepoint so every execution path is
//! covered without per-strategy code (`SimCluster::with_faults`,
//! `EngineConfig::faults`, CLI `--faults`). Recovery mirrors Spark's
//! lineage model — bounded retry with virtual-time backoff, upstream
//! re-fetch and task re-execution, speculative straggler copies — and is
//! strictly *additive*: `recovery/{stage}` ledger/metrics rows price the
//! repair next to the traffic it repairs, primary rows stay untouched,
//! and a zero-probability plan is bit-identical to no plan. When the
//! failure budget runs out, workers die and sampled runs **degrade
//! instead of erroring** ([`faults::degrade_strata`]): dead workers'
//! strata drop, survivors re-weight to keep targeting the full
//! population, and the measured between-strata loss variance widens the
//! confidence interval — the estimate is bit-unchanged, the interval
//! honest. Exact runs fail with the typed `JoinError::Degraded`. Every
//! outcome carries a [`faults::FaultReport`], and the serving layer's
//! admission prices the plan's expected overhead before any stage runs.
//! `tests/fault_recovery.rs` holds the chaos contract: 100-seed ≥ 85%
//! CI coverage under worker death, 1/2/8-thread bit-identity of faulted
//! runs, and kill-all fuzz without a single panic.

pub mod bloom;
pub mod cluster;
pub mod continuous;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod faults;
pub mod join;
pub mod query;
pub mod relation;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod session;
pub mod simulation;
pub mod stats;
pub mod stream;
pub mod testkit;
pub mod util;

pub use anyhow::Result;
pub use session::{Session, StrategyChoice, StreamingSession};
