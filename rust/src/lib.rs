//! # ApproxJoin
//!
//! Reproduction of *"Approximate Distributed Joins in Apache Spark"*
//! (Quoc et al., 2018) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-join coordinator: Bloom-filter
//!   join filtering (§3.1), budget-driven stratified sampling *during* the
//!   join (§3.2–3.3), CLT / Horvitz-Thompson error estimation (§3.4), on a
//!   simulated Spark-like cluster substrate with exact shuffle accounting.
//! * **L2/L1 (python/compile, build-time only)** — the numeric hot paths
//!   (Bloom probe, per-stratum sample aggregation, CLT moments) authored in
//!   JAX + Pallas, AOT-lowered to HLO text, and executed from Rust through
//!   the PJRT CPU client ([`runtime`]). Python never runs on the query path.
//!
//! Entry points: [`coordinator::ApproxJoinEngine`] for the programmatic
//! API, `approxjoin` (main.rs) for the CLI, `examples/` for walkthroughs.

pub mod bloom;
pub mod cluster;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod join;
pub mod query;
pub mod runtime;
pub mod sampling;
pub mod simulation;
pub mod stats;
pub mod testkit;
pub mod util;

pub use anyhow::Result;
