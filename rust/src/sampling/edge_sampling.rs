//! Stratified edge sampling on the complete n-partite join graph without
//! materializing it (paper §3.3, Algorithm 2).
//!
//! Per join key C_i the matching tuples form a complete bipartite
//! (n-partite) graph; an output sample of size b_i is drawn by b_i times
//! independently picking one endpoint per side — O(b_i) work instead of the
//! O(Π|side|) full cross product. Two variants:
//!
//! * with replacement (CLT error estimation, §3.4 I) — duplicates kept;
//! * deduplicated (Horvitz-Thompson, §3.4 II) — a hash set drops duplicate
//!   edges and draws continue until b_i distinct edges (or the stratum is
//!   exhausted); the HT estimator then removes the induced bias.

use crate::join::CombineOp;
use crate::stats::StratumAgg;
use crate::util::Rng;

/// Raw sampled pair values destined for the AOT `join_agg` artifact:
/// the n-way draw is pre-reduced to (left, right) with the same combine op
/// (associative for Sum/Product), so `combine(left, right)` equals the
/// combine over all n endpoint values.
#[derive(Clone, Debug, Default)]
pub struct SampledPairs {
    pub left: Vec<f64>,
    pub right: Vec<f64>,
}

impl SampledPairs {
    pub fn len(&self) -> usize {
        self.left.len()
    }

    pub fn is_empty(&self) -> bool {
        self.left.is_empty()
    }
}

/// Population size Π |side_i| of a key group, saturating. Generic over
/// the side container (`Vec<f64>` cogroups or columnar `&[f64]` runs).
pub fn population<S: AsRef<[f64]>>(sides: &[S]) -> f64 {
    sides.iter().map(|s| s.as_ref().len() as f64).product()
}

/// Draw one edge: one uniform endpoint per side; returns the endpoint
/// indices in `idx`.
#[inline]
fn draw<S: AsRef<[f64]>>(r: &mut Rng, sides: &[S], idx: &mut [usize]) {
    for (d, side) in sides.iter().enumerate() {
        idx[d] = r.index(side.as_ref().len());
    }
}

/// Stratified sampling with replacement (Alg 2 sampleAndExecute):
/// aggregates b draws directly into a `StratumAgg`. The RNG consumption
/// and f64 order depend only on side lengths and values, not on the
/// container — `Vec<f64>` and columnar `&[f64]` sides sample identically.
pub fn sample_edges_with_replacement<S: AsRef<[f64]>>(
    r: &mut Rng,
    sides: &[S],
    b: u64,
    op: CombineOp,
) -> StratumAgg {
    let mut agg = StratumAgg {
        population: population(sides),
        ..Default::default()
    };
    if sides.iter().any(|s| s.as_ref().is_empty()) || b == 0 {
        return agg;
    }
    let n = sides.len();
    let mut idx = vec![0usize; n];
    let mut vals = vec![0.0f64; n];
    for _ in 0..b {
        draw(r, sides, &mut idx);
        for d in 0..n {
            vals[d] = sides[d].as_ref()[idx[d]];
        }
        agg.push(op.combine(&vals));
    }
    agg
}

/// With-replacement sampling that emits raw (left, right) pair values for
/// the runtime path instead of aggregating locally. For n > 2 the first
/// n−1 endpoint values are pre-reduced with `op` into `left`.
pub fn sample_pairs_with_replacement<S: AsRef<[f64]>>(
    r: &mut Rng,
    sides: &[S],
    b: u64,
    op: CombineOp,
    out: &mut SampledPairs,
) -> f64 {
    let pop = population(sides);
    if sides.iter().any(|s| s.as_ref().is_empty()) || b == 0 {
        return pop;
    }
    let n = sides.len();
    let mut idx = vec![0usize; n];
    out.left.reserve(b as usize);
    out.right.reserve(b as usize);
    for _ in 0..b {
        draw(r, sides, &mut idx);
        let mut left = sides[0].as_ref()[idx[0]];
        for d in 1..n - 1 {
            left = op.fold(left, sides[d].as_ref()[idx[d]]);
        }
        out.left.push(left);
        out.right.push(sides[n - 1].as_ref()[idx[n - 1]]);
    }
    pop
}

/// Deduplicated sampling for the Horvitz-Thompson path: resample until b
/// *distinct* edges are collected (capped at the stratum population and at
/// `max_attempts` to bound the coupon-collector tail). Returns the
/// deduplicated aggregate plus the raw draw count used for π_i.
pub fn sample_edges_dedup<S: AsRef<[f64]>>(
    r: &mut Rng,
    sides: &[S],
    b: u64,
    op: CombineOp,
) -> (StratumAgg, f64) {
    let pop = population(sides);
    let mut agg = StratumAgg {
        population: pop,
        ..Default::default()
    };
    if sides.iter().any(|s| s.as_ref().is_empty()) || b == 0 {
        return (agg, 0.0);
    }
    let n = sides.len();
    let target = (b as f64).min(pop) as u64;
    let max_attempts = b.saturating_mul(20).max(64);
    let mut seen = std::collections::HashSet::new();
    let mut idx = vec![0usize; n];
    let mut vals = vec![0.0f64; n];
    let mut draws = 0f64;
    while (agg.count as u64) < target && (draws as u64) < max_attempts {
        draw(r, sides, &mut idx);
        draws += 1.0;
        // encode the edge as its odometer rank
        let mut rank = 0u128;
        for d in 0..n {
            rank = rank * sides[d].as_ref().len() as u128 + idx[d] as u128;
        }
        if seen.insert(rank) {
            for d in 0..n {
                vals[d] = sides[d].as_ref()[idx[d]];
            }
            agg.push(op.combine(&vals));
        }
    }
    (agg, draws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::join::cross_product_agg;

    fn sides2() -> Vec<Vec<f64>> {
        vec![vec![1.0, 2.0, 3.0], vec![10.0, 20.0, 30.0, 40.0]]
    }

    #[test]
    fn population_product() {
        assert_eq!(population(&sides2()), 12.0);
        assert_eq!(population(&[vec![1.0], vec![], vec![2.0]]), 0.0);
    }

    #[test]
    fn with_replacement_draws_exactly_b() {
        let mut r = Rng::new(1);
        let agg = sample_edges_with_replacement(&mut r, &sides2(), 100, CombineOp::Sum);
        assert_eq!(agg.count, 100.0);
        assert_eq!(agg.population, 12.0);
    }

    #[test]
    fn with_replacement_mean_estimates_population_mean() {
        let mut r = Rng::new(2);
        let truth = cross_product_agg(&sides2(), CombineOp::Sum);
        let agg = sample_edges_with_replacement(&mut r, &sides2(), 20_000, CombineOp::Sum);
        let true_mean = truth.sum / truth.population;
        assert!(
            (agg.mean() - true_mean).abs() < 0.5,
            "{} vs {}",
            agg.mean(),
            true_mean
        );
    }

    #[test]
    fn empty_side_yields_empty_sample() {
        let mut r = Rng::new(3);
        let agg =
            sample_edges_with_replacement(&mut r, &[vec![1.0], vec![]], 50, CombineOp::Sum);
        assert_eq!(agg.count, 0.0);
        assert_eq!(agg.population, 0.0);
    }

    #[test]
    fn pairs_prereduction_matches_full_combine() {
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let sides = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let agg = sample_edges_with_replacement(&mut r1, &sides, 500, CombineOp::Sum);
        let mut pairs = SampledPairs::default();
        sample_pairs_with_replacement(&mut r2, &sides, 500, CombineOp::Sum, &mut pairs);
        // identical RNG stream -> identical draws -> combined equal
        let sum: f64 = pairs
            .left
            .iter()
            .zip(&pairs.right)
            .map(|(l, rv)| l + rv)
            .sum();
        assert!((sum - agg.sum).abs() < 1e-9);
        assert_eq!(pairs.len(), 500);
    }

    #[test]
    fn dedup_never_duplicates_and_caps_at_population() {
        let mut r = Rng::new(5);
        let sides = vec![vec![1.0, 2.0], vec![10.0, 20.0]]; // pop = 4
        let (agg, draws) = sample_edges_dedup(&mut r, &sides, 100, CombineOp::Sum);
        assert_eq!(agg.count, 4.0, "must collect every distinct edge");
        assert!(draws >= 4.0);
        // the four distinct pair-sums: 11,21,12,22
        assert_eq!(agg.sum, 66.0);
    }

    #[test]
    fn dedup_bounded_attempts() {
        let mut r = Rng::new(6);
        // pathological: pop 1, ask for 5 -> must stop quickly
        let (agg, draws) = sample_edges_dedup(&mut r, &[vec![1.0], vec![1.0]], 5, CombineOp::Sum);
        assert_eq!(agg.count, 1.0);
        assert!(draws <= 100.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sample_edges_with_replacement(&mut Rng::new(7), &sides2(), 50, CombineOp::Sum);
        let b = sample_edges_with_replacement(&mut Rng::new(7), &sides2(), 50, CombineOp::Sum);
        assert_eq!(a, b);
    }
}
