//! The two baseline sampling placements Figure 1 compares against:
//!
//! * **pre-join** (`sample_by_key`) — Spark's `sampleByKey` on the *inputs*
//!   before joining. Fast, but the join of two p-samples keeps only ~p² of
//!   the matching pairs and badly distorts per-key output statistics (the
//!   order-of-magnitude accuracy loss in Fig 1/13c).
//! * **post-join** (`post_join_reservoir`) — stratified sampling over the
//!   join *output* after computing it in full. Accurate, but pays the
//!   whole cross-product + shuffle first (Fig 1's 3-7x slowdown; the
//!   "extended repartition join" and SnappyData baselines of §5.3/§5.5).

use crate::data::Dataset;
use crate::join::approx::SamplingParams;
use crate::join::CombineOp;
use crate::runtime::ParallelExecutor;
use crate::sampling::edge_sampling::{
    population, sample_edges_dedup, sample_edges_with_replacement,
};
use crate::stats::{EstimatorKind, StratumAgg};
use crate::util::Rng;
use std::collections::{HashMap, HashSet};

/// Spark `sampleByKey`: keep each record independently with probability
/// `fraction` (per-key simple random sampling of the inputs).
pub fn sample_by_key(dataset: &Dataset, fraction: f64, rng: &mut Rng) -> Dataset {
    assert!((0.0..=1.0).contains(&fraction));
    let mut out = Vec::new();
    for part in &dataset.partitions {
        for r in part {
            if rng.f64() < fraction {
                out.push(*r);
            }
        }
    }
    Dataset::from_records(
        format!("{}_sampled", dataset.name),
        out,
        dataset.num_partitions(),
        dataset.record_bytes,
    )
}

/// Stratified reservoir over a streamed join output: consumes the *full*
/// cross product of one key group (honest post-join cost) while retaining
/// a uniform without-replacement reservoir of `ceil(fraction · B_i)`
/// combined values, returned as the stratum's sample aggregate.
pub fn post_join_reservoir(
    sides: &[Vec<f64>],
    fraction: f64,
    op: CombineOp,
    rng: &mut Rng,
) -> StratumAgg {
    let population: f64 = sides.iter().map(|s| s.len() as f64).product();
    let mut agg = StratumAgg {
        population,
        ..Default::default()
    };
    if population == 0.0 || fraction <= 0.0 {
        return agg;
    }
    let b = ((fraction * population).ceil() as usize).max(1);
    let mut reservoir: Vec<f64> = Vec::with_capacity(b);
    let n = sides.len();
    let mut idx = vec![0usize; n];
    let mut vals: Vec<f64> = idx.iter().zip(sides).map(|(&i, s)| s[i]).collect();
    let mut seen = 0u64;
    // full odometer enumeration — this is the point: post-join sampling
    // cannot skip the cross product.
    loop {
        let v = op.combine(&vals);
        seen += 1;
        if reservoir.len() < b {
            reservoir.push(v);
        } else {
            let j = rng.below(seen);
            if (j as usize) < b {
                reservoir[j as usize] = v;
            }
        }
        let mut d = n;
        loop {
            if d == 0 {
                for v in reservoir {
                    agg.push(v);
                }
                return agg;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < sides[d].len() {
                vals[d] = sides[d][idx[d]];
                break;
            }
            idx[d] = 0;
            vals[d] = sides[d][0];
        }
    }
}

/// The RNG for one stratum's reservoir: derived from (seed, key) alone, so
/// every stratum's sample is independent of which worker/thread runs it
/// and of the key visit order.
fn stratum_rng(seed: u64, key: u64) -> Rng {
    Rng::new(seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Stratified post-join reservoirs over a whole set of cogrouped strata,
/// data-parallel across strata. Each key group still pays its full
/// cross-product enumeration (the point of the post-join baseline), but
/// groups run concurrently through `exec`; the per-key RNG depends only on
/// `(seed, key)`, so the result is bit-identical for any thread count.
pub fn post_join_reservoir_strata(
    groups: &HashMap<u64, Vec<Vec<f64>>>,
    fraction: f64,
    op: CombineOp,
    seed: u64,
    exec: &ParallelExecutor,
) -> HashMap<u64, StratumAgg> {
    let mut keys: Vec<u64> = groups.keys().copied().collect();
    keys.sort_unstable();
    let aggs = exec.map(keys.len(), |i| {
        let key = keys[i];
        let mut r = stratum_rng(seed, key);
        post_join_reservoir(&groups[&key], fraction, op, &mut r)
    });
    keys.into_iter().zip(aggs).collect()
}

/// One stratum's retained window sample for the streaming path: the sample
/// aggregate, the raw draw count behind it (the Horvitz-Thompson inclusion
/// probability π_i needs it; equals `agg.count` on the with-replacement
/// path), and the window index at which the reservoir was last (re)filled.
#[derive(Clone, Debug, PartialEq)]
pub struct StratumReservoir {
    pub agg: StratumAgg,
    pub draws: f64,
    pub epoch: u64,
}

/// The RNG for one stratum's window draw: derived from (seed, key, epoch)
/// alone, so a refresh is independent of worker/thread placement and of the
/// key visit order — the streaming bit-identity guarantee.
fn window_stratum_rng(seed: u64, key: u64, epoch: u64) -> Rng {
    Rng::new(
        seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ epoch.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    )
}

/// One stratum's eviction-aware refresh decision: carry the previous
/// reservoir verbatim when the stratum's window contents are untouched
/// (no re-draw, no RNG consumption), otherwise redraw from the
/// (seed, key, epoch)-derived RNG. Returns `None` for an empty stratum,
/// else the reservoir plus whether it was carried. Generic over the side
/// container — hash-map `Vec<f64>` cogroups and columnar `&[f64]` runs
/// make identical decisions and identical draws.
#[allow(clippy::too_many_arguments)]
fn refresh_one<S: AsRef<[f64]>>(
    key: u64,
    sides: &[S],
    changed: &HashSet<u64>,
    previous: &HashMap<u64, StratumReservoir>,
    params: &SamplingParams,
    estimator: EstimatorKind,
    op: CombineOp,
    seed: u64,
    epoch: u64,
) -> Option<(StratumReservoir, bool)> {
    if !changed.contains(&key) {
        if let Some(prev) = previous.get(&key) {
            debug_assert_eq!(
                prev.agg.population,
                population(sides),
                "unchanged stratum {key} changed population — stale change tracking"
            );
            return Some((prev.clone(), true));
        }
    }
    let pop = population(sides);
    if pop == 0.0 {
        return None;
    }
    let b = params.sample_size(key, pop);
    let mut r = window_stratum_rng(seed, key, epoch);
    let (agg, draws) = match estimator {
        EstimatorKind::Clt => {
            let agg = sample_edges_with_replacement(&mut r, sides, b, op);
            let d = agg.count;
            (agg, d)
        }
        EstimatorKind::HorvitzThompson => sample_edges_dedup(&mut r, sides, b, op),
    };
    Some((StratumReservoir { agg, draws, epoch }, false))
}

/// Eviction-aware refresh of per-stratum reservoirs over one window's
/// cogrouped strata. A stratum whose contributing tuples did not change
/// since the previous window (not in `changed`) carries its reservoir over
/// verbatim — no re-draw, no RNG consumption; changed or new strata are
/// refilled from a fresh (seed, key, epoch)-derived RNG, with-replacement
/// for [`EstimatorKind::Clt`] and deduplicated for
/// [`EstimatorKind::HorvitzThompson`]. Keys absent from `groups` (fully
/// evicted) simply drop out. Keys are visited in sorted order and the
/// per-key RNG is placement-independent, so any parallel split of `groups`
/// (the streaming runtime shards by destination worker) produces
/// bit-identical reservoirs. Returns the new reservoir map plus the
/// (refreshed, carried) stratum counts.
#[allow(clippy::too_many_arguments)] // mirrors refresh_one; a config struct would only restate it
pub fn refresh_reservoir_strata(
    groups: &HashMap<u64, Vec<Vec<f64>>>,
    changed: &HashSet<u64>,
    previous: &HashMap<u64, StratumReservoir>,
    params: &SamplingParams,
    estimator: EstimatorKind,
    op: CombineOp,
    seed: u64,
    epoch: u64,
) -> (HashMap<u64, StratumReservoir>, u64, u64) {
    let mut keys: Vec<u64> = groups.keys().copied().collect();
    keys.sort_unstable();
    let mut out = HashMap::with_capacity(keys.len());
    let (mut refreshed, mut carried) = (0u64, 0u64);
    for key in keys {
        let sides = &groups[&key];
        match refresh_one(key, sides, changed, previous, params, estimator, op, seed, epoch) {
            Some((res, true)) => {
                out.insert(key, res);
                carried += 1;
            }
            Some((res, false)) => {
                out.insert(key, res);
                refreshed += 1;
            }
            None => {}
        }
    }
    (out, refreshed, carried)
}

/// [`refresh_reservoir_strata`] over a columnar cogroup: iterates the
/// directory's contiguous key runs (already ascending — no key sort, no
/// hash lookups) and reads value slices straight out of the columns.
/// Per-stratum decisions, RNG streams and draws are identical to the
/// hash-map version's, so window outputs stay bit-identical whichever
/// cogroup representation the runtime uses.
#[allow(clippy::too_many_arguments)]
pub fn refresh_reservoir_strata_columnar(
    cogroup: &crate::runtime::CogroupColumns,
    changed: &HashSet<u64>,
    previous: &HashMap<u64, StratumReservoir>,
    params: &SamplingParams,
    estimator: EstimatorKind,
    op: CombineOp,
    seed: u64,
    epoch: u64,
) -> (HashMap<u64, StratumReservoir>, u64, u64) {
    let mut out = HashMap::with_capacity(cogroup.num_keys());
    let (mut refreshed, mut carried) = (0u64, 0u64);
    let mut sides: Vec<&[f64]> = Vec::with_capacity(cogroup.n_inputs());
    for idx in 0..cogroup.num_keys() {
        let key = cogroup.key(idx);
        cogroup.sides_into(idx, &mut sides);
        match refresh_one(key, &sides, changed, previous, params, estimator, op, seed, epoch) {
            Some((res, true)) => {
                out.insert(key, res);
                carried += 1;
            }
            Some((res, false)) => {
                out.insert(key, res);
                refreshed += 1;
            }
            None => {}
        }
    }
    (out, refreshed, carried)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Record;
    use crate::join::cross_product_agg;

    #[test]
    fn sample_by_key_fraction() {
        let d = Dataset::from_records(
            "t",
            (0..20_000).map(|k| Record::new(k % 100, 1.0)).collect(),
            4,
            10,
        );
        let mut r = Rng::new(1);
        let s = sample_by_key(&d, 0.3, &mut r);
        let frac = s.len() as f64 / d.len() as f64;
        assert!((frac - 0.3).abs() < 0.02, "frac {frac}");
        assert_eq!(sample_by_key(&d, 0.0, &mut r).len(), 0);
        assert_eq!(sample_by_key(&d, 1.0, &mut r).len(), d.len());
    }

    #[test]
    fn reservoir_size_and_population() {
        let sides = vec![vec![1.0; 20], vec![2.0; 30]]; // pop 600
        let mut r = Rng::new(2);
        let agg = post_join_reservoir(&sides, 0.1, CombineOp::Sum, &mut r);
        assert_eq!(agg.population, 600.0);
        assert_eq!(agg.count, 60.0);
    }

    #[test]
    fn reservoir_mean_unbiased() {
        let sides = vec![
            (0..25).map(|i| i as f64).collect::<Vec<_>>(),
            (0..20).map(|i| i as f64 * 2.0).collect::<Vec<_>>(),
        ];
        let truth = cross_product_agg(&sides, CombineOp::Sum);
        let true_mean = truth.sum / truth.population;
        let mut r = Rng::new(3);
        let mut est = 0.0;
        let reps = 200;
        for _ in 0..reps {
            let agg = post_join_reservoir(&sides, 0.2, CombineOp::Sum, &mut r);
            est += agg.mean();
        }
        est /= reps as f64;
        assert!((est - true_mean).abs() < 0.5, "{est} vs {true_mean}");
    }

    #[test]
    fn full_fraction_reservoir_is_exact() {
        let sides = vec![vec![1.0, 2.0], vec![3.0, 5.0]];
        let mut r = Rng::new(4);
        let agg = post_join_reservoir(&sides, 1.0, CombineOp::Sum, &mut r);
        let truth = cross_product_agg(&sides, CombineOp::Sum);
        assert_eq!(agg.count, truth.population);
        assert!((agg.sum - truth.sum).abs() < 1e-12);
    }

    #[test]
    fn empty_group() {
        let mut r = Rng::new(5);
        let agg = post_join_reservoir(&[vec![], vec![1.0]], 0.5, CombineOp::Sum, &mut r);
        assert_eq!(agg.population, 0.0);
        assert_eq!(agg.count, 0.0);
    }

    #[test]
    fn strata_reservoirs_thread_count_invariant() {
        let mut groups: HashMap<u64, Vec<Vec<f64>>> = HashMap::new();
        for key in 0..40u64 {
            let a: Vec<f64> = (0..12).map(|i| (key * 31 + i) as f64).collect();
            let b: Vec<f64> = (0..9).map(|i| (key * 17 + i) as f64 * 0.5).collect();
            groups.insert(key, vec![a, b]);
        }
        let seq = post_join_reservoir_strata(
            &groups,
            0.2,
            CombineOp::Sum,
            7,
            &ParallelExecutor::sequential(),
        );
        for threads in [2, 8] {
            let par = post_join_reservoir_strata(
                &groups,
                0.2,
                CombineOp::Sum,
                7,
                &ParallelExecutor::new(threads),
            );
            assert_eq!(seq, par, "threads {threads}");
        }
        // populations and sample sizes follow the fraction
        for (key, agg) in &seq {
            assert_eq!(agg.population, 108.0, "key {key}");
            assert_eq!(agg.count, 22.0, "key {key}"); // ceil(0.2 * 108)
        }
    }

    fn window_groups(n_keys: u64, salt: u64) -> HashMap<u64, Vec<Vec<f64>>> {
        let mut groups = HashMap::new();
        for key in 0..n_keys {
            let a: Vec<f64> = (0..10).map(|i| (key * 13 + i + salt) as f64).collect();
            let b: Vec<f64> = (0..8).map(|i| (key * 7 + i) as f64 * 0.25).collect();
            groups.insert(key, vec![a, b]);
        }
        groups
    }

    #[test]
    fn reservoir_refresh_is_deterministic_in_seed_key_epoch() {
        let groups = window_groups(20, 0);
        let changed: HashSet<u64> = groups.keys().copied().collect();
        let params = SamplingParams::Fraction(0.25);
        let run = || {
            refresh_reservoir_strata(
                &groups,
                &changed,
                &HashMap::new(),
                &params,
                EstimatorKind::Clt,
                CombineOp::Sum,
                9,
                3,
            )
        };
        let (a, refreshed, carried) = run();
        let (b, _, _) = run();
        assert_eq!(a, b);
        assert_eq!(refreshed, 20);
        assert_eq!(carried, 0);
        for (key, r) in &a {
            assert_eq!(r.agg.population, 80.0, "key {key}");
            assert_eq!(r.agg.count, 20.0, "key {key}"); // ceil(0.25 * 80)
            assert_eq!(r.draws, r.agg.count, "CLT draws == sample size");
            assert_eq!(r.epoch, 3);
        }
        // a different epoch redraws a different sample
        let (c, _, _) = refresh_reservoir_strata(
            &groups,
            &changed,
            &HashMap::new(),
            &params,
            EstimatorKind::Clt,
            CombineOp::Sum,
            9,
            4,
        );
        assert_ne!(a, c);
    }

    #[test]
    fn unchanged_strata_carry_over_changed_strata_refresh() {
        let params = SamplingParams::Fraction(0.25);
        let groups0 = window_groups(20, 0);
        let all: HashSet<u64> = groups0.keys().copied().collect();
        let (w0, _, _) = refresh_reservoir_strata(
            &groups0,
            &all,
            &HashMap::new(),
            &params,
            EstimatorKind::Clt,
            CombineOp::Sum,
            9,
            0,
        );
        // next window: keys 0..5 changed content, the rest are untouched
        let mut groups1 = window_groups(20, 0);
        for key in 0..5u64 {
            groups1.insert(key, window_groups(20, 100)[&key].clone());
        }
        let changed: HashSet<u64> = (0..5).collect();
        let (w1, refreshed, carried) = refresh_reservoir_strata(
            &groups1,
            &changed,
            &w0,
            &params,
            EstimatorKind::Clt,
            CombineOp::Sum,
            9,
            1,
        );
        assert_eq!(refreshed, 5);
        assert_eq!(carried, 15);
        for key in 0..20u64 {
            if key < 5 {
                assert_eq!(w1[&key].epoch, 1, "changed stratum {key} must refresh");
                assert_ne!(w1[&key], w0[&key]);
            } else {
                assert_eq!(w1[&key], w0[&key], "unchanged stratum {key} must carry");
            }
        }
    }

    #[test]
    fn columnar_refresh_bit_identical_to_hashmap_refresh() {
        use crate::data::Record;
        use crate::runtime::CogroupColumns;
        let params = SamplingParams::Fraction(0.3);
        for estimator in [EstimatorKind::Clt, EstimatorKind::HorvitzThompson] {
            let groups = window_groups(25, 3);
            // columnar build from the equivalent record streams
            let mut per_input: Vec<Vec<Record>> = vec![Vec::new(), Vec::new()];
            let mut keys: Vec<u64> = groups.keys().copied().collect();
            keys.sort_unstable();
            for &key in &keys {
                for (i, side) in groups[&key].iter().enumerate() {
                    for &v in side {
                        per_input[i].push(Record::new(key, v));
                    }
                }
            }
            let cg = CogroupColumns::from_records(&per_input);
            let changed: HashSet<u64> = (0..10u64).collect();
            // seed the previous map so carried strata exercise both paths
            let all: HashSet<u64> = groups.keys().copied().collect();
            let (prev, _, _) = refresh_reservoir_strata(
                &groups,
                &all,
                &HashMap::new(),
                &params,
                estimator,
                CombineOp::Sum,
                11,
                0,
            );
            let (a, ra, ca) = refresh_reservoir_strata(
                &groups, &changed, &prev, &params, estimator, CombineOp::Sum, 11, 1,
            );
            let (b, rb, cb) = refresh_reservoir_strata_columnar(
                &cg, &changed, &prev, &params, estimator, CombineOp::Sum, 11, 1,
            );
            assert_eq!(a, b, "{estimator:?}");
            assert_eq!((ra, ca), (rb, cb));
            assert_eq!(ca, 15);
        }
    }

    #[test]
    fn evicted_strata_drop_and_ht_tracks_raw_draws() {
        let params = SamplingParams::Fraction(0.5);
        let groups0 = window_groups(10, 0);
        let all: HashSet<u64> = groups0.keys().copied().collect();
        let (w0, _, _) = refresh_reservoir_strata(
            &groups0,
            &all,
            &HashMap::new(),
            &params,
            EstimatorKind::HorvitzThompson,
            CombineOp::Sum,
            5,
            0,
        );
        for r in w0.values() {
            // dedup sampling: distinct edges <= raw draws
            assert!(r.agg.count <= r.draws, "{} > {}", r.agg.count, r.draws);
            assert!(r.agg.count > 0.0);
        }
        // the next window only contains keys 5.. — the rest evict
        let mut groups1 = window_groups(10, 0);
        groups1.retain(|k, _| *k >= 5);
        let changed = HashSet::new();
        let (w1, refreshed, carried) = refresh_reservoir_strata(
            &groups1,
            &changed,
            &w0,
            &params,
            EstimatorKind::HorvitzThompson,
            CombineOp::Sum,
            5,
            1,
        );
        assert_eq!(w1.len(), 5);
        assert_eq!((refreshed, carried), (0, 5));
        assert!((0..5u64).all(|k| !w1.contains_key(&k)));
    }
}
