//! Sampling substrate: stratified edge sampling *during* the join (the
//! paper's core §3.3 mechanism) plus the two baseline placements Figure 1
//! compares against — pre-join input sampling and post-join output
//! sampling.

pub mod edge_sampling;
pub mod stratified;

pub use edge_sampling::{sample_edges_dedup, sample_edges_with_replacement, SampledPairs};
pub use stratified::{
    post_join_reservoir, refresh_reservoir_strata, sample_by_key, StratumReservoir,
};
