//! Flat columnar cogroup buffers — the cache-friendly replacement for the
//! per-worker `HashMap<u64, Vec<Vec<f64>>>` cogroups on the join hot path.
//!
//! Instead of one hash entry + n inner `Vec<f64>` allocations per key, each
//! input's shuffled records land in two flat columns (`key64`, `f64`) that
//! are stably sorted by key; equal keys become **contiguous runs**, and an
//! n-way merge of the per-input run lists yields the *joinable directory*:
//! every key present in all n inputs, ascending, with one `(start, end)`
//! span per input into the value columns. Consumers (cross products,
//! stratified samplers) iterate contiguous key runs and read value slices
//! straight out of the columns — no per-key allocation, no hash probes,
//! sequential memory.
//!
//! Determinism contract: the stable sort preserves each input's record
//! arrival order within a key, and the directory is ascending by key — so
//! per-key value sequences and key visit order are **identical** to the
//! old sorted-HashMap walk, down to the f64 accumulation order. The
//! buffers are reusable ([`CogroupColumns::rebuild`]): the streaming join
//! keeps one per worker across windows, so the columns, run lists and
//! directory reuse their capacity (the stable sort's internal merge
//! scratch is the one per-rebuild temporary that remains).

use crate::data::Record;

/// One worker's cogrouped survivors in flat columnar form.
#[derive(Clone, Debug, Default)]
pub struct CogroupColumns {
    n_inputs: usize,
    /// Per input: keys sorted ascending (stable), aligned with `vals`.
    keys: Vec<Vec<u64>>,
    /// Per input: values in key-sorted order (arrival order within a key).
    vals: Vec<Vec<f64>>,
    /// Keys present in *every* input, ascending.
    dir_keys: Vec<u64>,
    /// `spans[key_idx * n_inputs + input]` = (start, end) into
    /// `vals[input]` for that key's run.
    spans: Vec<(u32, u32)>,
    /// Per input: (key, start, end) run boundaries — rebuild scratch kept
    /// around so re-cogrouping reuses the allocation.
    runs: Vec<Vec<(u64, u32, u32)>>,
    /// Sort scratch: (key, value) pairs of the input being ingested.
    pair_scratch: Vec<(u64, f64)>,
    /// Delta-apply merge scratch: the spliced key/value columns are built
    /// here and swapped in, so steady-state splices reuse capacity.
    key_scratch: Vec<u64>,
    val_scratch: Vec<f64>,
}

impl CogroupColumns {
    /// An empty buffer for `n_inputs`-way cogroups.
    pub fn new(n_inputs: usize) -> Self {
        Self {
            n_inputs,
            keys: (0..n_inputs).map(|_| Vec::new()).collect(),
            vals: (0..n_inputs).map(|_| Vec::new()).collect(),
            runs: (0..n_inputs).map(|_| Vec::new()).collect(),
            ..Default::default()
        }
    }

    /// Build fresh from per-input record slices.
    pub fn from_slices(per_input: &[&[Record]]) -> Self {
        let mut cg = Self::new(per_input.len());
        cg.rebuild(per_input);
        cg
    }

    /// Convenience over owned per-input vectors.
    pub fn from_records(per_input: &[Vec<Record>]) -> Self {
        let slices: Vec<&[Record]> = per_input.iter().map(|v| v.as_slice()).collect();
        Self::from_slices(&slices)
    }

    /// Re-cogroup new record sets into the existing buffers. The columns,
    /// run lists, directory and pair scratch all reuse their capacity;
    /// the only remaining per-call temporary is the stable sort's
    /// internal merge buffer.
    pub fn rebuild(&mut self, per_input: &[&[Record]]) {
        let n = per_input.len();
        assert!(n >= 1, "cogroup needs at least one input");
        if n != self.n_inputs {
            self.n_inputs = n;
            self.keys.resize_with(n, Vec::new);
            self.vals.resize_with(n, Vec::new);
            self.runs.resize_with(n, Vec::new);
        }
        for (i, recs) in per_input.iter().enumerate() {
            debug_assert!(recs.len() < u32::MAX as usize, "u32 span offsets");
            // ingest into the sort scratch, stable-sort by key (arrival
            // order within a key is preserved), split into flat columns
            self.pair_scratch.clear();
            self.pair_scratch.extend(recs.iter().map(|r| (r.key, r.value)));
            self.pair_scratch.sort_by_key(|p| p.0);
            let keys = &mut self.keys[i];
            let vals = &mut self.vals[i];
            keys.clear();
            vals.clear();
            keys.reserve(recs.len());
            vals.reserve(recs.len());
            for &(k, v) in &self.pair_scratch {
                keys.push(k);
                vals.push(v);
            }
        }
        self.reindex();
    }

    /// Splice one micro-batch's deltas into the persistent columns in one
    /// merge pass — the incremental alternative to [`CogroupColumns::rebuild`].
    ///
    /// `arrivals[i]` are input `i`'s newly arrived records (any order;
    /// they are stably sorted by key here, appending to each key's run in
    /// arrival order). `retractions[i]` is sorted ascending by key and
    /// retracts `count` records from the *front* of that key's run — the
    /// oldest records, which is exactly what sliding-window eviction
    /// removes when arrivals only ever append. The splice is O(rows + Δ)
    /// memcpy-bound and never re-sorts the surviving window; the runs and
    /// joinable directory are then re-derived by the same indexing pass a
    /// fresh rebuild uses, so the spliced state is **bit-identical** to
    /// `rebuild` over the equivalent window contents (the invariant the
    /// continuous engine's from-scratch twin asserts).
    ///
    /// Panics if a retraction names a key the columns do not hold, or
    /// retracts more records than the key's run contains.
    pub fn apply_delta(&mut self, arrivals: &[&[Record]], retractions: &[Vec<(u64, u32)>]) {
        assert_eq!(arrivals.len(), self.n_inputs, "arrival arity");
        assert_eq!(retractions.len(), self.n_inputs, "retraction arity");
        for i in 0..self.n_inputs {
            self.pair_scratch.clear();
            self.pair_scratch
                .extend(arrivals[i].iter().map(|r| (r.key, r.value)));
            self.pair_scratch.sort_by_key(|p| p.0);
            let retr = &retractions[i];
            debug_assert!(
                retr.windows(2).all(|w| w[0].0 < w[1].0),
                "retractions must be sorted by key, one entry per key"
            );
            let old_keys = &self.keys[i];
            let old_vals = &self.vals[i];
            let arr = &self.pair_scratch;
            let merged_cap = old_keys.len() + arr.len();
            self.key_scratch.clear();
            self.val_scratch.clear();
            self.key_scratch.reserve(merged_cap);
            self.val_scratch.reserve(merged_cap);
            let (mut p, mut a, mut r) = (0usize, 0usize, 0usize);
            while p < old_keys.len() || a < arr.len() {
                // the next key in ascending order, from either side
                let k = match (old_keys.get(p), arr.get(a)) {
                    (Some(&ko), Some(&(ka, _))) => ko.min(ka),
                    (Some(&ko), None) => ko,
                    (None, Some(&(ka, _))) => ka,
                    (None, None) => unreachable!(),
                };
                // surviving old records first (they are older) ...
                if p < old_keys.len() && old_keys[p] == k {
                    let mut end = p + 1;
                    while end < old_keys.len() && old_keys[end] == k {
                        end += 1;
                    }
                    let mut drop = 0usize;
                    if r < retr.len() && retr[r].0 == k {
                        drop = retr[r].1 as usize;
                        assert!(
                            drop <= end - p,
                            "retracting {} records from key {k} input {i}, run holds {}",
                            drop,
                            end - p
                        );
                        r += 1;
                    }
                    for j in p + drop..end {
                        self.key_scratch.push(k);
                        self.val_scratch.push(old_vals[j]);
                    }
                    p = end;
                }
                // ... then this batch's arrivals, in arrival order
                while a < arr.len() && arr[a].0 == k {
                    self.key_scratch.push(k);
                    self.val_scratch.push(arr[a].1);
                    a += 1;
                }
            }
            assert!(
                r == retr.len(),
                "retraction key {} absent from input {i}'s columns",
                retr.get(r).map(|e| e.0).unwrap_or(0)
            );
            std::mem::swap(&mut self.keys[i], &mut self.key_scratch);
            std::mem::swap(&mut self.vals[i], &mut self.val_scratch);
        }
        self.reindex();
    }

    /// Derive the per-input run lists and the joinable directory from the
    /// key columns — shared by [`CogroupColumns::rebuild`] and
    /// [`CogroupColumns::apply_delta`], so both paths index identically.
    fn reindex(&mut self) {
        let n = self.n_inputs;
        for i in 0..n {
            let keys = &self.keys[i];
            // contiguous key runs
            let runs = &mut self.runs[i];
            runs.clear();
            let mut start = 0usize;
            while start < keys.len() {
                let key = keys[start];
                let mut end = start + 1;
                while end < keys.len() && keys[end] == key {
                    end += 1;
                }
                runs.push((key, start as u32, end as u32));
                start = end;
            }
        }
        // joinable directory: n-way sorted-merge intersection of run lists
        self.dir_keys.clear();
        self.spans.clear();
        let mut ptrs = vec![0usize; n];
        'outer: for r0 in 0..self.runs[0].len() {
            let (key, s0, e0) = self.runs[0][r0];
            // advance every other input's cursor to `key`
            for i in 1..n {
                let runs_i = &self.runs[i];
                while ptrs[i] < runs_i.len() && runs_i[ptrs[i]].0 < key {
                    ptrs[i] += 1;
                }
                if ptrs[i] >= runs_i.len() {
                    break 'outer; // input i exhausted: no further joins
                }
                if runs_i[ptrs[i]].0 != key {
                    continue 'outer; // key missing from input i
                }
            }
            self.dir_keys.push(key);
            self.spans.push((s0, e0));
            for (i, &p) in ptrs.iter().enumerate().skip(1) {
                let (_, s, e) = self.runs[i][p];
                self.spans.push((s, e));
            }
        }
        debug_assert_eq!(self.spans.len(), self.dir_keys.len() * n);
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Number of joinable keys (present in every input), the directory
    /// length.
    pub fn num_keys(&self) -> usize {
        self.dir_keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dir_keys.is_empty()
    }

    /// The idx-th joinable key; ascending in idx.
    #[inline]
    pub fn key(&self, idx: usize) -> u64 {
        self.dir_keys[idx]
    }

    /// The joinable keys, ascending.
    pub fn keys(&self) -> &[u64] {
        &self.dir_keys
    }

    /// Value slice of `input` for the idx-th joinable key.
    #[inline]
    pub fn side(&self, idx: usize, input: usize) -> &[f64] {
        let (s, e) = self.spans[idx * self.n_inputs + input];
        &self.vals[input][s as usize..e as usize]
    }

    /// Fill `out` with all n value slices of the idx-th joinable key, in
    /// input order — the borrow lives as long as `self`, so one scratch
    /// `Vec` serves a whole drain loop.
    #[inline]
    pub fn sides_into<'a>(&'a self, idx: usize, out: &mut Vec<&'a [f64]>) {
        out.clear();
        for i in 0..self.n_inputs {
            out.push(self.side(idx, i));
        }
    }

    /// Σ over joinable keys of Π side lengths — the exact join-output
    /// cardinality of this worker's shard, accumulated in ascending key
    /// order (deterministic f64 sum).
    pub fn total_pairs(&self) -> f64 {
        let mut total = 0.0;
        for idx in 0..self.num_keys() {
            let mut p = 1.0;
            for i in 0..self.n_inputs {
                p *= self.side(idx, i).len() as f64;
            }
            total += p;
        }
        total
    }

    /// Rows ingested across all inputs (pre-intersection) — throughput
    /// denominators for the benches.
    pub fn total_rows(&self) -> u64 {
        self.vals.iter().map(|v| v.len() as u64).sum()
    }

    /// Number of key runs of one input — ALL of that input's distinct
    /// keys, not just the joinable directory. The outer/semi/anti
    /// resolution walks these to find single-side keys.
    pub fn num_runs(&self, input: usize) -> usize {
        self.runs[input].len()
    }

    /// The idx-th key run of `input`: (key, value slice), ascending in
    /// idx, values in arrival order.
    #[inline]
    pub fn run(&self, input: usize, idx: usize) -> (u64, &[f64]) {
        let (k, s, e) = self.runs[input][idx];
        (k, &self.vals[input][s as usize..e as usize])
    }

    /// Is `key` present in every input (i.e. in the joinable directory)?
    pub fn contains_key(&self, key: u64) -> bool {
        self.dir_keys.binary_search(&key).is_ok()
    }

    /// Directory position of `key`, if it is joinable.
    pub fn index_of(&self, key: u64) -> Option<usize> {
        self.dir_keys.binary_search(&key).ok()
    }

    /// Value slice of `input` for `key`, whether or not the key is
    /// joinable — `None` only when the input holds no records for it.
    /// Runs are ascending by key, so this is a binary search.
    pub fn run_of_key(&self, input: usize, key: u64) -> Option<&[f64]> {
        let runs = &self.runs[input];
        let idx = runs.binary_search_by_key(&key, |&(k, _, _)| k).ok()?;
        let (_, s, e) = runs[idx];
        Some(&self.vals[input][s as usize..e as usize])
    }

    /// Estimated heap footprint in bytes (columns, run lists, directory,
    /// scratch) — the unit the serve-layer sketch-cache LRU budgets.
    pub fn heap_bytes(&self) -> u64 {
        let mut b = 0u64;
        for i in 0..self.n_inputs {
            b += (self.keys[i].capacity() * 8) as u64;
            b += (self.vals[i].capacity() * 8) as u64;
            b += (self.runs[i].capacity() * std::mem::size_of::<(u64, u32, u32)>()) as u64;
        }
        b += (self.dir_keys.capacity() * 8) as u64;
        b += (self.spans.capacity() * std::mem::size_of::<(u32, u32)>()) as u64;
        b += (self.pair_scratch.capacity() * 16) as u64;
        b += (self.key_scratch.capacity() * 8) as u64;
        b += (self.val_scratch.capacity() * 8) as u64;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::join::group_by_key;
    use crate::util::Rng;

    fn random_inputs(seed: u64, n_inputs: usize, rows: usize, keyspace: u64) -> Vec<Vec<Record>> {
        let mut r = Rng::new(seed);
        (0..n_inputs)
            .map(|_| {
                (0..rows)
                    .map(|_| Record::new(r.below(keyspace), r.f64()))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn matches_hashmap_cogroup_exactly() {
        for n in [2usize, 3] {
            let inputs = random_inputs(7 + n as u64, n, 400, 60);
            let cg = CogroupColumns::from_records(&inputs);
            let mut groups = group_by_key(&inputs);
            groups.retain(|_, sides| sides.iter().all(|s| !s.is_empty()));
            assert_eq!(cg.num_keys(), groups.len(), "{n}-way key count");
            let mut expect: Vec<u64> = groups.keys().copied().collect();
            expect.sort_unstable();
            assert_eq!(cg.keys(), &expect[..], "ascending joinable keys");
            for idx in 0..cg.num_keys() {
                let key = cg.key(idx);
                let sides = &groups[&key];
                for i in 0..n {
                    // same values in the same (arrival) order
                    assert_eq!(cg.side(idx, i), &sides[i][..], "key {key} input {i}");
                }
            }
        }
    }

    #[test]
    fn total_pairs_matches_product_sum() {
        let inputs = random_inputs(3, 2, 500, 40);
        let cg = CogroupColumns::from_records(&inputs);
        let mut groups = group_by_key(&inputs);
        groups.retain(|_, sides| sides.iter().all(|s| !s.is_empty()));
        let mut keys: Vec<u64> = groups.keys().copied().collect();
        keys.sort_unstable();
        let expect: f64 = keys
            .iter()
            .map(|k| groups[k].iter().map(|s| s.len() as f64).product::<f64>())
            .sum();
        assert_eq!(cg.total_pairs(), expect);
        assert_eq!(cg.total_rows(), 1000);
    }

    #[test]
    fn rebuild_reuses_buffers_and_agrees_with_fresh() {
        let a = random_inputs(11, 2, 300, 30);
        let b = random_inputs(12, 2, 350, 25);
        let mut cg = CogroupColumns::from_records(&a);
        let first_keys: Vec<u64> = cg.keys().to_vec();
        let slices_b: Vec<&[Record]> = b.iter().map(|v| v.as_slice()).collect();
        cg.rebuild(&slices_b);
        let fresh = CogroupColumns::from_records(&b);
        assert_eq!(cg.keys(), fresh.keys());
        for idx in 0..cg.num_keys() {
            for i in 0..2 {
                assert_eq!(cg.side(idx, i), fresh.side(idx, i));
            }
        }
        // and rebuilding the first inputs again restores the first state
        let slices_a: Vec<&[Record]> = a.iter().map(|v| v.as_slice()).collect();
        cg.rebuild(&slices_a);
        assert_eq!(cg.keys(), &first_keys[..]);
    }

    #[test]
    fn disjoint_and_empty_inputs() {
        let a = vec![Record::new(1, 1.0), Record::new(2, 2.0)];
        let b = vec![Record::new(3, 3.0)];
        let cg = CogroupColumns::from_records(&[a.clone(), b]);
        assert_eq!(cg.num_keys(), 0);
        assert_eq!(cg.total_pairs(), 0.0);
        let cg = CogroupColumns::from_records(&[a, vec![]]);
        assert!(cg.is_empty());
    }

    #[test]
    fn sides_into_fills_input_order() {
        let a = vec![Record::new(5, 1.0), Record::new(5, 2.0)];
        let b = vec![Record::new(5, 10.0)];
        let cg = CogroupColumns::from_records(&[a, b]);
        let mut sides: Vec<&[f64]> = Vec::new();
        cg.sides_into(0, &mut sides);
        assert_eq!(sides, vec![&[1.0, 2.0][..], &[10.0][..]]);
    }

    /// Simulate sliding-window churn: per batch, evict the oldest batch's
    /// per-key counts and append new arrivals, via `apply_delta` on one
    /// buffer and `rebuild` over the surviving window on another. The two
    /// must agree bit-for-bit every batch.
    #[test]
    fn apply_delta_matches_rebuild_under_churn() {
        let n_inputs = 2usize;
        let window = 3usize;
        let mut r = Rng::new(42);
        let mut incr = CogroupColumns::new(n_inputs);
        // window contents per input, as batches (front = oldest)
        let mut held: Vec<Vec<Vec<Record>>> = vec![Vec::new(); n_inputs];
        for batch in 0..20usize {
            let arrivals: Vec<Vec<Record>> = (0..n_inputs)
                .map(|_| {
                    (0..40 + batch)
                        .map(|_| Record::new(r.below(25), r.f64()))
                        .collect()
                })
                .collect();
            let mut retractions: Vec<Vec<(u64, u32)>> = vec![Vec::new(); n_inputs];
            if held[0].len() == window {
                for (i, held_i) in held.iter_mut().enumerate() {
                    let evicted = held_i.remove(0);
                    let mut counts: std::collections::BTreeMap<u64, u32> =
                        std::collections::BTreeMap::new();
                    for rec in &evicted {
                        *counts.entry(rec.key).or_insert(0) += 1;
                    }
                    retractions[i] = counts.into_iter().collect();
                }
            }
            let arr_slices: Vec<&[Record]> = arrivals.iter().map(|v| v.as_slice()).collect();
            incr.apply_delta(&arr_slices, &retractions);
            for (i, a) in arrivals.into_iter().enumerate() {
                held[i].push(a);
            }
            // from-scratch twin over the surviving window contents
            let flat: Vec<Vec<Record>> = held
                .iter()
                .map(|batches| batches.iter().flatten().copied().collect())
                .collect();
            let fresh = CogroupColumns::from_records(&flat);
            assert_eq!(incr.keys(), fresh.keys(), "batch {batch} directory");
            assert_eq!(incr.total_rows(), fresh.total_rows(), "batch {batch} rows");
            for idx in 0..incr.num_keys() {
                for i in 0..n_inputs {
                    assert_eq!(
                        incr.side(idx, i),
                        fresh.side(idx, i),
                        "batch {batch} key {} input {i}",
                        incr.key(idx)
                    );
                }
            }
            for i in 0..n_inputs {
                assert_eq!(incr.num_runs(i), fresh.num_runs(i), "batch {batch} runs");
                for ridx in 0..incr.num_runs(i) {
                    assert_eq!(incr.run(i, ridx), fresh.run(i, ridx));
                }
            }
        }
    }

    /// A key fully retracted then re-inserted must come back with only the
    /// new values — no stale residue from before the eviction.
    #[test]
    fn full_retraction_then_reinsert_is_clean() {
        let a = vec![Record::new(5, 1.0), Record::new(5, 2.0), Record::new(7, 3.0)];
        let b = vec![Record::new(5, 10.0), Record::new(7, 20.0)];
        let mut cg = CogroupColumns::from_records(&[a, b]);
        assert_eq!(cg.keys(), &[5, 7]);
        // evict all of key 5 from both inputs
        cg.apply_delta(&[&[], &[]], &[vec![(5, 2)], vec![(5, 1)]]);
        assert_eq!(cg.keys(), &[7]);
        assert_eq!(cg.run_of_key(0, 5), None);
        // re-insert key 5 with new values: only the new values appear
        let a2 = [Record::new(5, 99.0)];
        let b2 = [Record::new(5, 88.0)];
        cg.apply_delta(&[&a2, &b2], &[vec![], vec![]]);
        assert_eq!(cg.keys(), &[5, 7]);
        assert_eq!(cg.side(0, 0), &[99.0]);
        assert_eq!(cg.side(0, 1), &[88.0]);
        // partial retraction removes the oldest entries of the run
        let a3 = [Record::new(7, 4.0)];
        cg.apply_delta(&[&a3, &[]], &[vec![(7, 1)], vec![]]);
        assert_eq!(cg.run_of_key(0, 7), Some(&[4.0][..]));
        assert_eq!(cg.index_of(7), Some(1));
    }

    #[test]
    #[should_panic(expected = "absent")]
    fn retracting_unknown_key_panics() {
        let a = vec![Record::new(1, 1.0)];
        let b = vec![Record::new(1, 2.0)];
        let mut cg = CogroupColumns::from_records(&[a, b]);
        cg.apply_delta(&[&[], &[]], &[vec![(9, 1)], vec![]]);
    }

    #[test]
    fn stable_sort_preserves_arrival_order_within_key() {
        // duplicate keys with distinguishable values, deliberately
        // interleaved: the column must keep arrival order per key
        let a = vec![
            Record::new(9, 1.0),
            Record::new(4, 100.0),
            Record::new(9, 2.0),
            Record::new(4, 200.0),
            Record::new(9, 3.0),
        ];
        let b = vec![Record::new(9, 7.0), Record::new(4, 8.0)];
        let cg = CogroupColumns::from_records(&[a, b]);
        assert_eq!(cg.keys(), &[4, 9]);
        assert_eq!(cg.side(0, 0), &[100.0, 200.0]);
        assert_eq!(cg.side(1, 0), &[1.0, 2.0, 3.0]);
    }
}
