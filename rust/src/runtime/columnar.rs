//! Flat columnar cogroup buffers — the cache-friendly replacement for the
//! per-worker `HashMap<u64, Vec<Vec<f64>>>` cogroups on the join hot path.
//!
//! Instead of one hash entry + n inner `Vec<f64>` allocations per key, each
//! input's shuffled records land in two flat columns (`key64`, `f64`) that
//! are stably sorted by key; equal keys become **contiguous runs**, and an
//! n-way merge of the per-input run lists yields the *joinable directory*:
//! every key present in all n inputs, ascending, with one `(start, end)`
//! span per input into the value columns. Consumers (cross products,
//! stratified samplers) iterate contiguous key runs and read value slices
//! straight out of the columns — no per-key allocation, no hash probes,
//! sequential memory.
//!
//! Determinism contract: the stable sort preserves each input's record
//! arrival order within a key, and the directory is ascending by key — so
//! per-key value sequences and key visit order are **identical** to the
//! old sorted-HashMap walk, down to the f64 accumulation order. The
//! buffers are reusable ([`CogroupColumns::rebuild`]): the streaming join
//! keeps one per worker across windows, so the columns, run lists and
//! directory reuse their capacity (the stable sort's internal merge
//! scratch is the one per-rebuild temporary that remains).

use crate::data::Record;

/// One worker's cogrouped survivors in flat columnar form.
#[derive(Clone, Debug, Default)]
pub struct CogroupColumns {
    n_inputs: usize,
    /// Per input: keys sorted ascending (stable), aligned with `vals`.
    keys: Vec<Vec<u64>>,
    /// Per input: values in key-sorted order (arrival order within a key).
    vals: Vec<Vec<f64>>,
    /// Keys present in *every* input, ascending.
    dir_keys: Vec<u64>,
    /// `spans[key_idx * n_inputs + input]` = (start, end) into
    /// `vals[input]` for that key's run.
    spans: Vec<(u32, u32)>,
    /// Per input: (key, start, end) run boundaries — rebuild scratch kept
    /// around so re-cogrouping reuses the allocation.
    runs: Vec<Vec<(u64, u32, u32)>>,
    /// Sort scratch: (key, value) pairs of the input being ingested.
    pair_scratch: Vec<(u64, f64)>,
}

impl CogroupColumns {
    /// An empty buffer for `n_inputs`-way cogroups.
    pub fn new(n_inputs: usize) -> Self {
        Self {
            n_inputs,
            keys: (0..n_inputs).map(|_| Vec::new()).collect(),
            vals: (0..n_inputs).map(|_| Vec::new()).collect(),
            runs: (0..n_inputs).map(|_| Vec::new()).collect(),
            ..Default::default()
        }
    }

    /// Build fresh from per-input record slices.
    pub fn from_slices(per_input: &[&[Record]]) -> Self {
        let mut cg = Self::new(per_input.len());
        cg.rebuild(per_input);
        cg
    }

    /// Convenience over owned per-input vectors.
    pub fn from_records(per_input: &[Vec<Record>]) -> Self {
        let slices: Vec<&[Record]> = per_input.iter().map(|v| v.as_slice()).collect();
        Self::from_slices(&slices)
    }

    /// Re-cogroup new record sets into the existing buffers. The columns,
    /// run lists, directory and pair scratch all reuse their capacity;
    /// the only remaining per-call temporary is the stable sort's
    /// internal merge buffer.
    pub fn rebuild(&mut self, per_input: &[&[Record]]) {
        let n = per_input.len();
        assert!(n >= 1, "cogroup needs at least one input");
        if n != self.n_inputs {
            self.n_inputs = n;
            self.keys.resize_with(n, Vec::new);
            self.vals.resize_with(n, Vec::new);
            self.runs.resize_with(n, Vec::new);
        }
        for (i, recs) in per_input.iter().enumerate() {
            debug_assert!(recs.len() < u32::MAX as usize, "u32 span offsets");
            // ingest into the sort scratch, stable-sort by key (arrival
            // order within a key is preserved), split into flat columns
            self.pair_scratch.clear();
            self.pair_scratch.extend(recs.iter().map(|r| (r.key, r.value)));
            self.pair_scratch.sort_by_key(|p| p.0);
            let keys = &mut self.keys[i];
            let vals = &mut self.vals[i];
            keys.clear();
            vals.clear();
            keys.reserve(recs.len());
            vals.reserve(recs.len());
            for &(k, v) in &self.pair_scratch {
                keys.push(k);
                vals.push(v);
            }
            // contiguous key runs
            let runs = &mut self.runs[i];
            runs.clear();
            let mut start = 0usize;
            while start < keys.len() {
                let key = keys[start];
                let mut end = start + 1;
                while end < keys.len() && keys[end] == key {
                    end += 1;
                }
                runs.push((key, start as u32, end as u32));
                start = end;
            }
        }
        // joinable directory: n-way sorted-merge intersection of run lists
        self.dir_keys.clear();
        self.spans.clear();
        let mut ptrs = vec![0usize; n];
        'outer: for r0 in 0..self.runs[0].len() {
            let (key, s0, e0) = self.runs[0][r0];
            // advance every other input's cursor to `key`
            for i in 1..n {
                let runs_i = &self.runs[i];
                while ptrs[i] < runs_i.len() && runs_i[ptrs[i]].0 < key {
                    ptrs[i] += 1;
                }
                if ptrs[i] >= runs_i.len() {
                    break 'outer; // input i exhausted: no further joins
                }
                if runs_i[ptrs[i]].0 != key {
                    continue 'outer; // key missing from input i
                }
            }
            self.dir_keys.push(key);
            self.spans.push((s0, e0));
            for (i, &p) in ptrs.iter().enumerate().skip(1) {
                let (_, s, e) = self.runs[i][p];
                self.spans.push((s, e));
            }
        }
        debug_assert_eq!(self.spans.len(), self.dir_keys.len() * n);
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Number of joinable keys (present in every input), the directory
    /// length.
    pub fn num_keys(&self) -> usize {
        self.dir_keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dir_keys.is_empty()
    }

    /// The idx-th joinable key; ascending in idx.
    #[inline]
    pub fn key(&self, idx: usize) -> u64 {
        self.dir_keys[idx]
    }

    /// The joinable keys, ascending.
    pub fn keys(&self) -> &[u64] {
        &self.dir_keys
    }

    /// Value slice of `input` for the idx-th joinable key.
    #[inline]
    pub fn side(&self, idx: usize, input: usize) -> &[f64] {
        let (s, e) = self.spans[idx * self.n_inputs + input];
        &self.vals[input][s as usize..e as usize]
    }

    /// Fill `out` with all n value slices of the idx-th joinable key, in
    /// input order — the borrow lives as long as `self`, so one scratch
    /// `Vec` serves a whole drain loop.
    #[inline]
    pub fn sides_into<'a>(&'a self, idx: usize, out: &mut Vec<&'a [f64]>) {
        out.clear();
        for i in 0..self.n_inputs {
            out.push(self.side(idx, i));
        }
    }

    /// Σ over joinable keys of Π side lengths — the exact join-output
    /// cardinality of this worker's shard, accumulated in ascending key
    /// order (deterministic f64 sum).
    pub fn total_pairs(&self) -> f64 {
        let mut total = 0.0;
        for idx in 0..self.num_keys() {
            let mut p = 1.0;
            for i in 0..self.n_inputs {
                p *= self.side(idx, i).len() as f64;
            }
            total += p;
        }
        total
    }

    /// Rows ingested across all inputs (pre-intersection) — throughput
    /// denominators for the benches.
    pub fn total_rows(&self) -> u64 {
        self.vals.iter().map(|v| v.len() as u64).sum()
    }

    /// Number of key runs of one input — ALL of that input's distinct
    /// keys, not just the joinable directory. The outer/semi/anti
    /// resolution walks these to find single-side keys.
    pub fn num_runs(&self, input: usize) -> usize {
        self.runs[input].len()
    }

    /// The idx-th key run of `input`: (key, value slice), ascending in
    /// idx, values in arrival order.
    #[inline]
    pub fn run(&self, input: usize, idx: usize) -> (u64, &[f64]) {
        let (k, s, e) = self.runs[input][idx];
        (k, &self.vals[input][s as usize..e as usize])
    }

    /// Is `key` present in every input (i.e. in the joinable directory)?
    pub fn contains_key(&self, key: u64) -> bool {
        self.dir_keys.binary_search(&key).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::join::group_by_key;
    use crate::util::Rng;

    fn random_inputs(seed: u64, n_inputs: usize, rows: usize, keyspace: u64) -> Vec<Vec<Record>> {
        let mut r = Rng::new(seed);
        (0..n_inputs)
            .map(|_| {
                (0..rows)
                    .map(|_| Record::new(r.below(keyspace), r.f64()))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn matches_hashmap_cogroup_exactly() {
        for n in [2usize, 3] {
            let inputs = random_inputs(7 + n as u64, n, 400, 60);
            let cg = CogroupColumns::from_records(&inputs);
            let mut groups = group_by_key(&inputs);
            groups.retain(|_, sides| sides.iter().all(|s| !s.is_empty()));
            assert_eq!(cg.num_keys(), groups.len(), "{n}-way key count");
            let mut expect: Vec<u64> = groups.keys().copied().collect();
            expect.sort_unstable();
            assert_eq!(cg.keys(), &expect[..], "ascending joinable keys");
            for idx in 0..cg.num_keys() {
                let key = cg.key(idx);
                let sides = &groups[&key];
                for i in 0..n {
                    // same values in the same (arrival) order
                    assert_eq!(cg.side(idx, i), &sides[i][..], "key {key} input {i}");
                }
            }
        }
    }

    #[test]
    fn total_pairs_matches_product_sum() {
        let inputs = random_inputs(3, 2, 500, 40);
        let cg = CogroupColumns::from_records(&inputs);
        let mut groups = group_by_key(&inputs);
        groups.retain(|_, sides| sides.iter().all(|s| !s.is_empty()));
        let mut keys: Vec<u64> = groups.keys().copied().collect();
        keys.sort_unstable();
        let expect: f64 = keys
            .iter()
            .map(|k| groups[k].iter().map(|s| s.len() as f64).product::<f64>())
            .sum();
        assert_eq!(cg.total_pairs(), expect);
        assert_eq!(cg.total_rows(), 1000);
    }

    #[test]
    fn rebuild_reuses_buffers_and_agrees_with_fresh() {
        let a = random_inputs(11, 2, 300, 30);
        let b = random_inputs(12, 2, 350, 25);
        let mut cg = CogroupColumns::from_records(&a);
        let first_keys: Vec<u64> = cg.keys().to_vec();
        let slices_b: Vec<&[Record]> = b.iter().map(|v| v.as_slice()).collect();
        cg.rebuild(&slices_b);
        let fresh = CogroupColumns::from_records(&b);
        assert_eq!(cg.keys(), fresh.keys());
        for idx in 0..cg.num_keys() {
            for i in 0..2 {
                assert_eq!(cg.side(idx, i), fresh.side(idx, i));
            }
        }
        // and rebuilding the first inputs again restores the first state
        let slices_a: Vec<&[Record]> = a.iter().map(|v| v.as_slice()).collect();
        cg.rebuild(&slices_a);
        assert_eq!(cg.keys(), &first_keys[..]);
    }

    #[test]
    fn disjoint_and_empty_inputs() {
        let a = vec![Record::new(1, 1.0), Record::new(2, 2.0)];
        let b = vec![Record::new(3, 3.0)];
        let cg = CogroupColumns::from_records(&[a.clone(), b]);
        assert_eq!(cg.num_keys(), 0);
        assert_eq!(cg.total_pairs(), 0.0);
        let cg = CogroupColumns::from_records(&[a, vec![]]);
        assert!(cg.is_empty());
    }

    #[test]
    fn sides_into_fills_input_order() {
        let a = vec![Record::new(5, 1.0), Record::new(5, 2.0)];
        let b = vec![Record::new(5, 10.0)];
        let cg = CogroupColumns::from_records(&[a, b]);
        let mut sides: Vec<&[f64]> = Vec::new();
        cg.sides_into(0, &mut sides);
        assert_eq!(sides, vec![&[1.0, 2.0][..], &[10.0][..]]);
    }

    #[test]
    fn stable_sort_preserves_arrival_order_within_key() {
        // duplicate keys with distinguishable values, deliberately
        // interleaved: the column must keep arrival order per key
        let a = vec![
            Record::new(9, 1.0),
            Record::new(4, 100.0),
            Record::new(9, 2.0),
            Record::new(4, 200.0),
            Record::new(9, 3.0),
        ];
        let b = vec![Record::new(9, 7.0), Record::new(4, 8.0)];
        let cg = CogroupColumns::from_records(&[a, b]);
        assert_eq!(cg.keys(), &[4, 9]);
        assert_eq!(cg.side(0, 0), &[100.0, 200.0]);
        assert_eq!(cg.side(1, 0), &[1.0, 2.0, 3.0]);
    }
}
