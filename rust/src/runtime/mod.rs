//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the Rust hot path.
//!
//! The interchange format is HLO **text** (not serialized HloModuleProto):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 — what the published `xla` 0.1.6 crate links — rejects
//! (`proto.id() <= INT_MAX`); `HloModuleProto::from_text_file` reassigns
//! ids and round-trips cleanly. Artifacts are lowered with
//! return_tuple=True, so outputs unwrap with `to_tuple*`.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only thing touching the artifacts afterwards.
//!
//! [`parallel`] is the other half of the runtime: the partition-parallel
//! [`ParallelExecutor`] every join strategy routes its per-worker loops
//! through (deterministic, bit-identical to sequential execution).

pub mod batch;
pub mod columnar;
pub mod parallel;

pub use batch::{BloomProbeExecutor, CltExecutor, JoinAggExecutor};
pub use columnar::CogroupColumns;
pub use parallel::{default_parallelism, ParallelExecutor, NUM_PARTITIONS};

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Artifact geometry — must match python/compile/model.py (the manifest
/// carries the authored values; `Geometry::default()` mirrors them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    pub batch: usize,
    pub strata: usize,
    pub num_hashes: u32,
    pub log2_bits: u32,
    pub nwords: usize,
}

impl Default for Geometry {
    fn default() -> Self {
        Self {
            batch: 4096,
            strata: 256,
            num_hashes: 5,
            log2_bits: 20,
            nwords: 32768,
        }
    }
}

impl Geometry {
    pub fn from_manifest(j: &Json) -> Result<Self> {
        let g = j.get("geometry").ok_or_else(|| anyhow!("no geometry"))?;
        let f = |k: &str| -> Result<f64> {
            g.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("manifest missing geometry.{k}"))
        };
        Ok(Self {
            batch: f("batch")? as usize,
            strata: f("strata")? as usize,
            num_hashes: f("num_hashes")? as u32,
            log2_bits: f("log2_bits")? as u32,
            nwords: f("nwords")? as usize,
        })
    }
}

/// The PJRT CPU client plus the compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub geometry: Geometry,
}

impl PjrtRuntime {
    /// Open the artifacts directory, read the manifest, create the client.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = Json::parse(
            &std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?,
        )?;
        let geometry = Geometry::from_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Self {
            client,
            dir,
            geometry,
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name (`join_agg`, `bloom_probe`,
    /// `clt_estimate`).
    pub fn compile(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(to_anyhow)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(to_anyhow)
    }

    /// Compile the sampling-stage aggregator.
    pub fn join_agg(&self) -> Result<JoinAggExecutor> {
        Ok(JoinAggExecutor::new(self.compile("join_agg")?, self.geometry))
    }

    /// Compile the filtering-stage prober.
    pub fn bloom_probe(&self) -> Result<BloomProbeExecutor> {
        Ok(BloomProbeExecutor::new(
            self.compile("bloom_probe")?,
            self.geometry,
        ))
    }

    /// Compile the CLT moment estimator.
    pub fn clt_estimate(&self) -> Result<CltExecutor> {
        Ok(CltExecutor::new(
            self.compile("clt_estimate")?,
            self.geometry,
        ))
    }
}

/// The xla crate has its own error type; fold it into anyhow.
pub(crate) fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<PjrtRuntime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        match PjrtRuntime::open(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                // artifacts on disk but no PJRT backend (vendored XLA stub)
                eprintln!("skipping: XLA runtime unavailable ({e:#})");
                None
            }
        }
    }

    #[test]
    fn geometry_defaults_match_manifest() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.geometry, Geometry::default());
    }

    #[test]
    fn opens_cpu_platform() {
        let Some(rt) = runtime() else { return };
        assert!(rt.platform_name().to_lowercase().contains("cpu"));
    }

    #[test]
    fn compiles_all_artifacts() {
        let Some(rt) = runtime() else { return };
        rt.compile("join_agg").expect("join_agg");
        rt.compile("bloom_probe").expect("bloom_probe");
        rt.compile("clt_estimate").expect("clt_estimate");
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let Some(rt) = runtime() else { return };
        assert!(rt.compile("nonexistent").is_err());
    }
}
