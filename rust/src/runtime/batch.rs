//! Fixed-shape batch executors over the compiled artifacts. Each wraps one
//! `PjRtLoadedExecutable` and adapts the coordinator's trait contracts
//! ([`BatchAggregator`], [`KeyProber`]) to the artifact's static tensor
//! shapes, padding + masking the last partial batch.

use super::{to_anyhow, Geometry};
use crate::bloom::BloomFilter;
use crate::join::approx::BatchAggregator;
use crate::join::bloom_join::KeyProber;
use crate::join::CombineOp;
use anyhow::{ensure, Result};

/// The combine-op one-hot ordering pinned in python/compile/model.py.
fn op_onehot(op: CombineOp) -> [f32; 4] {
    match op {
        CombineOp::Sum => [1.0, 0.0, 0.0, 0.0],
        CombineOp::Product => [0.0, 1.0, 0.0, 0.0],
        CombineOp::Left => [0.0, 0.0, 1.0, 0.0],
    }
}

/// Executes the `join_agg` artifact: (v1, v2, seg, mask, op) →
/// per-stratum (counts, sums, sumsqs).
pub struct JoinAggExecutor {
    exe: xla::PjRtLoadedExecutable,
    geometry: Geometry,
    /// Scratch buffers reused across calls (hot-path allocation matters;
    /// see EXPERIMENTS.md §Perf).
    f1: Vec<f32>,
    f2: Vec<f32>,
    fm: Vec<f32>,
    /// Executions so far (diagnostics).
    pub calls: u64,
}

impl JoinAggExecutor {
    pub fn new(exe: xla::PjRtLoadedExecutable, geometry: Geometry) -> Self {
        let b = geometry.batch;
        Self {
            exe,
            geometry,
            f1: vec![0.0; b],
            f2: vec![0.0; b],
            fm: vec![0.0; b],
            calls: 0,
        }
    }

    pub fn geometry(&self) -> Geometry {
        self.geometry
    }
}

impl BatchAggregator for JoinAggExecutor {
    fn run(
        &mut self,
        left: &[f64],
        right: &[f64],
        seg: &[i32],
        mask: &[f64],
        op: CombineOp,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let b = self.geometry.batch;
        ensure!(left.len() == b, "batch must be padded to {b}");
        ensure!(right.len() == b && seg.len() == b && mask.len() == b);
        for i in 0..b {
            self.f1[i] = left[i] as f32;
            self.f2[i] = right[i] as f32;
            self.fm[i] = mask[i] as f32;
        }
        let l1 = xla::Literal::vec1(&self.f1);
        let l2 = xla::Literal::vec1(&self.f2);
        let ls = xla::Literal::vec1(seg);
        let lm = xla::Literal::vec1(&self.fm);
        let lop = xla::Literal::vec1(&op_onehot(op));
        let result = self
            .exe
            .execute::<xla::Literal>(&[l1, l2, ls, lm, lop])
            .map_err(to_anyhow)?[0][0]
            .to_literal_sync()
            .map_err(to_anyhow)?;
        let (counts, sums, sumsqs) = result.to_tuple3().map_err(to_anyhow)?;
        self.calls += 1;
        let cast = |l: xla::Literal| -> Result<Vec<f64>> {
            Ok(l.to_vec::<f32>()
                .map_err(to_anyhow)?
                .into_iter()
                .map(|v| v as f64)
                .collect())
        };
        Ok((cast(counts)?, cast(sums)?, cast(sumsqs)?))
    }

    fn batch_rows(&self) -> usize {
        self.geometry.batch
    }

    fn strata_slots(&self) -> usize {
        self.geometry.strata
    }
}

/// Executes the `bloom_probe` artifact: (words, keys) → membership mask.
/// Implements [`KeyProber`] for filters whose geometry matches the
/// artifact; other geometries fall back to native probing.
pub struct BloomProbeExecutor {
    exe: xla::PjRtLoadedExecutable,
    geometry: Geometry,
    keybuf: Vec<u32>,
    pub calls: u64,
    pub native_fallbacks: u64,
}

impl BloomProbeExecutor {
    pub fn new(exe: xla::PjRtLoadedExecutable, geometry: Geometry) -> Self {
        Self {
            exe,
            geometry,
            keybuf: vec![0; geometry.batch],
            calls: 0,
            native_fallbacks: 0,
        }
    }

    /// Whether the artifact can probe this filter.
    pub fn matches(&self, filter: &BloomFilter) -> bool {
        filter.log2_bits() == self.geometry.log2_bits
            && filter.num_hashes() == self.geometry.num_hashes
    }
}

impl KeyProber for BloomProbeExecutor {
    fn probe(&mut self, filter: &BloomFilter, keys: &[u32]) -> Result<Vec<bool>> {
        if !self.matches(filter) {
            // geometry mismatch: stay correct via the native path
            self.native_fallbacks += 1;
            return Ok(keys.iter().map(|&k| filter.contains(k)).collect());
        }
        let b = self.geometry.batch;
        let words = xla::Literal::vec1(filter.words());
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            self.keybuf[..chunk.len()].copy_from_slice(chunk);
            // pad with a repeat of the first key (never read past len)
            for slot in &mut self.keybuf[chunk.len()..] {
                *slot = chunk.first().copied().unwrap_or(0);
            }
            let lk = xla::Literal::vec1(&self.keybuf[..]);
            let result = self
                .exe
                .execute::<&xla::Literal>(&[&words, &lk])
                .map_err(to_anyhow)?[0][0]
                .to_literal_sync()
                .map_err(to_anyhow)?;
            let mask = result.to_tuple1().map_err(to_anyhow)?;
            let mask = mask.to_vec::<i32>().map_err(to_anyhow)?;
            out.extend(mask[..chunk.len()].iter().map(|&m| m != 0));
            self.calls += 1;
        }
        Ok(out)
    }
}

/// Executes the `clt_estimate` artifact: per-stratum (B, b, sums, sumsqs)
/// → (τ̂, V̂ar). Strata are fed in slot-sized chunks and the two moments
/// accumulate (both are sums over strata).
pub struct CltExecutor {
    exe: xla::PjRtLoadedExecutable,
    geometry: Geometry,
    pub calls: u64,
}

impl CltExecutor {
    pub fn new(exe: xla::PjRtLoadedExecutable, geometry: Geometry) -> Self {
        Self {
            exe,
            geometry,
            calls: 0,
        }
    }

    /// Estimate (total, variance) from parallel per-stratum arrays.
    pub fn estimate(
        &mut self,
        big_b: &[f64],
        small_b: &[f64],
        sums: &[f64],
        sumsqs: &[f64],
    ) -> Result<(f64, f64)> {
        ensure!(
            big_b.len() == small_b.len() && sums.len() == sumsqs.len() && big_b.len() == sums.len()
        );
        let s = self.geometry.strata;
        let mut tau = 0.0f64;
        let mut var = 0.0f64;
        let mut buf = vec![0.0f32; s * 4];
        for start in (0..big_b.len()).step_by(s) {
            let end = (start + s).min(big_b.len());
            let n = end - start;
            buf.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..n {
                buf[i] = big_b[start + i] as f32;
                buf[s + i] = small_b[start + i] as f32;
                buf[2 * s + i] = sums[start + i] as f32;
                buf[3 * s + i] = sumsqs[start + i] as f32;
            }
            let lb = xla::Literal::vec1(&buf[..s]);
            let ls = xla::Literal::vec1(&buf[s..2 * s]);
            let lsum = xla::Literal::vec1(&buf[2 * s..3 * s]);
            let lsq = xla::Literal::vec1(&buf[3 * s..4 * s]);
            let result = self
                .exe
                .execute::<xla::Literal>(&[lb, ls, lsum, lsq])
                .map_err(to_anyhow)?[0][0]
                .to_literal_sync()
                .map_err(to_anyhow)?;
            let (t, v) = result.to_tuple2().map_err(to_anyhow)?;
            tau += t.to_vec::<f32>().map_err(to_anyhow)?[0] as f64;
            var += v.to_vec::<f32>().map_err(to_anyhow)?[0] as f64;
            self.calls += 1;
        }
        Ok((tau, var))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::join::approx::NativeAggregator;
    use crate::runtime::PjrtRuntime;
    use crate::util::Rng;
    use std::path::PathBuf;

    fn runtime() -> Option<PjrtRuntime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        match PjrtRuntime::open(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                // artifacts on disk but no PJRT backend (vendored XLA stub)
                eprintln!("skipping: XLA runtime unavailable ({e:#})");
                None
            }
        }
    }

    #[test]
    fn join_agg_matches_native_aggregator() {
        let Some(rt) = runtime() else { return };
        let mut xla_agg = rt.join_agg().unwrap();
        let g = xla_agg.geometry();
        let mut native = NativeAggregator {
            rows: g.batch,
            slots: g.strata,
        };
        let mut r = Rng::new(3);
        let b = g.batch;
        let left: Vec<f64> = (0..b).map(|_| r.range_f64(-5.0, 5.0)).collect();
        let right: Vec<f64> = (0..b).map(|_| r.range_f64(-5.0, 5.0)).collect();
        let seg: Vec<i32> = (0..b).map(|_| r.index(g.strata) as i32).collect();
        let mask: Vec<f64> = (0..b).map(|_| if r.f64() < 0.9 { 1.0 } else { 0.0 }).collect();
        for op in [CombineOp::Sum, CombineOp::Product, CombineOp::Left] {
            let (xc, xs, xq) = xla_agg.run(&left, &right, &seg, &mask, op).unwrap();
            let (nc, ns, nq) = native.run(&left, &right, &seg, &mask, op).unwrap();
            for i in 0..g.strata {
                assert!((xc[i] - nc[i]).abs() < 1e-3, "count[{i}] {op:?}");
                assert!(
                    (xs[i] - ns[i]).abs() < 1e-2 * (1.0 + ns[i].abs()),
                    "sum[{i}] {op:?}: {} vs {}",
                    xs[i],
                    ns[i]
                );
                assert!(
                    (xq[i] - nq[i]).abs() < 1e-2 * (1.0 + nq[i].abs()),
                    "sumsq[{i}] {op:?}"
                );
            }
        }
        assert_eq!(xla_agg.calls, 3);
    }

    #[test]
    fn bloom_probe_matches_native_filter() {
        let Some(rt) = runtime() else { return };
        let mut prober = rt.bloom_probe().unwrap();
        let g = rt.geometry;
        let mut filter = BloomFilter::new(g.log2_bits, g.num_hashes);
        let mut r = Rng::new(4);
        let members: Vec<u32> = (0..5000).map(|_| r.next_u32()).collect();
        for &k in &members {
            filter.insert(k);
        }
        // probe a mix of members and non-members, non-multiple of batch
        let mut keys = members[..3000].to_vec();
        keys.extend((0..2500).map(|_| r.next_u32()));
        let got = prober.probe(&filter, &keys).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(got[i], filter.contains(k), "key {k} at {i}");
        }
        assert!(prober.calls >= 2); // 5500 keys / 4096 batch
        assert_eq!(prober.native_fallbacks, 0);
    }

    #[test]
    fn bloom_probe_falls_back_on_geometry_mismatch() {
        let Some(rt) = runtime() else { return };
        let mut prober = rt.bloom_probe().unwrap();
        let mut filter = BloomFilter::new(14, 4); // not the artifact geometry
        filter.insert(7);
        let got = prober.probe(&filter, &[7, 8]).unwrap();
        assert!(got[0]);
        assert_eq!(prober.native_fallbacks, 1);
    }

    #[test]
    fn clt_estimate_matches_rust_estimator() {
        let Some(rt) = runtime() else { return };
        let mut clt = rt.clt_estimate().unwrap();
        let mut r = Rng::new(5);
        // 300 strata -> exercises the chunking (2 calls at 256 slots)
        let m = 300;
        let mut strata = Vec::with_capacity(m);
        let (mut bb, mut sb, mut su, mut sq) = (vec![], vec![], vec![], vec![]);
        for _ in 0..m {
            let pop = 50.0 + r.f64() * 1000.0;
            let b = 2.0 + (r.f64() * 20.0).floor();
            let mut agg = crate::stats::StratumAgg {
                population: pop,
                ..Default::default()
            };
            for _ in 0..b as usize {
                agg.push(r.range_f64(0.0, 10.0));
            }
            bb.push(agg.population);
            sb.push(agg.count);
            su.push(agg.sum);
            sq.push(agg.sumsq);
            strata.push(agg);
        }
        let (tau, var) = clt.estimate(&bb, &sb, &su, &sq).unwrap();
        // rust-side reference (f64): the f32 artifact should agree to ~1e-3
        let res = crate::stats::clt_sum(&strata, 0.95);
        assert!(
            (tau - res.estimate).abs() / res.estimate.abs() < 1e-3,
            "tau {tau} vs {}",
            res.estimate
        );
        let var_rust = strata
            .iter()
            .filter(|s| s.count > 1.0)
            .map(|s| s.population * (s.population - s.count).max(0.0) * s.variance() / s.count)
            .sum::<f64>();
        assert!(
            (var - var_rust).abs() / var_rust.max(1.0) < 5e-3,
            "var {var} vs {var_rust}"
        );
        assert_eq!(clt.calls, 2);
    }
}
