//! Partition-parallel execution: scoped worker threads over deterministic
//! hash-partitions.
//!
//! Every strategy's heavy loops (Bloom-shard build, filter probing, cross
//! products, per-stratum sampling) are expressed as an order-preserving
//! `map` over partition/worker indices. [`ParallelExecutor::map`] runs that
//! map either sequentially (`threads == 1`, the reference path) or on
//! `threads` scoped OS threads with striped index ownership. Results are
//! merged back **in index order**, and every per-index computation owns its
//! inputs (a pre-forked RNG, a partition slice), so the parallel output is
//! bit-identical to the sequential output for fixed seeds — the invariant
//! `tests/parallel_equivalence.rs` asserts across all five strategies.
//!
//! This is the execution half of the paper's cluster model: the
//! [`crate::cluster::SimCluster`] still *accounts* k logical workers and
//! their shuffle traffic, while the executor decides how many OS threads
//! actually chew through the per-worker tasks on this host.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Default number of execution partitions (worker threads) a parallel
/// cluster uses — the paper's experiments shard work 8 ways per node.
pub const NUM_PARTITIONS: usize = 8;

/// Host parallelism for new engines/sessions: `APPROXJOIN_THREADS` when
/// set, else `min(available cores, NUM_PARTITIONS)`, floor 1.
pub fn default_parallelism() -> usize {
    if let Ok(v) = std::env::var("APPROXJOIN_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(NUM_PARTITIONS)
        .max(1)
}

/// An order-preserving data-parallel mapper over index ranges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelExecutor {
    threads: usize,
}

impl ParallelExecutor {
    /// An executor running map bodies on up to `threads` OS threads.
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
        }
    }

    /// The strict sequential reference executor.
    pub fn sequential() -> Self {
        Self::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn is_sequential(&self) -> bool {
        self.threads == 1
    }

    /// Apply `f` to every index in `0..n` and return the results in index
    /// order. With one thread this is a plain sequential map; with more,
    /// indices are striped across scoped threads (thread t owns indices
    /// `t, t + T, t + 2T, ...`) and the per-index results are written back
    /// into their slots, so scheduling cannot reorder anything. A panic in
    /// any body propagates to the caller after the scope joins.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = self.threads.min(n.max(1));
        if threads <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let poisoned = AtomicBool::new(false);
        {
            // hand each thread a disjoint striped view of the slot vector
            let mut views: Vec<Vec<(usize, &mut Option<T>)>> =
                (0..threads).map(|_| Vec::new()).collect();
            for (i, slot) in slots.iter_mut().enumerate() {
                views[i % threads].push((i, slot));
            }
            std::thread::scope(|scope| {
                let f = &f;
                let poisoned = &poisoned;
                let handles: Vec<_> = views
                    .into_iter()
                    .map(|view| {
                        scope.spawn(move || {
                            for (i, slot) in view {
                                if poisoned.load(Ordering::Relaxed) {
                                    return;
                                }
                                let out = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| f(i)),
                                );
                                match out {
                                    Ok(v) => *slot = Some(v),
                                    Err(payload) => {
                                        poisoned.store(true, Ordering::Relaxed);
                                        std::panic::resume_unwind(payload);
                                    }
                                }
                            }
                        })
                    })
                    .collect();
                let mut panic_payload = None;
                for h in handles {
                    if let Err(payload) = h.join() {
                        panic_payload.get_or_insert(payload);
                    }
                }
                if let Some(payload) = panic_payload {
                    std::panic::resume_unwind(payload);
                }
            });
        }
        slots
            .into_iter()
            .map(|s| s.expect("every index mapped"))
            .collect()
    }
}

impl ParallelExecutor {
    /// Like [`ParallelExecutor::map`], but each index additionally gets
    /// exclusive mutable access to its own pre-built state (one entry of
    /// `states`; `n` is `states.len()`). This is how per-worker trait
    /// objects (forked probers, forked aggregators) reach parallel bodies
    /// without locks: states are *moved* into the thread stripes alongside
    /// their result slots, so no sharing ever occurs.
    pub fn map_with<S, T, F>(&self, states: Vec<S>, f: F) -> Vec<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        let n = states.len();
        let threads = self.threads.min(n.max(1));
        if threads <= 1 || n <= 1 {
            let mut states = states;
            return states
                .iter_mut()
                .enumerate()
                .map(|(i, s)| f(i, s))
                .collect();
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        {
            let mut views: Vec<Vec<(usize, &mut Option<T>, S)>> =
                (0..threads).map(|_| Vec::new()).collect();
            for ((i, slot), state) in slots.iter_mut().enumerate().zip(states) {
                views[i % threads].push((i, slot, state));
            }
            std::thread::scope(|scope| {
                let f = &f;
                let handles: Vec<_> = views
                    .into_iter()
                    .map(|view| {
                        scope.spawn(move || {
                            for (i, slot, mut state) in view {
                                *slot = Some(f(i, &mut state));
                            }
                        })
                    })
                    .collect();
                let mut panic_payload = None;
                for h in handles {
                    if let Err(payload) = h.join() {
                        panic_payload.get_or_insert(payload);
                    }
                }
                if let Some(payload) = panic_payload {
                    std::panic::resume_unwind(payload);
                }
            });
        }
        slots
            .into_iter()
            .map(|s| s.expect("every index mapped"))
            .collect()
    }
}

impl ParallelExecutor {
    /// Like [`ParallelExecutor::map`], but indices are claimed dynamically
    /// from a shared atomic counter instead of being striped up front —
    /// work stealing in its simplest form. Threads that finish a cheap
    /// index immediately claim the next unclaimed one, so wildly uneven
    /// per-index costs (a serving workload's client scripts, not the
    /// kernel's balanced partitions) keep every thread busy. Results are
    /// still returned **in index order**: each thread collects `(i, f(i))`
    /// pairs and the pairs are merged back into their slots after the
    /// scope joins, so scheduling cannot reorder anything. A panic in any
    /// body propagates to the caller after the scope joins.
    pub fn map_dynamic<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = self.threads.min(n.max(1));
        if threads <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let poisoned = AtomicBool::new(false);
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|scope| {
            let f = &f;
            let next = &next;
            let poisoned = &poisoned;
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(move || {
                        let mut local: Vec<(usize, T)> = Vec::new();
                        loop {
                            if poisoned.load(Ordering::Relaxed) {
                                return local;
                            }
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                return local;
                            }
                            let out = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| f(i)),
                            );
                            match out {
                                Ok(v) => local.push((i, v)),
                                Err(payload) => {
                                    poisoned.store(true, Ordering::Relaxed);
                                    std::panic::resume_unwind(payload);
                                }
                            }
                        }
                    })
                })
                .collect();
            let mut panic_payload = None;
            for h in handles {
                match h.join() {
                    Ok(local) => {
                        for (i, v) in local {
                            slots[i] = Some(v);
                        }
                    }
                    Err(payload) => {
                        panic_payload.get_or_insert(payload);
                    }
                }
            }
            if let Some(payload) = panic_payload {
                std::panic::resume_unwind(payload);
            }
        });
        slots
            .into_iter()
            .map(|s| s.expect("every index mapped"))
            .collect()
    }
}

impl Default for ParallelExecutor {
    fn default() -> Self {
        Self::sequential()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_index_order() {
        for threads in [1, 2, 3, 8] {
            let exec = ParallelExecutor::new(threads);
            let out = exec.map(37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "{threads}");
        }
    }

    #[test]
    fn map_handles_empty_and_singleton() {
        let exec = ParallelExecutor::new(4);
        assert_eq!(exec.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(exec.map(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn every_index_runs_exactly_once() {
        let exec = ParallelExecutor::new(4);
        let calls = AtomicUsize::new(0);
        let out = exec.map(100, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn parallel_matches_sequential_with_owned_rngs() {
        // the pattern the strategies use: fork per-index RNGs up front,
        // then map with each index cloning its own stream
        let fork_streams = |threads: usize| -> Vec<u64> {
            let mut root = crate::util::Rng::new(42);
            let rngs: Vec<crate::util::Rng> = (0..16).map(|w| root.fork(w as u64 + 1)).collect();
            ParallelExecutor::new(threads).map(16, |w| {
                let mut r = rngs[w].clone();
                (0..100).map(|_| r.next_u64()).fold(0u64, u64::wrapping_add)
            })
        };
        assert_eq!(fork_streams(1), fork_streams(8));
    }

    #[test]
    fn map_with_gives_each_index_its_own_state() {
        for threads in [1, 4] {
            let exec = ParallelExecutor::new(threads);
            let states: Vec<Vec<usize>> = (0..20).map(|_| Vec::new()).collect();
            let out = exec.map_with(states, |i, s: &mut Vec<usize>| {
                s.push(i);
                s.len() * 100 + i
            });
            assert_eq!(
                out,
                (0..20).map(|i| 100 + i).collect::<Vec<_>>(),
                "{threads}"
            );
        }
    }

    #[test]
    fn map_panics_propagate() {
        let exec = ParallelExecutor::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.map(8, |i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
                i
            })
        }));
        assert!(r.is_err());
    }

    #[test]
    fn map_dynamic_preserves_index_order() {
        for threads in [1, 2, 3, 8] {
            let exec = ParallelExecutor::new(threads);
            let out = exec.map_dynamic(37, |i| i * 3);
            assert_eq!(out, (0..37).map(|i| i * 3).collect::<Vec<_>>(), "{threads}");
        }
    }

    #[test]
    fn map_dynamic_runs_every_index_once() {
        let exec = ParallelExecutor::new(4);
        let calls = AtomicUsize::new(0);
        let out = exec.map_dynamic(100, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(out, (0..100).collect::<Vec<_>>());
        assert_eq!(exec.map_dynamic(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn map_dynamic_panics_propagate() {
        let exec = ParallelExecutor::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.map_dynamic(16, |i| {
                if i == 7 {
                    panic!("boom at {i}");
                }
                i
            })
        }));
        assert!(r.is_err());
    }

    #[test]
    fn default_parallelism_floor_one() {
        assert!(default_parallelism() >= 1);
        assert!(ParallelExecutor::default().is_sequential());
    }
}
