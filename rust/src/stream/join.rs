//! The streaming windowed ApproxJoin: incremental Bloom sketching over a
//! sliding/tumbling micro-batch window, per-window filtered shuffle,
//! per-stratum eviction-aware reservoirs, per-window CLT /
//! Horvitz-Thompson estimates — all through the same [`SimCluster`] /
//! [`crate::runtime::ParallelExecutor`] substrate as the batch strategies.
//!
//! Per emitted window the pipeline runs three stages, each with measured
//! compute and counted network traffic in the window's [`ShuffleLedger`]:
//!
//! 1. **`sketch_update`** — the master holds one persistent counting-Bloom
//!    sketch per input. Workers ship the cell deltas of their
//!    locally-arrived records (5 bytes per touched cell, capped at the
//!    sketch size); the master *inserts* arriving batches and *deletes*
//!    expired ones ([`CountingBloomFilter::remove_key64`]) — the sketch is
//!    maintained incrementally, O(touched cells) per window, never rebuilt
//!    from the window contents — then ANDs (cell-wise min) the inputs into
//!    the window join sketch and broadcasts its *bit view*
//!    ([`CountingBloomFilter::to_join_filter`], 1/8 the bytes; a standard
//!    or cache-line-blocked layout per [`StreamConfig::filter_kind`]).
//! 2. **`filter_shuffle`** — each worker probes its locally-arrived window
//!    records against the broadcast filter and shuffles only the survivors
//!    to their key-hashed destination. With filtering disabled the stage is
//!    named `shuffle` and moves every window record — the unfiltered
//!    baseline the per-window shuffle-reduction claim is measured against.
//! 3. **`sample`** (or **`crossproduct`** in exact mode) — per-stratum
//!    reservoirs refresh via
//!    [`crate::sampling::stratified::refresh_reservoir_strata`]: only
//!    strata touched by arriving/expiring batches re-draw; untouched strata
//!    carry their sample over verbatim. Estimates + confidence intervals
//!    come from the same CLT / Horvitz-Thompson estimators as the batch
//!    path.
//!
//! Determinism: per-stratum RNGs depend only on (seed, key, refresh epoch),
//! the master's sketch updates run in one fixed order, workers own disjoint
//! key sets, and partial results merge in worker order — window outputs
//! (strata, draws, ledger) are bit-identical for any thread count, the
//! invariant `tests/stream_windows.rs` asserts.

use super::source::StreamSource;
use super::window::{WindowBounds, WindowSpec};
use crate::bloom::hashing::fold_key;
use crate::bloom::{CountingBloomFilter, FilterKind, JoinFilter};
use crate::cluster::{JoinMetrics, ShuffleLedger, SimCluster, TimeModel};
use crate::data::{partition_of, Record};
use crate::join::approx::ApproxConfig;
use crate::join::{CombineOp, JoinVariant};
use crate::query::AggFunc;
use crate::runtime::CogroupColumns;
use crate::sampling::stratified::{refresh_reservoir_strata_columnar, StratumReservoir};
use crate::stats::{ApproxResult, EstimatorKind, StratumAgg};
use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

/// Geometry of the window sketch (counting cells; the broadcast join
/// filter is the bit view of the same geometry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SketchConfig {
    pub log2_cells: u32,
    pub num_hashes: u32,
}

impl SketchConfig {
    /// Geometry for an expected per-input window volume at a target
    /// false-positive rate (eq 27 applied to the cell count). The hash
    /// count is capped at 6: per-window delta traffic scales with h (one
    /// touched cell per hash per arriving/expiring record) while the
    /// power-of-two cell rounding already holds the fp rate at target —
    /// at h = 6 and the eq-27 minimal cell count, fp ≈ 0.0101 for a 1%
    /// target, and any rounding slack only improves it.
    pub fn for_capacity(items: u64, fp_rate: f64) -> Self {
        Self::for_capacity_kind(items, fp_rate, FilterKind::Standard)
    }

    /// [`SketchConfig::for_capacity`] for an explicit cell-addressing
    /// kind (blocked sketches floor at one 512-cell block, matching
    /// [`CountingBloomFilter::with_capacity_kind`]).
    pub fn for_capacity_kind(items: u64, fp_rate: f64, kind: FilterKind) -> Self {
        // same sizing as CountingBloomFilter::with_capacity_kind (shared
        // pow2_geometry helper), computed without allocating a cell array
        let (log2_cells, h) =
            crate::bloom::hashing::pow2_geometry(items, fp_rate, kind.min_log2().max(6), 26);
        Self {
            log2_cells,
            num_hashes: h.min(6),
        }
    }
}

/// Configuration of a streaming windowed join.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub window: WindowSpec,
    /// Logical workers of the simulated cluster (the accounting k).
    pub workers: usize,
    pub time_model: TimeModel,
    /// OS threads the per-worker loops run on (pure throughput knob —
    /// window outputs are bit-identical for any value).
    pub parallelism: usize,
    /// Sketch sizing target when `sketch` is None.
    pub fp_rate: f64,
    /// Explicit sketch geometry; None sizes from the observed per-batch
    /// volume × window size at the first emission.
    pub sketch: Option<SketchConfig>,
    /// Per-window sampling (params + estimator + seed); None enumerates the
    /// exact per-window cross products (the truth twin tests compare to).
    pub sampling: Option<ApproxConfig>,
    /// false shuffles every window record — the unfiltered baseline.
    pub bloom_filtering: bool,
    /// Cell/bit addressing of the window sketch and its broadcast filter:
    /// standard (default) or the cache-line-blocked hot path. The sketch
    /// stays incrementally maintained either way; only the position
    /// family (and so probe cost + fp rate) changes.
    pub filter_kind: FilterKind,
    pub agg: AggFunc,
    pub combine: CombineOp,
    /// Join variant of every emitted window. Non-inner variants run only
    /// on the exact unfiltered path (`sampling: None`,
    /// `bloom_filtering: false`): padding an unmatched key requires every
    /// window record at the cogroup, and the Bloom stage exists precisely
    /// to drop non-joinable records before the shuffle.
    pub variant: JoinVariant,
    pub confidence: f64,
    /// Deterministic fault injection: every emitted window runs under
    /// `plan.salted(window_index)`, so each window draws its own faults
    /// while the whole stream stays a pure function of the plan. `None`
    /// runs fault-free.
    pub faults: Option<crate::faults::FaultPlan>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        Self {
            window: WindowSpec::default(),
            workers: 4,
            time_model: TimeModel::default(),
            parallelism: crate::runtime::default_parallelism(),
            fp_rate: 0.01,
            sketch: None,
            sampling: Some(ApproxConfig::default()),
            bloom_filtering: true,
            filter_kind: FilterKind::Standard,
            agg: AggFunc::Sum,
            combine: CombineOp::Sum,
            variant: JoinVariant::Inner,
            confidence: 0.95,
            faults: None,
        }
    }
}

/// One emitted window's outcome: the estimate with its confidence interval,
/// the per-stratum aggregates behind it, and the window's own measured
/// metrics + shuffle ledger.
#[derive(Clone, Debug)]
pub struct WindowResult {
    pub bounds: WindowBounds,
    pub result: ApproxResult,
    /// Per-join-key aggregates of this window (population = exact per-key
    /// output cardinality; count = sample size, or population in exact
    /// mode).
    pub strata: HashMap<u64, StratumAgg>,
    /// Raw draw counts per key (Horvitz-Thompson path only).
    pub draws: HashMap<u64, f64>,
    pub sampled: bool,
    pub metrics: JoinMetrics,
    /// Measured per-stage / per-worker traffic of THIS window.
    pub ledger: ShuffleLedger,
    /// Strata re-drawn this window (touched by arrivals/evictions).
    pub refreshed_strata: u64,
    /// Strata whose reservoir carried over unchanged.
    pub carried_strata: u64,
    /// Faults injected into this window's stages and how they were
    /// recovered; `None` when the stream runs without a fault plan.
    pub fault_report: Option<crate::faults::FaultReport>,
}

impl WindowResult {
    /// Exact per-window join-output cardinality Σ B_i.
    pub fn output_cardinality(&self) -> f64 {
        self.strata.values().map(|s| s.population).sum()
    }
}

/// A whole streaming run: every emitted window plus the run-level ledger
/// (per-window stages tagged `w{index}/{stage}`).
#[derive(Clone, Debug)]
pub struct StreamRun {
    pub windows: Vec<WindowResult>,
    pub ledger: ShuffleLedger,
}

/// One worker's share of one input's micro-batch: the records plus their
/// u32-folded keys. Folding happens **once at arrival** — a record that
/// lives through W windows is probed W times but folded exactly once,
/// instead of re-hashing through `fold_key` on every window's probe and
/// sketch walk.
#[derive(Clone, Debug, Default)]
struct WorkerShard {
    recs: Vec<Record>,
    folded: Vec<u32>,
}

/// One pushed micro-batch split by arrival worker, `[input][worker]`:
/// worker w owns the records at positions ≡ w (mod k) of each input. The
/// split happens once at push time, so every per-worker loop (sketch
/// update, probing) touches only its own records instead of skip-scanning
/// the whole window k times.
type SplitBatch = Vec<Vec<WorkerShard>>;

/// Retention cap of the run-level ledger: with 3 stages per window this
/// keeps ~1300 windows of tagged traffic before the oldest are dropped.
pub const MAX_RUN_LEDGER_STAGES: usize = 4096;

fn split_batch(batch: Vec<Vec<Record>>, k: usize) -> SplitBatch {
    batch
        .into_iter()
        .map(|recs| {
            let mut per_worker: Vec<WorkerShard> =
                (0..k).map(|_| WorkerShard::default()).collect();
            for (j, r) in recs.into_iter().enumerate() {
                let shard = &mut per_worker[j % k];
                shard.folded.push(fold_key(r.key));
                shard.recs.push(r);
            }
            per_worker
        })
        .collect()
}

/// The streaming windowed join operator. Feed it micro-batches with
/// [`StreamingApproxJoin::push_batch`]; it emits a [`WindowResult`] every
/// time a window closes.
pub struct StreamingApproxJoin {
    cfg: StreamConfig,
    /// Wire width of one record, per input (one entry repeats for all).
    record_bytes: Vec<u64>,
    /// Resolved sketch geometry (fixed at the first emission).
    sketch: Option<SketchConfig>,
    /// The master's persistent per-input counting sketches — updated with
    /// every window's arrival/eviction deltas, never rebuilt. u8 cells
    /// saturate at 255 copies of one key per window per input; removes
    /// then skip the saturated cells, which can only cost false positives,
    /// never false negatives.
    sketch_filters: Vec<CountingBloomFilter>,
    /// Batches currently applied to the sketches, oldest first.
    window: VecDeque<SplitBatch>,
    /// Batches pushed since the last emission (not yet sketched).
    pending: Vec<SplitBatch>,
    reservoirs: HashMap<u64, StratumReservoir>,
    /// Per-destination-worker columnar cogroup buffers, carried across
    /// windows so re-cogrouping reuses the flat column allocations.
    cogroup_scratch: Vec<CogroupColumns>,
    batches_pushed: u64,
    run_ledger: ShuffleLedger,
    n_inputs: Option<usize>,
}

impl StreamingApproxJoin {
    pub fn new(cfg: StreamConfig, record_bytes: Vec<u64>) -> Self {
        assert!(cfg.workers >= 1);
        assert!((0.0..1.0).contains(&cfg.fp_rate) && cfg.fp_rate > 0.0);
        assert!(!record_bytes.is_empty(), "need at least one record width");
        if !cfg.variant.is_inner() {
            assert!(
                cfg.sampling.is_none() && !cfg.bloom_filtering,
                "streaming {} joins need the exact unfiltered path \
                 (sampling: None, bloom_filtering: false): unmatched keys \
                 must reach the cogroup to be padded or complemented",
                cfg.variant.tag()
            );
        }
        if let Some(g) = cfg.sketch {
            // validate an explicit geometry against the kind's floor NOW,
            // not at the first window emission deep inside emit()
            assert!(
                g.log2_cells >= cfg.filter_kind.min_log2(),
                "sketch log2_cells {} below the {} filter kind's minimum {}",
                g.log2_cells,
                cfg.filter_kind,
                cfg.filter_kind.min_log2()
            );
        }
        let sketch = cfg.sketch;
        Self {
            cfg,
            record_bytes,
            sketch,
            sketch_filters: Vec::new(),
            window: VecDeque::new(),
            pending: Vec::new(),
            reservoirs: HashMap::new(),
            cogroup_scratch: Vec::new(),
            batches_pushed: 0,
            run_ledger: ShuffleLedger::default(),
            n_inputs: None,
        }
    }

    /// Wire width of one record of `input` (the last reported width
    /// repeats when the source gave fewer widths than inputs).
    fn width(&self, input: usize) -> u64 {
        *self
            .record_bytes
            .get(input)
            .unwrap_or_else(|| self.record_bytes.last().expect("non-empty widths"))
    }

    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }

    /// The run-level ledger: recently emitted windows' measured traffic,
    /// stages tagged `w{index}/{stage}`. Bounded on a long-lived operator —
    /// once it exceeds [`MAX_RUN_LEDGER_STAGES`] stage entries the oldest
    /// windows' stages are dropped (each [`WindowResult`] still carries its
    /// own complete ledger).
    pub fn run_ledger(&self) -> &ShuffleLedger {
        &self.run_ledger
    }

    /// Detach and reset the run-level ledger (long-lived operators can
    /// drain it periodically instead of relying on the retention cap).
    pub fn take_run_ledger(&mut self) -> ShuffleLedger {
        std::mem::take(&mut self.run_ledger)
    }

    /// Push one micro-batch (one record vector per input). Returns the
    /// window result when this batch closes a window.
    pub fn push_batch(&mut self, batch: Vec<Vec<Record>>) -> Option<WindowResult> {
        let n = batch.len();
        assert!(n >= 2, "streaming join needs >= 2 inputs");
        assert!(
            self.cfg.variant.is_inner() || n == 2,
            "streaming {} joins are binary: got {} inputs",
            self.cfg.variant.tag(),
            n
        );
        match self.n_inputs {
            None => self.n_inputs = Some(n),
            Some(m) => assert_eq!(m, n, "input arity changed mid-stream"),
        }
        self.pending.push(split_batch(batch, self.cfg.workers));
        self.batches_pushed += 1;
        if self.cfg.window.emits_after(self.batches_pushed) {
            Some(self.emit())
        } else {
            None
        }
    }

    /// Drive `batches` further micro-batches from a source, collecting
    /// every emitted window. Resumes at the operator's current stream
    /// position, so repeated calls (or calls after manual
    /// [`StreamingApproxJoin::push_batch`]es) pull fresh batches instead of
    /// replaying the source from 0.
    pub fn run(&mut self, source: &mut dyn StreamSource, batches: u64) -> Vec<WindowResult> {
        let start = self.batches_pushed;
        (start..start + batches)
            .filter_map(|t| self.push_batch(source.batch(t)))
            .collect()
    }

    fn emit(&mut self) -> WindowResult {
        let windex = self.cfg.window.window_index(self.batches_pushed);
        let bounds = self.cfg.window.bounds(windex);
        let n = self.n_inputs.expect("emit after at least one batch");
        let k = self.cfg.workers;
        let mut cluster = SimCluster::new(k, self.cfg.time_model)
            .with_parallelism(self.cfg.parallelism)
            .with_faults(self.cfg.faults.map(|p| p.salted(windex)));
        let exec = cluster.exec;

        // batches entering / leaving the window since the last emission
        let arrivals: Vec<SplitBatch> = std::mem::take(&mut self.pending);
        let n_evict = (self.window.len() + arrivals.len()).saturating_sub(self.cfg.window.size);
        let evicted: Vec<SplitBatch> = (0..n_evict)
            .map(|_| self.window.pop_front().expect("evictable batch"))
            .collect();

        // keys whose window contents changed — exactly the reservoirs that
        // must refresh (an untouched key's record set is provably identical)
        let mut changed: HashSet<u64> = HashSet::new();
        for b in arrivals.iter().chain(&evicted) {
            for per_worker in b {
                for shard in per_worker {
                    for r in &shard.recs {
                        changed.insert(r.key);
                    }
                }
            }
        }

        // ---- stage 1: incremental sketch maintenance + filter broadcast
        let join_filter: Option<JoinFilter> = if self.cfg.bloom_filtering {
            let kind = self.cfg.filter_kind;
            let g = *self.sketch.get_or_insert_with(|| {
                // first emission: size for the observed per-batch volume
                // times the window length
                let per_batch = arrivals
                    .iter()
                    .flat_map(|b| {
                        b.iter().map(|per_worker| {
                            per_worker.iter().map(|s| s.recs.len()).sum::<usize>() as u64
                        })
                    })
                    .max()
                    .unwrap_or(1)
                    .max(1);
                SketchConfig::for_capacity_kind(
                    per_batch * self.cfg.window.size as u64,
                    self.cfg.fp_rate,
                    kind,
                )
            });
            if self.sketch_filters.is_empty() {
                self.sketch_filters = (0..n)
                    .map(|_| CountingBloomFilter::new_kind(g.log2_cells, g.num_hashes, kind))
                    .collect();
            }
            let mut s = cluster.stage("sketch_update");
            // each worker ships the cell delta of its locally-arrived /
            // expiring records to the master: 5 bytes per touched cell
            // (u32 index + signed count), never more than the full
            // per-input sketches
            let sketch_bytes = 1u64 << g.log2_cells;
            let mut total_touched = 0u64;
            for w in 0..k {
                let touched: u64 = arrivals
                    .iter()
                    .chain(&evicted)
                    .flat_map(|b| b.iter().map(|per_worker| per_worker[w].recs.len() as u64))
                    .sum();
                let delta = (touched * g.num_hashes as u64 * 5).min(n as u64 * sketch_bytes);
                s.transfer(w, 0, delta);
                total_touched += touched;
            }
            s.add_items(total_touched);
            // the master applies the deltas to its persistent per-input
            // sketches — O(touched cells), not a rebuild; evictions before
            // arrivals, one fixed order, since cell updates at the u8
            // saturation boundary do not commute — then ANDs (cell-wise
            // min) the inputs into the window join sketch and broadcasts
            // its bit view (membership-identical, 1/8 the bytes). Keys were
            // folded once at arrival; the sketch walk reuses the cache.
            let filters = &mut self.sketch_filters;
            let filter = s.task(0, || {
                for b in &evicted {
                    for (i, per_worker) in b.iter().enumerate() {
                        for shard in per_worker {
                            for &fk in &shard.folded {
                                filters[i].remove(fk);
                            }
                        }
                    }
                }
                for b in &arrivals {
                    for (i, per_worker) in b.iter().enumerate() {
                        for shard in per_worker {
                            for &fk in &shard.folded {
                                filters[i].insert(fk);
                            }
                        }
                    }
                }
                let mut join = filters[0].clone();
                for f in &filters[1..] {
                    join.intersect_with(f);
                }
                join.to_join_filter()
            });
            s.broadcast(0, filter.size_bytes());
            s.finish(&mut cluster);
            Some(filter)
        } else {
            None
        };
        self.window.extend(arrivals);
        debug_assert!(self.window.len() <= self.cfg.window.size);

        // ---- stage 2: probe locally-arrived records, shuffle survivors
        let stage_name = if join_filter.is_some() {
            "filter_shuffle"
        } else {
            "shuffle"
        };
        let mut s = cluster.stage(stage_name);
        let window_ref = &self.window;
        let jf = join_filter.as_ref();
        let probed: Vec<(Vec<Vec<Record>>, f64)> = exec.map(k, |w| {
            let t0 = Instant::now();
            let mut mine: Vec<Vec<Record>> = vec![Vec::new(); n];
            for b in window_ref {
                for (i, per_worker) in b.iter().enumerate() {
                    let shard = &per_worker[w];
                    for (r, &fk) in shard.recs.iter().zip(&shard.folded) {
                        // probe on the arrival-time folded key: no
                        // re-hash per window the record survives in
                        let keep = match jf {
                            Some(f) => f.contains(fk),
                            None => true,
                        };
                        if keep {
                            mine[i].push(*r);
                        }
                    }
                }
            }
            (mine, t0.elapsed().as_secs_f64())
        });
        // [dst worker][input] so each destination's records move into the
        // cogroup stage without a copy
        let mut shuffled: Vec<Vec<Vec<Record>>> = vec![vec![Vec::new(); n]; k];
        let mut survivors = 0u64;
        for (w, (mine, secs)) in probed.into_iter().enumerate() {
            s.add_compute(w, secs);
            for (i, recs) in mine.into_iter().enumerate() {
                let width = self.width(i);
                for r in recs {
                    let dst = partition_of(r.key, k);
                    s.transfer(w, dst, width);
                    shuffled[dst][i].push(r);
                    survivors += 1;
                }
            }
        }
        s.add_items(survivors);
        s.finish(&mut cluster);

        // cogroup per destination worker into flat columns (the hash
        // shuffle put every key on exactly one worker); the joinable
        // directory only lists keys present in every input, so survivors
        // of the false-positive-prone filter that miss some input drop
        // out here. The column buffers persist across windows
        // (self.cogroup_scratch), so steady-state windows re-cogroup
        // without allocating.
        let mut scratch = std::mem::take(&mut self.cogroup_scratch);
        scratch.resize_with(k, || CogroupColumns::new(n));
        let states: Vec<(CogroupColumns, Vec<Vec<Record>>)> =
            scratch.into_iter().zip(shuffled).collect();
        let groups: Vec<CogroupColumns> = exec.map_with(
            states,
            |_w, (cols, per_input): &mut (CogroupColumns, Vec<Vec<Record>>)| {
                let slices: Vec<&[Record]> =
                    per_input.iter().map(|v| v.as_slice()).collect();
                cols.rebuild(&slices);
                std::mem::take(cols)
            },
        );

        // ---- stage 3: per-window sample (eviction-aware reservoirs) or
        // the exact cross product
        let estimator = self
            .cfg
            .sampling
            .as_ref()
            .map(|c| c.estimator)
            .unwrap_or(EstimatorKind::Clt);
        let combine = self.cfg.combine;
        let (mut strata, mut draws, sampled, refreshed, carried) = match &self.cfg.sampling {
            Some(acfg) => {
                let mut s = cluster.stage("sample");
                let prev = &self.reservoirs;
                let changed_ref = &changed;
                let groups_ref = &groups;
                type SampleOut = (HashMap<u64, StratumReservoir>, u64, u64, f64);
                let per_worker: Vec<SampleOut> = exec.map(k, |w| {
                    let t0 = Instant::now();
                    let (res, refreshed, carried) = refresh_reservoir_strata_columnar(
                        &groups_ref[w],
                        changed_ref,
                        prev,
                        &acfg.params,
                        acfg.estimator,
                        combine,
                        acfg.seed,
                        windex,
                    );
                    (res, refreshed, carried, t0.elapsed().as_secs_f64())
                });
                let mut reservoirs: HashMap<u64, StratumReservoir> = HashMap::new();
                let (mut refreshed, mut carried, mut drawn) = (0u64, 0u64, 0u64);
                for (w, (res, rf, ca, secs)) in per_worker.into_iter().enumerate() {
                    s.add_compute(w, secs);
                    refreshed += rf;
                    carried += ca;
                    drawn += res
                        .values()
                        .filter(|r| r.epoch == windex)
                        .map(|r| r.draws as u64)
                        .sum::<u64>();
                    reservoirs.extend(res);
                }
                s.add_items(drawn);
                s.finish(&mut cluster);
                let strata: HashMap<u64, StratumAgg> =
                    reservoirs.iter().map(|(&key, r)| (key, r.agg)).collect();
                let draws: HashMap<u64, f64> = match acfg.estimator {
                    EstimatorKind::HorvitzThompson => {
                        reservoirs.iter().map(|(&key, r)| (key, r.draws)).collect()
                    }
                    EstimatorKind::Clt => HashMap::new(),
                };
                self.reservoirs = reservoirs;
                (strata, draws, true, refreshed, carried)
            }
            None => {
                let mut s = cluster.stage("crossproduct");
                let groups_ref = &groups;
                let variant = self.cfg.variant;
                let per_worker: Vec<(HashMap<u64, StratumAgg>, u64, f64)> = exec.map(k, |w| {
                    let t0 = Instant::now();
                    let cg = &groups_ref[w];
                    let mut local = HashMap::with_capacity(cg.num_keys());
                    let mut pairs = 0u64;
                    if variant.is_inner() {
                        let mut sides: Vec<&[f64]> = Vec::with_capacity(cg.n_inputs());
                        for idx in 0..cg.num_keys() {
                            cg.sides_into(idx, &mut sides);
                            let agg = crate::join::cross_product_agg(&sides, combine);
                            pairs += agg.population as u64;
                            local.insert(cg.key(idx), agg);
                        }
                    } else {
                        // unfiltered shuffle put every window record of a
                        // key on this worker, so the full run directories
                        // support padding/complement resolution locally
                        for (key, agg) in
                            crate::join::variant_strata_from_cogroup(cg, combine, variant)
                        {
                            pairs += agg.population as u64;
                            local.insert(key, agg);
                        }
                    }
                    (local, pairs, t0.elapsed().as_secs_f64())
                });
                let mut strata = HashMap::new();
                for (w, (local, pairs, secs)) in per_worker.into_iter().enumerate() {
                    s.add_compute(w, secs);
                    s.add_items(pairs);
                    strata.extend(local);
                }
                s.finish(&mut cluster);
                (strata, HashMap::new(), false, 0, 0)
            }
        };

        // hand the columnar buffers back for the next window's rebuild
        self.cogroup_scratch = groups;

        // fault harvest. Sampled windows degrade like batch queries: drop
        // the dead workers' strata, re-weight survivors, widen the CI (an
        // all-strata loss leaves an empty, flagged window). Exact windows
        // keep their strata — the operator retains every live batch in
        // memory, so a lost worker's share is replayed from the window
        // buffer rather than dropped.
        let mut fault_report = cluster.take_fault_report();
        if let Some(rep) = fault_report.as_mut() {
            if sampled {
                let _ = crate::faults::degrade_strata(rep, &mut strata, &mut draws, k, true);
            }
        }

        let result = crate::coordinator::estimate_result(
            self.cfg.agg,
            sampled,
            estimator,
            &strata,
            &draws,
            self.cfg.confidence,
        );
        let metrics = cluster.take_metrics();
        let ledger = cluster.take_ledger();
        self.run_ledger.merge(ledger.tagged(&format!("w{windex}")));
        // bound the run ledger on long-lived streams: drop the oldest
        // windows' stages once past the retention cap
        if self.run_ledger.stages.len() > MAX_RUN_LEDGER_STAGES {
            let excess = self.run_ledger.stages.len() - MAX_RUN_LEDGER_STAGES;
            self.run_ledger.stages.drain(..excess);
        }
        WindowResult {
            bounds,
            result,
            strata,
            draws,
            sampled,
            metrics,
            ledger,
            refreshed_strata: refreshed,
            carried_strata: carried,
            fault_report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::join::approx::SamplingParams;

    fn fast_model() -> TimeModel {
        TimeModel {
            bandwidth: 1e9,
            stage_latency: 0.0,
            compute_scale: 1.0,
        }
    }

    fn cfg(window: WindowSpec, sampling: Option<ApproxConfig>) -> StreamConfig {
        StreamConfig {
            window,
            workers: 4,
            time_model: fast_model(),
            parallelism: 1,
            sampling,
            ..Default::default()
        }
    }

    fn batch(a: &[(u64, f64)], b: &[(u64, f64)]) -> Vec<Vec<Record>> {
        vec![
            a.iter().map(|&(k, v)| Record::new(k, v)).collect(),
            b.iter().map(|&(k, v)| Record::new(k, v)).collect(),
        ]
    }

    #[test]
    fn sketch_geometry_matches_counting_filter_sizing() {
        // for_capacity avoids allocating a filter; its arithmetic must stay
        // in lockstep with CountingBloomFilter::with_capacity
        for &(items, fp) in &[(1u64, 0.01), (100, 0.01), (12_000, 0.01), (48_000, 0.02)] {
            let s = SketchConfig::for_capacity(items, fp);
            let f = CountingBloomFilter::with_capacity(items, fp);
            assert_eq!(s.log2_cells, f.log2_cells(), "items {items} fp {fp}");
            assert_eq!(s.num_hashes, f.num_hashes().min(6), "items {items} fp {fp}");
        }
    }

    #[test]
    fn tumbling_exact_windows_match_hand_computation() {
        let mut j = StreamingApproxJoin::new(cfg(WindowSpec::tumbling(1), None), vec![100, 100]);
        // window 0: key 1 -> (1+10) + (2+10); key 2 absent from b
        let w0 = j
            .push_batch(batch(&[(1, 1.0), (1, 2.0), (2, 5.0)], &[(1, 10.0)]))
            .expect("tumbling(1) emits every batch");
        assert!(!w0.sampled);
        assert_eq!(w0.bounds.index, 0);
        assert_eq!(w0.strata.len(), 1);
        assert_eq!(w0.strata[&1].population, 2.0);
        assert!((w0.result.estimate - 23.0).abs() < 1e-9);
        assert_eq!(w0.result.error_bound, 0.0);
        // window 1: key 1 expired; key 3 joins now
        let w1 = j
            .push_batch(batch(&[(3, 1.0)], &[(3, 2.0), (3, 4.0)]))
            .unwrap();
        assert_eq!(w1.bounds.index, 1);
        assert!(!w1.strata.contains_key(&1), "expired key must leave");
        assert!((w1.result.estimate - ((1.0 + 2.0) + (1.0 + 4.0))).abs() < 1e-9);
        // window 2: key 1 re-inserted after full eviction — the counting
        // sketch's delete path must not have broken it
        let w2 = j.push_batch(batch(&[(1, 1.0)], &[(1, 5.0)])).unwrap();
        assert_eq!(w2.strata.len(), 1);
        assert!((w2.result.estimate - 6.0).abs() < 1e-9);
    }

    #[test]
    fn sliding_window_carries_unchanged_strata() {
        // W=2, S=1; key 7 lives only in batch 1, key 8 in every batch
        let sampling = ApproxConfig {
            params: SamplingParams::Fraction(0.5),
            estimator: EstimatorKind::Clt,
            seed: 5,
        };
        let mut j = StreamingApproxJoin::new(
            cfg(WindowSpec::sliding(2, 1), Some(sampling)),
            vec![100, 100],
        );
        let b0 = batch(&[(8, 1.0), (8, 2.0)], &[(8, 10.0)]);
        let b1 = batch(&[(7, 3.0), (8, 4.0)], &[(7, 30.0), (8, 40.0)]);
        let b2 = batch(&[(9, 1.0)], &[(9, 2.0)]);
        assert!(j.push_batch(b0).is_none(), "window not full yet");
        let w0 = j.push_batch(b1).expect("first full window");
        assert!(w0.sampled);
        assert!(w0.strata.contains_key(&7) && w0.strata.contains_key(&8));
        assert_eq!(w0.carried_strata, 0, "first window refreshes everything");
        // window 1 = {b1, b2}: batch 0 evicts (touches 8), batch 2 arrives
        // (touches 9); key 7's contents are identical -> carried verbatim
        let w1 = j.push_batch(b2).expect("slides every batch");
        assert_eq!(w1.carried_strata, 1);
        assert_eq!(w1.strata[&7], w0.strata[&7], "key 7 reservoir must carry");
        assert_eq!(w1.strata[&7].population, 1.0);
        assert!(w1.strata.contains_key(&9));
        // key 8 remains joinable (b1 has it on both sides) but refreshed
        assert!(w1.strata.contains_key(&8));
        assert_ne!(w1.strata[&8].population, w0.strata[&8].population);
    }

    #[test]
    fn filtered_and_unfiltered_agree_on_strata_filtered_moves_less() {
        use crate::stream::source::{EventStream, EventStreamSpec};
        let spec = EventStreamSpec {
            events_per_batch: 800,
            shared_fraction: 0.08,
            seed: 11,
            ..Default::default()
        };
        let run = |filtering: bool| {
            let mut c = cfg(WindowSpec::tumbling(3), None);
            c.bloom_filtering = filtering;
            let mut j = StreamingApproxJoin::new(c, vec![100, 100]);
            j.run(&mut EventStream::new(spec.clone()), 6)
        };
        let filtered = run(true);
        let unfiltered = run(false);
        assert_eq!(filtered.len(), 2);
        assert_eq!(unfiltered.len(), 2);
        for (f, u) in filtered.iter().zip(&unfiltered) {
            // identical exact answers — filtering only drops non-joinable
            // tuples (plus false positives that cogrouping discards)
            assert_eq!(f.result.estimate.to_bits(), u.result.estimate.to_bits());
            assert_eq!(f.strata.len(), u.strata.len());
            // and strictly less measured traffic at 8% overlap
            assert!(
                f.ledger.total_bytes() < u.ledger.total_bytes(),
                "window {}: filtered {} vs unfiltered {}",
                f.bounds.index,
                f.ledger.total_bytes(),
                u.ledger.total_bytes()
            );
            assert!(f.ledger.stage_bytes("filter_shuffle") < u.ledger.stage_bytes("shuffle"));
        }
    }

    #[test]
    fn blocked_filter_kind_matches_standard_windows() {
        use crate::stream::source::{EventStream, EventStreamSpec};
        let spec = EventStreamSpec {
            events_per_batch: 600,
            shared_fraction: 0.1,
            seed: 29,
            ..Default::default()
        };
        let run = |kind: FilterKind| {
            let mut c = cfg(WindowSpec::sliding(3, 1), None);
            c.filter_kind = kind;
            let mut j = StreamingApproxJoin::new(c, vec![100, 100]);
            j.run(&mut EventStream::new(spec.clone()), 6)
        };
        let std_run = run(FilterKind::Standard);
        let blk_run = run(FilterKind::Blocked);
        assert_eq!(std_run.len(), blk_run.len());
        for (a, b) in std_run.iter().zip(&blk_run) {
            // false positives die at the cogroup, so exact window answers
            // are identical; only probe layout (and possibly a few more
            // shuffled false-positive bytes) differ
            assert_eq!(a.result.estimate.to_bits(), b.result.estimate.to_bits());
            assert_eq!(a.strata, b.strata);
        }
    }

    #[test]
    fn exact_window_variants_pad_and_complement() {
        // window: a = {1:[1,2], 2:[5]}, b = {1:[10], 3:[7]}
        let a: &[(u64, f64)] = &[(1, 1.0), (1, 2.0), (2, 5.0)];
        let b: &[(u64, f64)] = &[(1, 10.0), (3, 7.0)];
        let run = |variant: JoinVariant| {
            let mut c = cfg(WindowSpec::tumbling(1), None);
            c.bloom_filtering = false;
            c.variant = variant;
            let mut j = StreamingApproxJoin::new(c, vec![100, 100]);
            j.push_batch(batch(a, b)).expect("tumbling(1) emits")
        };
        let inner = run(JoinVariant::Inner);
        assert_eq!(inner.output_cardinality(), 2.0);
        assert!((inner.result.estimate - 23.0).abs() < 1e-9);
        // left outer pads key 2 with its own values
        let lo = run(JoinVariant::LeftOuter);
        assert_eq!(lo.output_cardinality(), 3.0);
        assert!((lo.result.estimate - 28.0).abs() < 1e-9);
        // full outer additionally pads key 3 from the right
        let fo = run(JoinVariant::FullOuter);
        assert_eq!(fo.output_cardinality(), 4.0);
        assert!((fo.result.estimate - 35.0).abs() < 1e-9);
        // semi keeps a's rows under matched keys; anti the complement
        let semi = run(JoinVariant::Semi);
        assert_eq!(semi.output_cardinality(), 2.0);
        assert!((semi.result.estimate - 3.0).abs() < 1e-9);
        let anti = run(JoinVariant::Anti);
        assert_eq!(anti.output_cardinality(), 1.0);
        assert!((anti.result.estimate - 5.0).abs() < 1e-9);
        for w in [&inner, &lo, &fo, &semi, &anti] {
            assert!(!w.sampled);
            assert_eq!(w.result.error_bound, 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "exact unfiltered path")]
    fn non_inner_streaming_rejects_sampling() {
        let mut c = cfg(
            WindowSpec::tumbling(1),
            Some(ApproxConfig {
                params: SamplingParams::Fraction(0.5),
                estimator: EstimatorKind::Clt,
                seed: 1,
            }),
        );
        c.variant = JoinVariant::LeftOuter;
        let _ = StreamingApproxJoin::new(c, vec![100, 100]);
    }

    #[test]
    fn run_ledger_tags_windows() {
        let mut j = StreamingApproxJoin::new(cfg(WindowSpec::tumbling(1), None), vec![100, 100]);
        let w0 = j.push_batch(batch(&[(1, 1.0)], &[(1, 2.0)])).unwrap();
        let _ = j.push_batch(batch(&[(2, 1.0)], &[(2, 2.0)])).unwrap();
        let run = j.run_ledger();
        assert_eq!(run.prefix_bytes("w0/"), w0.ledger.total_bytes());
        assert!(run.stages.iter().any(|s| s.stage.starts_with("w1/")));
        assert_eq!(
            run.total_bytes(),
            run.prefix_bytes("w0/") + run.prefix_bytes("w1/")
        );
    }

    #[test]
    fn thread_count_invariance_quick() {
        use crate::stream::source::{EventStream, EventStreamSpec};
        let spec = EventStreamSpec {
            events_per_batch: 400,
            shared_fraction: 0.2,
            seed: 3,
            ..Default::default()
        };
        let sampling = ApproxConfig {
            params: SamplingParams::Fraction(0.3),
            estimator: EstimatorKind::Clt,
            seed: 17,
        };
        let run = |threads: usize| {
            let mut c = cfg(WindowSpec::sliding(4, 2), Some(sampling.clone()));
            c.parallelism = threads;
            let mut j = StreamingApproxJoin::new(c, vec![100, 100]);
            j.run(&mut EventStream::new(spec.clone()), 8)
        };
        let seq = run(1);
        let par = run(8);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.result.estimate.to_bits(), b.result.estimate.to_bits());
            assert_eq!(a.result.error_bound.to_bits(), b.result.error_bound.to_bits());
            assert_eq!(a.strata, b.strata);
            assert_eq!(a.ledger, b.ledger);
        }
    }
}
