//! Streaming execution mode: the batch ApproxJoin pipeline driven
//! incrementally over an unbounded micro-batched stream (the StreamApprox
//! direction — *Approximate Stream Analytics in Apache Flink and Apache
//! Spark Streaming*, arXiv 1709.02946 — grafted onto this repo's
//! Bloom-filtered join).
//!
//! * [`source`] — micro-batch [`StreamSource`]s: the unbounded
//!   [`EventStream`] generator and [`ReplaySource`] over the batch `data/`
//!   generators.
//! * [`window`] — tumbling/sliding [`WindowSpec`] in micro-batch units.
//! * [`join`] — [`StreamingApproxJoin`]: incremental counting-Bloom
//!   sketches (expired tuples are *deleted* from the sketch, never
//!   rebuilt), per-window filtered shuffle with measured
//!   [`crate::cluster::ShuffleLedger`] traffic, eviction-aware per-stratum
//!   reservoirs, and per-window CLT / Horvitz-Thompson confidence
//!   intervals.
//!
//! The [`crate::session::StreamingSession`] front end is how callers reach
//! this module; the `approxjoin stream` CLI subcommand and
//! `examples/streaming_windows.rs` drive it end to end.

pub mod join;
pub mod source;
pub mod window;

pub use join::{
    SketchConfig, StreamConfig, StreamRun, StreamingApproxJoin, WindowResult,
};
pub use source::{EventStream, EventStreamSpec, ReplaySource, StreamSource};
pub use window::{WindowBounds, WindowSpec};
