//! Micro-batch stream sources: the unbounded event generator and a replay
//! adapter over the batch-world `data/` generators.
//!
//! A [`StreamSource`] hands out micro-batches by index. Batch `t` must be a
//! pure function of `(source spec, t)` — never of how many batches were
//! pulled before it — so a stream replays identically across runs and
//! thread counts; the bit-identity tests lean on this the same way the
//! batch path leans on seeded datasets.

use crate::data::generators::ValueDist;
use crate::data::{Dataset, Record};
use crate::util::rng::{splitmix64, Rng};

/// A micro-batched record stream feeding the streaming join: `n >= 2`
/// inputs advancing in lock-step, one record vector per input per batch.
pub trait StreamSource {
    fn num_inputs(&self) -> usize;

    /// Wire width of one record per input, for shuffle accounting (the
    /// batch strategies charge per-dataset widths; the streaming path does
    /// the same).
    fn record_bytes(&self) -> Vec<u64>;

    /// The `t`-th micro-batch (t = 0, 1, ...): one record vector per input.
    /// Must be deterministic in `t`.
    fn batch(&mut self, t: u64) -> Vec<Vec<Record>>;
}

/// Specification of the unbounded synthetic event stream: every batch draws
/// `events_per_batch` events per input; a `shared_fraction` of the events
/// reference a hot shared key pool (the streaming analogue of the batch
/// generators' overlap fraction), the rest reference a per-input private
/// pool. Popularity within each pool is Zipf(`zipf_s`) (0.0 = uniform), so
/// the per-window multiplicities are naturally skewed / heavy-tailed.
#[derive(Clone, Debug)]
pub struct EventStreamSpec {
    /// Number of joined input streams (n-way, >= 2).
    pub num_inputs: usize,
    /// Events per input per micro-batch.
    pub events_per_batch: u64,
    /// Size of the shared (joinable) key pool.
    pub shared_keys: u64,
    /// Size of each input's private key pool.
    pub private_keys: u64,
    /// Probability an event's key comes from the shared pool — the
    /// streaming overlap knob.
    pub shared_fraction: f64,
    /// Zipf exponent for key popularity within a pool (0.0 = uniform).
    pub zipf_s: f64,
    /// Value distribution of the aggregated attribute.
    pub values: ValueDist,
    /// Wire width of one event (bytes) for shuffle accounting.
    pub record_bytes: u64,
    pub seed: u64,
}

impl Default for EventStreamSpec {
    fn default() -> Self {
        Self {
            num_inputs: 2,
            events_per_batch: 2_000,
            shared_keys: 48,
            private_keys: 4_096,
            shared_fraction: 0.05,
            zipf_s: 0.4,
            values: ValueDist::Uniform(0.0, 100.0),
            record_bytes: 100,
            seed: 42,
        }
    }
}

/// Key tags keep the shared and per-input private pools disjoint by
/// construction (same scheme as the batch generators).
#[inline]
fn shared_key(i: u64) -> u64 {
    (1 << 40) | i
}

#[inline]
fn private_key(input: usize, i: u64) -> u64 {
    ((input as u64 + 2) << 41) | i
}

/// The unbounded event generator.
pub struct EventStream {
    pub spec: EventStreamSpec,
}

impl EventStream {
    pub fn new(spec: EventStreamSpec) -> Self {
        assert!(spec.num_inputs >= 2, "a streaming join needs >= 2 inputs");
        assert!((0.0..=1.0).contains(&spec.shared_fraction));
        assert!(spec.shared_keys >= 1 && spec.private_keys >= 1);
        // the key tags give the shared pool the low 40 bits and each
        // private pool the low 41; larger pools would silently collide
        // across inputs and corrupt the overlap knob
        assert!(
            spec.shared_keys <= 1 << 40,
            "shared_keys exceeds the 2^40 shared key tag space"
        );
        assert!(
            spec.private_keys <= 1 << 41,
            "private_keys exceeds the 2^41 per-input key tag space"
        );
        Self { spec }
    }
}

impl StreamSource for EventStream {
    fn num_inputs(&self) -> usize {
        self.spec.num_inputs
    }

    fn record_bytes(&self) -> Vec<u64> {
        vec![self.spec.record_bytes; self.spec.num_inputs]
    }

    fn batch(&mut self, t: u64) -> Vec<Vec<Record>> {
        let s = &self.spec;
        (0..s.num_inputs)
            .map(|i| {
                // one independent stream per (batch, input): seeded from the
                // spec seed and the coordinates only, never from pull order
                let mut z = s.seed
                    ^ t.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ (i as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                let mut r = Rng::new(splitmix64(&mut z));
                (0..s.events_per_batch)
                    .map(|_| {
                        let key = if r.f64() < s.shared_fraction {
                            shared_key(r.zipf(s.shared_keys, s.zipf_s) - 1)
                        } else {
                            private_key(i, r.zipf(s.private_keys, s.zipf_s) - 1)
                        };
                        Record::new(key, s.values.sample(&mut r))
                    })
                    .collect()
            })
            .collect()
    }
}

/// Replays batch-world datasets (the `data/` generators: synthetic, TPC-H,
/// network, Netflix) as an unbounded stream: each input's records cycle in
/// record order, `batch_records` per micro-batch.
pub struct ReplaySource {
    per_input: Vec<Vec<Record>>,
    /// Per-dataset wire widths — heterogeneous inputs (e.g. TPC-H tables)
    /// keep their own byte accounting, as on the batch path.
    record_bytes: Vec<u64>,
    batch_records: usize,
}

impl ReplaySource {
    pub fn new(datasets: &[Dataset], batch_records: usize) -> Self {
        assert!(datasets.len() >= 2, "a streaming join needs >= 2 inputs");
        assert!(batch_records >= 1);
        assert!(
            datasets.iter().all(|d| !d.is_empty()),
            "cannot replay an empty dataset"
        );
        Self {
            per_input: datasets
                .iter()
                .map(|d| d.iter().copied().collect())
                .collect(),
            record_bytes: datasets.iter().map(|d| d.record_bytes).collect(),
            batch_records,
        }
    }
}

impl StreamSource for ReplaySource {
    fn num_inputs(&self) -> usize {
        self.per_input.len()
    }

    fn record_bytes(&self) -> Vec<u64> {
        self.record_bytes.clone()
    }

    fn batch(&mut self, t: u64) -> Vec<Vec<Record>> {
        self.per_input
            .iter()
            .map(|recs| {
                let start = (t as usize).wrapping_mul(self.batch_records);
                (0..self.batch_records)
                    .map(|j| recs[(start + j) % recs.len()])
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_overlapping, SyntheticSpec};

    #[test]
    fn event_batches_are_deterministic_in_t() {
        let mut a = EventStream::new(EventStreamSpec::default());
        let mut b = EventStream::new(EventStreamSpec::default());
        // pull order must not matter
        let a3 = a.batch(3);
        let _ = b.batch(0);
        let _ = b.batch(7);
        assert_eq!(a3, b.batch(3));
        assert_ne!(a.batch(0), a.batch(1), "distinct batches must differ");
    }

    #[test]
    fn event_shared_fraction_controls_overlap() {
        let mut s = EventStream::new(EventStreamSpec {
            shared_fraction: 0.1,
            ..Default::default()
        });
        let batch = s.batch(0);
        assert_eq!(batch.len(), 2);
        for recs in &batch {
            assert_eq!(recs.len(), 2_000);
            let shared = recs.iter().filter(|r| r.key >> 40 == 1).count();
            let frac = shared as f64 / recs.len() as f64;
            assert!((frac - 0.1).abs() < 0.03, "shared fraction {frac}");
        }
        // private pools of different inputs are disjoint
        let keys0: std::collections::HashSet<u64> = batch[0]
            .iter()
            .map(|r| r.key)
            .filter(|k| k >> 41 != 0)
            .collect();
        let keys1: std::collections::HashSet<u64> = batch[1]
            .iter()
            .map(|r| r.key)
            .filter(|k| k >> 41 != 0)
            .collect();
        assert!(keys0.is_disjoint(&keys1));
    }

    #[test]
    fn event_zipf_skews_popularity() {
        let mut s = EventStream::new(EventStreamSpec {
            shared_fraction: 1.0,
            shared_keys: 10,
            zipf_s: 1.2,
            ..Default::default()
        });
        let batch = s.batch(0);
        let mut counts = vec![0u64; 10];
        for r in &batch[0] {
            counts[(r.key & 0xFFFF) as usize] += 1;
        }
        assert!(counts[0] > counts[4], "{counts:?}");
        assert!(counts[0] > counts[9], "{counts:?}");
    }

    #[test]
    fn replay_cycles_in_record_order() {
        let ds = generate_overlapping(&SyntheticSpec {
            items_per_input: 1_000,
            ..Default::default()
        });
        let mut src = ReplaySource::new(&ds, 300);
        assert_eq!(src.num_inputs(), 2);
        assert_eq!(src.record_bytes(), vec![100, 100]);
        let b0 = src.batch(0);
        let b1 = src.batch(1);
        assert_eq!(b0[0].len(), 300);
        assert_ne!(b0, b1);
        // deterministic replay
        assert_eq!(b0, src.batch(0));
        // replay cycles: input 0's batch n starts at offset n·300 ≡ 0 (mod n)
        let n = ds[0].len();
        assert_eq!(src.batch(n)[0], b0[0]);
    }

    #[test]
    fn replay_keeps_heterogeneous_record_widths() {
        let a = Dataset::from_records_unpartitioned(
            "wide",
            vec![Record::new(1, 1.0), Record::new(2, 2.0)],
            2,
            1000,
        );
        let b = Dataset::from_records_unpartitioned(
            "narrow",
            vec![Record::new(1, 3.0), Record::new(2, 4.0)],
            2,
            40,
        );
        let src = ReplaySource::new(&[a, b], 2);
        assert_eq!(src.record_bytes(), vec![1000, 40]);
    }
}
