//! Micro-batch windowing: tumbling and sliding windows measured in
//! micro-batches, plus the emission schedule the streaming join follows.
//!
//! A window of `size` batches emits every `slide` batches once the first
//! `size` batches have arrived. `slide == size` is a tumbling window (no
//! batch belongs to two windows); `slide < size` is a sliding window
//! (consecutive windows share `size - slide` batches — the shared batches
//! are exactly the tuples the streaming join does *not* re-sketch and does
//! *not* re-sample).

/// How a stream is windowed, in micro-batch units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSpec {
    /// Window length in micro-batches (>= 1).
    pub size: usize,
    /// Emission period in micro-batches (1 ..= size).
    pub slide: usize,
}

impl WindowSpec {
    /// A tumbling window: emit every `size` batches, no overlap.
    pub fn tumbling(size: usize) -> Self {
        Self::sliding(size, size)
    }

    /// A sliding window: `size` batches long, emitted every `slide` batches.
    pub fn sliding(size: usize, slide: usize) -> Self {
        assert!(size >= 1, "window size must be >= 1");
        assert!(
            (1..=size).contains(&slide),
            "slide must be in 1..=size (got {slide} for size {size})"
        );
        Self { size, slide }
    }

    pub fn is_tumbling(&self) -> bool {
        self.slide == self.size
    }

    /// Whether a window closes after `batches_pushed` total batches.
    pub fn emits_after(&self, batches_pushed: u64) -> bool {
        batches_pushed >= self.size as u64
            && (batches_pushed - self.size as u64) % self.slide as u64 == 0
    }

    /// Index of the window that closes after `batches_pushed` batches
    /// (only meaningful when [`WindowSpec::emits_after`] is true).
    pub fn window_index(&self, batches_pushed: u64) -> u64 {
        debug_assert!(self.emits_after(batches_pushed));
        (batches_pushed - self.size as u64) / self.slide as u64
    }

    /// The batch range window `index` covers.
    pub fn bounds(&self, index: u64) -> WindowBounds {
        let first_batch = index * self.slide as u64;
        WindowBounds {
            index,
            first_batch,
            last_batch: first_batch + self.size as u64 - 1,
        }
    }
}

impl Default for WindowSpec {
    fn default() -> Self {
        Self::tumbling(4)
    }
}

/// The inclusive batch range of one emitted window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowBounds {
    pub index: u64,
    pub first_batch: u64,
    pub last_batch: u64,
}

impl WindowBounds {
    pub fn len(&self) -> u64 {
        self.last_batch - self.first_batch + 1
    }

    pub fn is_empty(&self) -> bool {
        false // a window always covers at least one batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tumbling_schedule() {
        let w = WindowSpec::tumbling(4);
        assert!(w.is_tumbling());
        let emits: Vec<u64> = (1..=13).filter(|&t| w.emits_after(t)).collect();
        assert_eq!(emits, vec![4, 8, 12]);
        assert_eq!(w.window_index(4), 0);
        assert_eq!(w.window_index(12), 2);
        let b = w.bounds(2);
        assert_eq!((b.first_batch, b.last_batch, b.len()), (8, 11, 4));
    }

    #[test]
    fn sliding_schedule_overlaps() {
        let w = WindowSpec::sliding(6, 2);
        assert!(!w.is_tumbling());
        let emits: Vec<u64> = (1..=12).filter(|&t| w.emits_after(t)).collect();
        assert_eq!(emits, vec![6, 8, 10, 12]);
        let b0 = w.bounds(0);
        let b1 = w.bounds(1);
        assert_eq!((b0.first_batch, b0.last_batch), (0, 5));
        assert_eq!((b1.first_batch, b1.last_batch), (2, 7));
        // consecutive windows share size - slide = 4 batches
        assert_eq!(b0.last_batch - b1.first_batch + 1, 4);
    }

    #[test]
    fn slide_one_emits_every_batch_after_fill() {
        let w = WindowSpec::sliding(3, 1);
        let emits: Vec<u64> = (1..=6).filter(|&t| w.emits_after(t)).collect();
        assert_eq!(emits, vec![3, 4, 5, 6]);
    }

    #[test]
    #[should_panic(expected = "slide")]
    fn slide_larger_than_size_rejected() {
        WindowSpec::sliding(2, 3);
    }
}
