//! The fluent session front end — the one way callers run joins.
//!
//! A [`Session`] owns named datasets, an [`ApproxJoinEngine`] (cost model,
//! feedback store, optional XLA runtime) and a [`StrategyRegistry`]. A
//! query flows through a [`QueryBuilder`]:
//!
//! ```no_run
//! use approxjoin::coordinator::EngineConfig;
//! use approxjoin::data::{generate_overlapping, SyntheticSpec};
//! use approxjoin::session::{Session, StrategyChoice};
//!
//! # fn main() -> anyhow::Result<()> {
//! let inputs = generate_overlapping(&SyntheticSpec::default());
//! let outcome = Session::new(EngineConfig::default())?
//!     .with_data("a", inputs[0].clone())
//!     .with_data("b", inputs[1].clone())
//!     .sql("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k")?
//!     .strategy(StrategyChoice::Auto)
//!     .run()?;
//! println!("{} via {}", outcome.result.estimate, outcome.strategy);
//! # Ok(())
//! # }
//! ```
//!
//! `strategy(Auto)` lets the cost-based [`Planner`] rank the registered
//! strategies on input statistics (bloom wins at low key overlap,
//! repartition at high; a budget in the query routes to the sampled
//! ApproxJoin pipeline). `strategy(Named("bloom"))` forces one. `plan()` /
//! `explain()` expose the ranking without executing anything.

mod relational;
pub mod streaming;

pub use streaming::StreamingSession;

use crate::cluster::SimCluster;
use crate::coordinator::{
    estimate_result, ApproxJoinEngine, EngineConfig, ExecutionMode, QueryOutcome,
};
use crate::cost::CostModel;
use crate::data::Dataset;
use crate::join::approx::{ApproxConfig, SamplingParams};
use crate::join::{
    ApproxJoin, BernoulliJoin, BloomJoin, BroadcastJoin, InputStats, JoinError, JoinPlan,
    JoinStrategy, NativeJoin, Planner, RepartitionJoin, StrategyRegistry, UniverseJoin,
};
use crate::query::{parse, Query};
use crate::relation::{Relation, Row, Schema};
use crate::stats::EstimatorKind;
use anyhow::Result;
use std::collections::HashMap;

pub use crate::join::StrategyChoice;

/// The default registry, parameterized by the session's engine config so
/// `fp_rate`, `memory_budget`, `estimator` and `seed` carry through to the
/// strategies the planner hands out.
fn registry_for(cfg: &EngineConfig) -> StrategyRegistry {
    // a kind-only (auto-sized) filter config pins the engine's filter
    // kind while leaving the geometry to be sized from the inputs at
    // execute time; the standard default keeps `filter: None`
    let filter = match cfg.filter_kind {
        crate::bloom::FilterKind::Standard => None,
        kind => Some(crate::join::bloom_join::FilterConfig::auto_sized(kind)),
    };
    let mut r = StrategyRegistry::empty();
    r.register(Box::new(BloomJoin {
        fp_rate: cfg.fp_rate,
        filter,
    }));
    r.register(Box::new(RepartitionJoin));
    r.register(Box::new(BroadcastJoin));
    r.register(Box::new(NativeJoin {
        memory_budget: cfg.memory_budget,
    }));
    r.register(Box::new(ApproxJoin {
        fp_rate: cfg.fp_rate,
        filter,
        config: ApproxConfig {
            params: SamplingParams::Fraction(0.1),
            estimator: cfg.estimator,
            seed: cfg.seed,
        },
    }));
    // centralized sample-first baselines — explicit-name only (the planner
    // never Auto-picks a baseline), seeded from the session config
    r.register(Box::new(BernoulliJoin {
        fraction: 0.1,
        seed: cfg.seed,
    }));
    r.register(Box::new(UniverseJoin {
        fraction: 0.1,
        seed: cfg.seed,
    }));
    r
}

/// A connection-like handle: datasets, engine state and the strategy
/// registry every query planned in this session draws from.
pub struct Session {
    engine: ApproxJoinEngine,
    registry: StrategyRegistry,
    datasets: HashMap<String, Dataset>,
    /// Typed multi-column relations (the relational front end). Tables
    /// and datasets share one namespace; queries resolve tables first.
    tables: HashMap<String, Relation>,
}

impl Session {
    /// Open a session; compiles the AOT artifacts when available.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let registry = registry_for(&cfg);
        Ok(Self {
            engine: ApproxJoinEngine::new(cfg)?,
            registry,
            datasets: HashMap::new(),
            tables: HashMap::new(),
        })
    }

    /// Pure-Rust session (no artifacts) — tests, quick starts.
    pub fn without_runtime(cfg: EngineConfig) -> Result<Self> {
        let registry = registry_for(&cfg);
        Ok(Self {
            engine: ApproxJoinEngine::without_runtime(cfg)?,
            registry,
            datasets: HashMap::new(),
            tables: HashMap::new(),
        })
    }

    /// True when `name` is already taken by a dataset or a table.
    pub fn is_registered(&self, name: &str) -> bool {
        self.datasets.contains_key(name) || self.tables.contains_key(name)
    }

    /// Register a dataset under the name queries reference it by.
    /// Replaces (and warns about) an existing registration of the same
    /// name; use [`Session::try_with_data`] to make a conflict an error.
    pub fn with_data(mut self, name: &str, mut dataset: Dataset) -> Self {
        if self.is_registered(name) {
            eprintln!(
                "warning: dataset {name} is already registered in this \
                 session; replacing it"
            );
            self.tables.remove(name);
        }
        dataset.name = name.to_string();
        self.datasets.insert(name.to_string(), dataset);
        self.invalidate_sketches(name);
        self
    }

    /// Like [`Session::with_data`], but an already-registered name is an
    /// error instead of a silent replacement.
    pub fn try_with_data(self, name: &str, dataset: Dataset) -> Result<Self, JoinError> {
        if self.is_registered(name) {
            return Err(JoinError::Runtime(format!(
                "dataset {name} is already registered in this session"
            )));
        }
        Ok(self.with_data(name, dataset))
    }

    /// Register datasets under their own names. Replaces (and warns
    /// about) existing registrations of the same name.
    pub fn with_datasets(mut self, datasets: impl IntoIterator<Item = Dataset>) -> Self {
        for d in datasets {
            let name = d.name.clone();
            self = self.with_data(&name, d);
        }
        self
    }

    /// Register a typed multi-column relation from a schema and rows —
    /// the relational analogue of [`Session::with_data`]. Rows are
    /// validated against the schema; a name collision (dataset or table)
    /// is an error, never a silent replacement.
    ///
    /// ```
    /// use approxjoin::coordinator::EngineConfig;
    /// use approxjoin::relation::{ColumnType, Schema, Value};
    /// use approxjoin::session::Session;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let customers = Schema::new(vec![
    ///     ("custkey", ColumnType::Key),
    ///     ("balance", ColumnType::Float),
    /// ]);
    /// let orders = Schema::new(vec![
    ///     ("custkey", ColumnType::Key),
    ///     ("region", ColumnType::Int),
    ///     ("price", ColumnType::Float),
    /// ]);
    /// let mut session = Session::without_runtime(EngineConfig {
    ///     workers: 2,
    ///     ..Default::default()
    /// })?
    /// .register_table(
    ///     "customers",
    ///     customers,
    ///     vec![
    ///         vec![Value::Key(1), Value::Float(50.0)],
    ///         vec![Value::Key(2), Value::Float(80.0)],
    ///         vec![Value::Key(3), Value::Float(10.0)],
    ///     ],
    /// )?
    /// .register_table(
    ///     "orders",
    ///     orders,
    ///     vec![
    ///         vec![Value::Key(1), Value::Int(7), Value::Float(10.0)],
    ///         vec![Value::Key(1), Value::Int(8), Value::Float(30.0)],
    ///         vec![Value::Key(2), Value::Int(7), Value::Float(20.0)],
    ///         vec![Value::Key(3), Value::Int(7), Value::Float(40.0)],
    ///     ],
    /// )?;
    /// // grouped + filtered: predicate pushed below the join, one
    /// // estimate ± CI per region
    /// let out = session
    ///     .sql(
    ///         "SELECT region, SUM(orders.price) AS revenue \
    ///          FROM orders, customers \
    ///          WHERE orders.custkey = customers.custkey \
    ///            AND customers.balance > 40 \
    ///          GROUP BY region",
    ///     )?
    ///     .run()?;
    /// let grouped = out.grouped.expect("grouped query");
    /// let revenue = &grouped.aggregates[0];
    /// assert_eq!(revenue.label, "revenue");
    /// // region 7: custkey 1 (10.0) + custkey 2 (20.0); custkey 3 was
    /// // filtered out by balance > 40 before the join
    /// assert_eq!(revenue.groups[0].result.estimate, 30.0);
    /// assert_eq!(revenue.groups[1].result.estimate, 30.0); // region 8
    /// # Ok(())
    /// # }
    /// ```
    pub fn register_table(
        mut self,
        name: &str,
        schema: Schema,
        rows: Vec<Row>,
    ) -> Result<Self> {
        if self.is_registered(name) {
            anyhow::bail!("table {name} is already registered in this session");
        }
        let partitions = self.engine.cfg.workers.max(1) * 2;
        let relation = Relation::new(name, schema, rows, partitions)?;
        self.tables.insert(name.to_string(), relation);
        self.invalidate_sketches(name);
        Ok(self)
    }

    /// Register an already-built relation under a name (fluent). Replaces
    /// (and warns about) an existing registration of the same name.
    pub fn with_table(mut self, name: &str, mut relation: Relation) -> Self {
        if self.is_registered(name) {
            eprintln!(
                "warning: table {name} is already registered in this \
                 session; replacing it"
            );
            self.datasets.remove(name);
        }
        relation.name = name.to_string();
        self.tables.insert(name.to_string(), relation);
        self.invalidate_sketches(name);
        self
    }

    /// Bump the attached sketch cache's epoch for `name` — every (re-)
    /// registration path funnels through here so a cache can never serve a
    /// sketch built over a table's previous contents.
    fn invalidate_sketches(&self, name: &str) {
        if let Some(cache) = &self.engine.sketches {
            cache.invalidate(name);
        }
    }

    /// Attach a shared [`crate::serve::SketchCache`]: budgeted queries in
    /// this session reuse (and contribute) stage-1 sketches. Attach the
    /// cache *before* registering data so the registrations invalidate
    /// against it.
    pub fn with_sketch_cache(mut self, cache: std::sync::Arc<crate::serve::SketchCache>) -> Self {
        self.engine = self.engine.with_sketches(cache);
        self
    }

    /// Namespace this session's σ feedback under `scope` (see
    /// [`crate::cost::FeedbackStore::with_scope`]) — concurrent serving
    /// sessions use one scope per client so feedback never interleaves.
    pub fn with_feedback_scope(mut self, scope: impl Into<String>) -> Self {
        self.engine.feedback.set_scope(scope);
        self
    }

    /// A registered relation, if any.
    pub fn table(&self, name: &str) -> Option<&Relation> {
        self.tables.get(name)
    }

    /// A registered dataset, if any.
    pub fn dataset(&self, name: &str) -> Option<&Dataset> {
        self.datasets.get(name)
    }

    /// Use a profiled cost model (β_compute from this host / cluster).
    pub fn with_cost_model(mut self, cost: CostModel) -> Self {
        self.engine = self.engine.with_cost_model(cost);
        self
    }

    /// Register (or replace) a join strategy — new strategies are a
    /// registry entry, not a new code path.
    pub fn with_strategy(mut self, strategy: Box<dyn JoinStrategy>) -> Self {
        self.registry.register(strategy);
        self
    }

    pub fn registry(&self) -> &StrategyRegistry {
        &self.registry
    }

    pub fn cost(&self) -> &CostModel {
        &self.engine.cost
    }

    pub fn has_runtime(&self) -> bool {
        self.engine.has_runtime()
    }

    /// Escape hatch to the underlying engine (feedback store, cost model).
    pub fn engine_mut(&mut self) -> &mut ApproxJoinEngine {
        &mut self.engine
    }

    /// Parse a budget-SQL query into a [`QueryBuilder`]. The builder
    /// defaults to [`StrategyChoice::Auto`].
    pub fn sql(&mut self, text: &str) -> Result<QueryBuilder<'_>> {
        let query = parse(text)?;
        Ok(QueryBuilder {
            session: self,
            query,
            choice: StrategyChoice::Auto,
        })
    }

    /// Build a query from an already-parsed AST.
    pub fn query(&mut self, query: Query) -> QueryBuilder<'_> {
        QueryBuilder {
            session: self,
            query,
            choice: StrategyChoice::Auto,
        }
    }

    fn resolve_inputs(&self, query: &Query) -> Result<Vec<Dataset>, JoinError> {
        let mut inputs = Vec::with_capacity(query.tables.len());
        for t in &query.tables {
            match self.datasets.get(t) {
                Some(d) => inputs.push(d.clone()),
                None => {
                    return Err(JoinError::Runtime(format!(
                        "dataset {t} not registered in this session"
                    )))
                }
            }
        }
        Ok(inputs)
    }
}

/// One query, ready to plan or run.
pub struct QueryBuilder<'a> {
    session: &'a mut Session,
    query: Query,
    choice: StrategyChoice,
}

impl QueryBuilder<'_> {
    /// Pick how the strategy is chosen: [`StrategyChoice::Auto`] (the
    /// planner ranks by predicted cost) or `Named` (force one).
    pub fn strategy(mut self, choice: StrategyChoice) -> Self {
        self.choice = choice;
        self
    }

    pub fn query(&self) -> &Query {
        &self.query
    }

    fn stats(&self, inputs: &[Dataset]) -> InputStats {
        InputStats::collect(
            inputs,
            self.session.engine.cfg.workers,
            &self.session.engine.cfg.time_model,
        )
    }

    /// Run the join-order optimizer for this query over `inputs` (given in
    /// FROM order). `None` when ordering is skipped — see
    /// [`crate::join::order::plan_query_order`]. Pure function of the
    /// session's (config, feedback snapshot) and the query, so the plan
    /// and the engine recompute identical orders.
    fn order_report(&self, inputs: &[Dataset]) -> Option<crate::join::JoinOrderReport> {
        let engine = &self.session.engine;
        // non-inner joins are not freely commutable — an outer join's
        // padded side is positional, semi/anti are left-anchored — so the
        // optimizer only ever reorders inner joins
        let commutative = matches!(
            self.query.combine,
            crate::join::CombineOp::Sum | crate::join::CombineOp::Product
        ) && self.query.variant.is_inner();
        let ctx = crate::join::order::OrderContext {
            feedback: Some(&engine.feedback),
            predicate_tag: String::new(),
            beta_compute: engine.cost.beta_compute,
            workers: engine.cfg.workers,
            bandwidth: engine.cfg.time_model.bandwidth,
            enabled: engine.cfg.reorder_joins,
        };
        let stats = crate::join::TableStats::collect(inputs, &self.query.tables);
        crate::join::order::plan_query_order(
            &self.query.tables,
            &self.query.join_clauses,
            commutative,
            &stats,
            &ctx,
        )
    }

    /// Produce the cost-based [`JoinPlan`] without executing anything.
    /// Relational queries (predicates, GROUP BY, typed tables) are
    /// lowered first, so the plan carries the pushed-down predicates and
    /// the lowered kernel plan.
    pub fn plan(&self) -> Result<JoinPlan, JoinError> {
        if relational::is_relational(self.session, &self.query) {
            return relational::plan_relational(self.session, &self.query, &self.choice)
                .map(|(plan, _)| plan);
        }
        let inputs = self.session.resolve_inputs(&self.query)?;
        let order = self.order_report(&inputs);
        let mut stats = self.stats(&inputs);
        if let Some(r) = &order {
            if r.reordered {
                stats = stats.permuted(&r.order);
            }
        }
        Planner::new(&self.session.registry, &self.session.engine.cost)
            .plan(&stats, &self.choice, &self.query.budget)
            .map(|p| p.with_order(order))
    }

    /// `plan()` rendered as an EXPLAIN-style string.
    pub fn explain(&self) -> Result<String, JoinError> {
        Ok(self.plan()?.explain())
    }

    /// Plan and execute the query; returns the result with its confidence
    /// interval, cluster metrics, and the plan that produced it. Queries
    /// with relational features (predicates, GROUP BY, multiple
    /// aggregates) or over typed tables run through the relational
    /// lowering; `QueryOutcome::grouped` then carries one estimate ± CI
    /// per group per aggregate.
    pub fn run(self) -> Result<QueryOutcome> {
        if relational::is_relational(self.session, &self.query) {
            return relational::run_relational(self.session, &self.query, &self.choice);
        }
        let inputs = self.session.resolve_inputs(&self.query)?;
        // join-order optimization: plan on FROM-order inputs, execute on
        // the permuted ones (query.tables is never mutated — fingerprints
        // and feedback continuity depend on it)
        let order = self.order_report(&inputs);
        let exec_inputs: Vec<Dataset> = match &order {
            Some(r) if r.reordered => crate::join::order::permute(&inputs, &r.order),
            _ => inputs.clone(),
        };
        let stats = self.stats(&exec_inputs);
        let session = &mut *self.session;
        let plan = Planner::new(&session.registry, &session.engine.cost)
            .plan(&stats, &self.choice, &self.query.budget)?
            .with_order(order.clone());

        // An approximate plan for a budgeted query goes through the engine:
        // its §3.2 cost function sizes the sampling fraction from the
        // *measured* filter time, runs the feedback loop, and may still
        // conclude the budget is loose enough for the exact (bloom) path.
        // This covers both Auto and Named("approx") — only an unbudgeted
        // forced approx run uses the strategy's own fixed sampling config.
        // The engine receives the ORIGINAL (FROM-order) inputs and owns the
        // reordering itself — both sides plan from the same feedback
        // snapshot, so they compute the same order.
        if plan.approximate
            && !self.query.budget.is_unbounded()
            && self.query.variant.is_inner()
            && !plan.chosen().baseline
        {
            let mut outcome = session.engine.execute_on(&self.query, &inputs)?;
            outcome.plan = Some(
                plan.with_order(outcome.join_order.clone())
                    .with_measured_shuffle(outcome.ledger.total_bytes())
                    .with_filter_report(outcome.filter_report),
            );
            return Ok(outcome);
        }
        if !plan.approximate
            && !self.query.budget.is_unbounded()
            && matches!(self.choice, StrategyChoice::Named(_))
        {
            // a forced exact strategy cannot honor a sampling budget
            // (Auto-planned exact means the budget was loose enough)
            eprintln!(
                "warning: strategy {} is exact; the query's latency/error \
                 budget is ignored",
                plan.strategy
            );
        }

        let strategy = session
            .registry
            .get(&plan.strategy)
            .expect("planned strategy is registered");
        let mut cluster = SimCluster::new(
            session.engine.cfg.workers,
            session.engine.cfg.time_model,
        )
        .with_parallelism(session.engine.cfg.parallelism)
        .with_faults(session.engine.cfg.faults);
        let run = strategy.execute_variant(
            &mut cluster,
            &exec_inputs,
            self.query.combine,
            self.query.variant,
        )?;

        let confidence = self
            .query
            .budget
            .error
            .map(|e| e.confidence)
            .unwrap_or(0.95);
        // the draws map is only populated by Horvitz-Thompson sampling
        let estimator = if run.draws.is_empty() {
            EstimatorKind::Clt
        } else {
            EstimatorKind::HorvitzThompson
        };
        // sample-first baselines carry a join-level closed-form estimator;
        // everything else estimates from the per-stratum aggregates
        let result = match &run.baseline {
            Some(report) => report.result_for(self.query.agg, confidence)?,
            None => estimate_result(
                self.query.agg,
                run.sampled,
                estimator,
                &run.strata,
                &run.draws,
                confidence,
            ),
        };
        session
            .engine
            .feedback
            .record(&self.query.fingerprint(), &run.strata);

        let output_cardinality: f64 = run.strata.values().map(|s| s.population).sum();
        let sampled_count: f64 = run.strata.values().map(|s| s.count).sum();
        let mode = if run.sampled {
            ExecutionMode::Sampled {
                // baselines report their input sampling fraction; sampled
                // strata report the per-stratum draw fraction
                fraction: match &run.baseline {
                    Some(report) => report.fraction,
                    None if output_cardinality > 0.0 => sampled_count / output_cardinality,
                    None => 1.0,
                },
            }
        } else {
            ExecutionMode::Exact
        };
        let metrics = run.metrics;
        let ledger = run.ledger;

        // close the calibration loop for the direct-strategy path (the
        // engine path calibrates inside execute_on)
        let mut join_order = order;
        if let Some(r) = join_order.as_mut() {
            r.set_measured(&crate::join::order::measure_step_cardinalities(
                &exec_inputs,
            ));
            let exec_tables = r.tables.clone();
            crate::join::order::calibrate(
                &mut session.engine.feedback,
                "",
                &exec_tables,
                &exec_inputs,
                r.cost.shuffle_bytes,
                ledger.total_bytes() as f64,
            );
        }

        Ok(QueryOutcome {
            sim_secs: metrics.total_sim_secs(),
            d_dt: metrics.stage_secs("build_filter") + metrics.stage_secs("filter_shuffle"),
            result,
            mode,
            output_cardinality,
            metrics,
            strategy: plan.strategy.clone(),
            plan: Some(
                plan.with_order(join_order.clone())
                    .with_measured_shuffle(ledger.total_bytes())
                    .with_filter_report(run.filter_report),
            ),
            ledger,
            grouped: None,
            filter_report: run.filter_report,
            join_order,
            fault_report: run.fault_report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::{generate_overlapping, SyntheticSpec};

    /// Network-bound cluster so strategy ranking is shuffle-driven, plus a
    /// small worker count to keep the tests quick.
    fn config() -> EngineConfig {
        EngineConfig {
            workers: 4,
            time_model: TimeModel {
                bandwidth: 1e6,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
            ..Default::default()
        }
    }

    fn workload(overlap: f64) -> Vec<Dataset> {
        generate_overlapping(&SyntheticSpec {
            items_per_input: 10_000,
            overlap_fraction: overlap,
            lambda: 20.0,
            partitions: 4,
            seed: 21,
            ..Default::default()
        })
    }

    fn session_with(overlap: f64) -> Session {
        let inputs = workload(overlap);
        Session::without_runtime(config())
            .unwrap()
            .with_data("a", inputs[0].clone())
            .with_data("b", inputs[1].clone())
    }

    const SQL: &str = "SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k";

    #[test]
    fn auto_strategy_depends_on_overlap() {
        let low = session_with(0.01).sql(SQL).unwrap().run().unwrap();
        assert_eq!(low.strategy, "bloom", "\n{}", low.plan.unwrap().explain());
        assert_eq!(low.mode, ExecutionMode::Exact);

        let high = session_with(1.0).sql(SQL).unwrap().run().unwrap();
        assert_eq!(
            high.strategy,
            "repartition",
            "\n{}",
            high.plan.unwrap().explain()
        );
    }

    #[test]
    fn named_strategies_agree_on_the_exact_answer() {
        let mut sums = Vec::new();
        for name in ["native", "repartition", "broadcast", "bloom"] {
            let mut s = session_with(0.05);
            let out = s
                .sql(SQL)
                .unwrap()
                .strategy(StrategyChoice::named(name))
                .run()
                .unwrap();
            assert_eq!(out.strategy, name);
            assert_eq!(out.mode, ExecutionMode::Exact);
            assert_eq!(out.result.error_bound, 0.0, "{name}");
            sums.push(out.result.estimate);
        }
        for s in &sums[1..] {
            assert!(
                (s - sums[0]).abs() < 1e-6 * (1.0 + sums[0].abs()),
                "{sums:?}"
            );
        }
    }

    #[test]
    fn named_approx_samples_without_a_budget() {
        let mut s = session_with(0.2);
        let exact = s.sql(SQL).unwrap().run().unwrap();
        let approx = s
            .sql(SQL)
            .unwrap()
            .strategy(StrategyChoice::named("approx"))
            .run()
            .unwrap();
        assert_eq!(approx.strategy, "approx");
        match approx.mode {
            ExecutionMode::Sampled { fraction } => {
                assert!(fraction > 0.0 && fraction < 1.0, "fraction {fraction}")
            }
            m => panic!("expected sampled, got {m:?}"),
        }
        let rel = (approx.result.estimate - exact.result.estimate).abs()
            / exact.result.estimate.abs();
        assert!(rel < 0.1, "rel {rel}");
        assert!(approx.result.error_bound > 0.0);
    }

    #[test]
    fn budgeted_query_routes_through_the_engine() {
        let mut s = session_with(0.2);
        let out = s
            .sql("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 0.000001 SECONDS")
            .unwrap()
            .run()
            .unwrap();
        match out.mode {
            ExecutionMode::Sampled { fraction } => assert!(fraction < 1.0),
            m => panic!("expected sampled, got {m:?}"),
        }
        assert_eq!(out.strategy, "approx");
        let plan = out.plan.expect("session queries carry a plan");
        assert!(plan.approximate);
    }

    #[test]
    fn unknown_strategy_and_missing_dataset_error() {
        let mut s = session_with(0.05);
        let err = s
            .sql(SQL)
            .unwrap()
            .strategy(StrategyChoice::named("hash"))
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("unsupported"), "{err:#}");

        let mut empty = Session::without_runtime(config()).unwrap();
        let err = empty.sql(SQL).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("not registered"), "{err:#}");
    }

    #[test]
    fn explain_without_executing() {
        let mut s = session_with(0.01);
        let text = s.sql(SQL).unwrap().explain().unwrap();
        assert!(text.contains("JoinPlan"), "{text}");
        assert!(text.contains("<- chosen"), "{text}");
        assert!(text.contains("not executed yet"), "{text}");
    }

    #[test]
    fn executed_plan_carries_measured_shuffle() {
        let out = session_with(0.05).sql(SQL).unwrap().run().unwrap();
        let plan = out.plan.expect("session queries carry a plan");
        assert_eq!(
            plan.measured_shuffle_bytes,
            Some(out.ledger.total_bytes()),
            "plan must carry the run's measured bytes"
        );
        assert_eq!(out.ledger.total_bytes(), out.metrics.total_shuffled_bytes());
        let text = plan.explain();
        assert!(text.contains("measured"), "{text}");
    }

    #[test]
    fn duplicate_registration_is_a_conflict_not_a_silent_drop() {
        let inputs = workload(0.05);

        // try_with_data: an existing name is an error
        let s = Session::without_runtime(config())
            .unwrap()
            .with_data("a", inputs[0].clone());
        assert!(s.is_registered("a"));
        let err = s.try_with_data("a", inputs[1].clone()).unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err}");

        // with_data / with_datasets replace deterministically (the last
        // registration wins) and log the conflict instead of silently
        // dropping one of the datasets
        let mut small = inputs[1].clone();
        small.partitions.truncate(1);
        let s = Session::without_runtime(config())
            .unwrap()
            .with_data("a", inputs[0].clone())
            .with_data("a", small.clone());
        assert_eq!(s.dataset("a").unwrap().len(), small.len());

        let mut named = inputs[0].clone();
        named.name = "dup".to_string();
        let mut named2 = small.clone();
        named2.name = "dup".to_string();
        let s = Session::without_runtime(config())
            .unwrap()
            .with_datasets([named, named2.clone()]);
        assert_eq!(s.dataset("dup").unwrap().len(), named2.len());

        // register_table refuses both table and dataset collisions
        use crate::relation::{ColumnType, Schema, Value};
        let schema = Schema::new(vec![("k", ColumnType::Key), ("v", ColumnType::Float)]);
        let rows = vec![vec![Value::Key(1), Value::Float(1.0)]];
        let s = Session::without_runtime(config())
            .unwrap()
            .with_data("a", inputs[0].clone())
            .register_table("t", schema.clone(), rows.clone())
            .unwrap();
        assert!(s.table("t").is_some());
        let err = s
            .register_table("t", schema.clone(), rows.clone())
            .unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err:#}");
        let s = Session::without_runtime(config())
            .unwrap()
            .with_data("a", inputs[0].clone());
        let err = s.register_table("a", schema, rows).unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err:#}");
    }

    #[test]
    fn fluent_one_liner_chains() {
        let inputs = workload(0.05);
        let out = Session::without_runtime(config())
            .unwrap()
            .with_data("a", inputs[0].clone())
            .with_data("b", inputs[1].clone())
            .sql(SQL)
            .unwrap()
            .run()
            .unwrap();
        assert!(out.result.estimate != 0.0);
        assert!(out.plan.is_some());
    }
}
