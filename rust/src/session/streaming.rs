//! The streaming session front end — the one way callers run windowed
//! streaming joins, mirroring the batch [`super::Session`] fluent shape:
//!
//! ```no_run
//! use approxjoin::coordinator::EngineConfig;
//! use approxjoin::session::StreamingSession;
//! use approxjoin::stream::{EventStream, EventStreamSpec, WindowSpec};
//!
//! let mut source = EventStream::new(EventStreamSpec::default());
//! let run = StreamingSession::new(&EngineConfig::default())
//!     .window(WindowSpec::sliding(6, 2))
//!     .sampling_fraction(0.1)
//!     .run(&mut source, 24);
//! for w in &run.windows {
//!     println!(
//!         "window {} [{}..{}]: {:.1} ± {:.1}",
//!         w.bounds.index, w.bounds.first_batch, w.bounds.last_batch,
//!         w.result.estimate, w.result.error_bound
//!     );
//! }
//! ```
//!
//! The builder maps the engine configuration (workers, time model,
//! parallelism, fp rate, estimator, seed) onto a [`StreamConfig`] and adds
//! the streaming-only knobs: window shape, per-window sampling, the
//! unfiltered baseline, and the exact truth twin.

use crate::coordinator::EngineConfig;
use crate::join::approx::{ApproxConfig, SamplingParams};
use crate::join::CombineOp;
use crate::query::AggFunc;
use crate::stream::{
    StreamConfig, StreamRun, StreamSource, StreamingApproxJoin, WindowSpec,
};

/// Fluent builder for streaming windowed joins.
#[derive(Clone, Debug)]
pub struct StreamingSession {
    config: StreamConfig,
    /// The session's sampling defaults (estimator, seed) — restored when
    /// sampling is re-enabled after `.exact()`.
    base_sampling: ApproxConfig,
}

impl StreamingSession {
    /// A streaming session on the engine's cluster model: `workers`,
    /// `time_model`, `parallelism`, `fp_rate`, `estimator` and `seed` carry
    /// through; sampling defaults to a 10% fraction per window.
    pub fn new(cfg: &EngineConfig) -> Self {
        let base_sampling = ApproxConfig {
            params: SamplingParams::Fraction(0.1),
            estimator: cfg.estimator,
            seed: cfg.seed,
        };
        Self {
            config: StreamConfig {
                workers: cfg.workers,
                time_model: cfg.time_model,
                parallelism: cfg.parallelism,
                fp_rate: cfg.fp_rate,
                filter_kind: cfg.filter_kind,
                sampling: Some(base_sampling.clone()),
                faults: cfg.faults,
                ..Default::default()
            },
            base_sampling,
        }
    }

    /// Sketch/filter bit layout — [`crate::bloom::FilterKind::Blocked`]
    /// opts this stream into the one-cache-line probe path.
    pub fn filter_kind(mut self, kind: crate::bloom::FilterKind) -> Self {
        self.config.filter_kind = kind;
        self
    }

    /// Window shape (tumbling or sliding), in micro-batch units.
    pub fn window(mut self, spec: WindowSpec) -> Self {
        self.config.window = spec;
        self
    }

    /// Per-window uniform sampling fraction — keeps the session's
    /// estimator and seed, even when re-enabling sampling after
    /// [`StreamingSession::exact`].
    pub fn sampling_fraction(mut self, fraction: f64) -> Self {
        let prev = self
            .config
            .sampling
            .take()
            .unwrap_or_else(|| self.base_sampling.clone());
        self.config.sampling = Some(ApproxConfig {
            params: SamplingParams::Fraction(fraction),
            ..prev
        });
        self
    }

    /// Full per-window sampling configuration (params + estimator + seed);
    /// becomes the session's new sampling default.
    pub fn sampling(mut self, cfg: ApproxConfig) -> Self {
        self.base_sampling = cfg.clone();
        self.config.sampling = Some(cfg);
        self
    }

    /// Enumerate the exact per-window cross products instead of sampling —
    /// the truth twin the soundness tests compare against.
    pub fn exact(mut self) -> Self {
        self.config.sampling = None;
        self
    }

    /// Disable the Bloom filtering stage: every window record is shuffled —
    /// the baseline the per-window shuffle-reduction claim is measured
    /// against.
    pub fn unfiltered(mut self) -> Self {
        self.config.bloom_filtering = false;
        self
    }

    /// How per-input values combine inside the aggregate.
    pub fn combine(mut self, op: CombineOp) -> Self {
        self.config.combine = op;
        self
    }

    /// Join variant of every emitted window. Non-inner variants need every
    /// window record at the cogroup (unmatched keys are padded or
    /// complemented there), so selecting one switches the session onto the
    /// exact unfiltered path — sampling and Bloom filtering turn off, as
    /// if [`StreamingSession::exact`] and [`StreamingSession::unfiltered`]
    /// had been called.
    pub fn variant(mut self, variant: crate::join::JoinVariant) -> Self {
        self.config.variant = variant;
        if !variant.is_inner() {
            self.config.sampling = None;
            self.config.bloom_filtering = false;
        }
        self
    }

    pub fn aggregate(mut self, agg: AggFunc) -> Self {
        self.config.agg = agg;
        self
    }

    pub fn confidence(mut self, confidence: f64) -> Self {
        assert!((0.0..1.0).contains(&confidence) && confidence > 0.0);
        self.config.confidence = confidence;
        self
    }

    /// Explicit window-sketch geometry.
    pub fn sketch(mut self, sketch: crate::stream::SketchConfig) -> Self {
        self.config.sketch = Some(sketch);
        self
    }

    pub fn config(&self) -> &StreamConfig {
        &self.config
    }

    /// Open a long-lived operator for manual [`StreamingApproxJoin::push_batch`]
    /// driving. `record_bytes` holds one wire width per input (the last
    /// repeats if fewer are given).
    pub fn open(&self, record_bytes: Vec<u64>) -> StreamingApproxJoin {
        StreamingApproxJoin::new(self.config.clone(), record_bytes)
    }

    /// Open a continuous standing-query engine
    /// ([`crate::continuous::ContinuousEngine`]) on this session's
    /// cluster knobs: parallelism, sampling policy (including `.exact()`
    /// and the estimator/seed defaults) and sketch fp rate carry over;
    /// `window_batches` is the engine's sliding-window length. Register
    /// tables and SQL on the returned engine, then feed it micro-batches.
    pub fn open_continuous(&self, window_batches: usize) -> crate::continuous::ContinuousEngine {
        crate::continuous::ContinuousEngine::new(crate::continuous::ContinuousConfig {
            window_batches,
            parallelism: self.config.parallelism,
            sampling: self.config.sampling.clone(),
            fp_rate: self.config.fp_rate,
            faults: self.config.faults,
            ..crate::continuous::ContinuousConfig::default()
        })
    }

    /// Drive `batches` micro-batches from a source and collect every
    /// emitted window plus the tagged run ledger.
    pub fn run(&self, source: &mut dyn StreamSource, batches: u64) -> StreamRun {
        let mut join = self.open(source.record_bytes());
        let windows = join.run(source, batches);
        StreamRun {
            windows,
            ledger: join.run_ledger().clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::stream::{EventStream, EventStreamSpec};

    fn engine_config() -> EngineConfig {
        EngineConfig {
            workers: 4,
            parallelism: 1,
            time_model: TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
            ..Default::default()
        }
    }

    fn source(seed: u64) -> EventStream {
        EventStream::new(EventStreamSpec {
            events_per_batch: 600,
            shared_fraction: 0.2,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn fluent_streaming_run_produces_windows() {
        let run = StreamingSession::new(&engine_config())
            .window(WindowSpec::sliding(4, 2))
            .sampling_fraction(0.3)
            .run(&mut source(9), 10);
        assert_eq!(run.windows.len(), 4); // emits after 4, 6, 8, 10 batches
        for (i, w) in run.windows.iter().enumerate() {
            assert_eq!(w.bounds.index, i as u64);
            assert!(w.sampled);
            assert!(w.result.estimate > 0.0);
            assert!(w.result.error_bound > 0.0);
            assert!(!w.ledger.stages.is_empty());
            // the run ledger carries this window's bytes under its tag
            assert_eq!(
                run.ledger.prefix_bytes(&format!("w{i}/")),
                w.ledger.total_bytes()
            );
        }
    }

    #[test]
    fn sampled_estimates_track_the_exact_twin() {
        let session = StreamingSession::new(&engine_config()).window(WindowSpec::tumbling(3));
        let sampled = session
            .clone()
            .sampling_fraction(0.4)
            .run(&mut source(31), 9);
        let exact = session.exact().run(&mut source(31), 9);
        assert_eq!(sampled.windows.len(), exact.windows.len());
        for (s, e) in sampled.windows.iter().zip(&exact.windows) {
            assert!(!e.sampled);
            assert_eq!(e.result.error_bound, 0.0);
            // exact per-window populations agree — the filter stage knows
            // every stratum's size regardless of sampling
            assert_eq!(s.output_cardinality(), e.output_cardinality());
            let rel = (s.result.estimate - e.result.estimate).abs() / e.result.estimate.abs();
            assert!(rel < 0.15, "window {}: rel {rel}", s.bounds.index);
        }
    }

    #[test]
    fn sampling_after_exact_restores_engine_estimator_and_seed() {
        use crate::stats::EstimatorKind;
        let cfg = EngineConfig {
            estimator: EstimatorKind::HorvitzThompson,
            seed: 123,
            ..engine_config()
        };
        let s = StreamingSession::new(&cfg).exact().sampling_fraction(0.2);
        let sampling = s.config().sampling.as_ref().expect("sampling re-enabled");
        assert_eq!(sampling.estimator, EstimatorKind::HorvitzThompson);
        assert_eq!(sampling.seed, 123);
    }

    #[test]
    fn open_continuous_inherits_session_knobs() {
        use crate::continuous::feed;
        let session = StreamingSession::new(&engine_config()).sampling_fraction(0.25);
        let mut eng = session
            .open_continuous(3)
            .with_table("a", feed::feed_schema())
            .with_table("b", feed::feed_schema());
        assert_eq!(eng.config().window_batches, 3);
        assert_eq!(eng.config().parallelism, 1);
        let q = eng
            .register("SELECT g, COUNT(*) FROM a, b WHERE a.k = b.k GROUP BY a.g")
            .unwrap();
        let mut feed = feed::RowFeed::new(2, feed::FeedSpec::default());
        for _ in 0..4 {
            eng.push_batch(feed.next_batch()).unwrap();
        }
        assert_eq!(eng.current(q).unwrap(), eng.recompute(q).unwrap());
        // exact sessions hand their exactness to the engine too
        let exact = StreamingSession::new(&engine_config()).exact().open_continuous(2);
        assert!(exact.config().sampling.is_none());
    }

    #[test]
    fn run_resumes_at_the_stream_position() {
        // two runs on one operator must consume fresh batches, not replay
        let session = StreamingSession::new(&engine_config())
            .window(WindowSpec::tumbling(2))
            .exact();
        let mut src = source(4);
        let mut join = session.open(src.record_bytes());
        let first = join.run(&mut src, 4);
        let second = join.run(&mut src, 4);
        assert_eq!(first.len(), 2);
        assert_eq!(second.len(), 2);
        assert_eq!(
            (second[0].bounds.first_batch, second[1].bounds.last_batch),
            (4, 7)
        );
        // one continuous 8-batch run sees the identical windows
        let whole = session.run(&mut source(4), 8);
        for (w, cont) in first.iter().chain(&second).zip(&whole.windows) {
            assert_eq!(w.bounds, cont.bounds);
            assert_eq!(w.result.estimate.to_bits(), cont.result.estimate.to_bits());
            assert_eq!(w.strata, cont.strata);
        }
    }

    #[test]
    fn variant_builder_switches_to_the_exact_unfiltered_path() {
        use crate::join::JoinVariant;
        let session = StreamingSession::new(&engine_config())
            .window(WindowSpec::tumbling(2))
            .sampling_fraction(0.3)
            .variant(JoinVariant::LeftOuter);
        assert!(session.config().sampling.is_none());
        assert!(!session.config().bloom_filtering);
        let outer = session.run(&mut source(13), 4);
        let inner = StreamingSession::new(&engine_config())
            .window(WindowSpec::tumbling(2))
            .exact()
            .unfiltered()
            .run(&mut source(13), 4);
        assert_eq!(outer.windows.len(), inner.windows.len());
        for (o, i) in outer.windows.iter().zip(&inner.windows) {
            assert!(!o.sampled);
            // the outer result covers the inner pairs plus left-side pads
            assert!(o.output_cardinality() >= i.output_cardinality());
            assert!(o.strata.len() >= i.strata.len());
        }
    }

    #[test]
    fn unfiltered_baseline_moves_more_bytes() {
        let session = StreamingSession::new(&engine_config())
            .window(WindowSpec::tumbling(3))
            .sampling_fraction(0.2);
        let filtered = session.clone().run(&mut source(7), 6);
        let unfiltered = session.unfiltered().run(&mut source(7), 6);
        for (f, u) in filtered.windows.iter().zip(&unfiltered.windows) {
            assert!(f.ledger.total_bytes() < u.ledger.total_bytes());
            assert_eq!(f.result.estimate.to_bits(), u.result.estimate.to_bits());
        }
    }
}
