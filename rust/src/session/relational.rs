//! The relational execution path behind [`super::Session`]: resolve the
//! FROM list against registered [`Relation`]s (legacy datasets wrap as
//! degenerate two-column relations), build the logical plan, lower it
//! onto the join kernel (predicate pushdown, per-aggregate projection,
//! GROUP BY composite strata), rank strategies with the same cost-based
//! [`Planner`], execute, and assemble per-group estimates.
//!
//! The kernel — the strategy implementations and the partition-parallel
//! runtime — is untouched: this module only changes *what* records it
//! joins (post-filter, composite-keyed) and how the resulting strata are
//! read back out (per group instead of in one total).

use crate::cluster::SimCluster;
use crate::coordinator::{estimate_result, ExecutionMode, QueryOutcome};
use crate::join::approx::{sample_stage, ApproxConfig, NativeAggregator, SamplingParams};
use crate::join::bloom_join::{
    cross_product_stage, filter_and_shuffle, FilterConfig, NativeProber,
};
use crate::join::{InputStats, JoinError, JoinPlan, Planner, StrategyChoice};
use crate::query::{Budget, Query};
use crate::relation::grouped::{assemble_grouped, assemble_ungrouped};
use crate::relation::{lower, GroupedApproxResult, LogicalPlan, LoweredQuery, Relation};
use crate::stats::{EstimatorKind, StratumAgg};
use std::collections::HashMap;

use super::Session;

/// Whether a query must take the relational path: it uses relational
/// grammar (predicates, GROUP BY, multiple aggregates) or scans at least
/// one table registered as a typed relation.
pub(crate) fn is_relational(session: &Session, query: &Query) -> bool {
    query.has_relational_features()
        || query.tables.iter().any(|t| session.tables.contains_key(t))
}

/// Wrap any dataset-backed FROM entries as degenerate relations (typed
/// tables are borrowed from the session, never cloned). One `None` per
/// table that resolves to a registered typed relation.
fn wrap_datasets(
    session: &Session,
    query: &Query,
) -> Result<Vec<Option<Relation>>, JoinError> {
    let mut owned = Vec::with_capacity(query.tables.len());
    for t in &query.tables {
        if session.tables.contains_key(t) {
            owned.push(None);
        } else if let Some(d) = session.datasets.get(t) {
            owned.push(Some(Relation::from_dataset(d)));
        } else {
            return Err(JoinError::Runtime(format!(
                "dataset {t} not registered in this session"
            )));
        }
    }
    Ok(owned)
}

/// Everything that shapes the lowered *keys* (and therefore the join
/// filter): the join attribute, the pushed predicates, the GROUP BY
/// composite strata — but not the per-aggregate value projection, which
/// only the cogroup cache entry keys on. Also tags the join-order
/// optimizer's learned selectivities, so different predicate mixes
/// calibrate independently.
pub(crate) fn predicate_tag(query: &Query) -> String {
    let mut t = format!("attr={}", query.join_attr);
    for p in &query.predicates {
        t.push_str(&format!(";{p}"));
    }
    if let Some(g) = &query.group_by {
        t.push_str(&format!(";g={g}"));
    }
    t
}

/// Lower the query and rank strategies on the lowered kernel inputs.
/// When the join-order optimizer reorders, the lowered per-aggregate
/// inputs come back permuted into execution order (the report on the
/// returned plan records the mapping; `query.tables` is never mutated).
pub(crate) fn plan_relational(
    session: &Session,
    query: &Query,
    choice: &StrategyChoice,
) -> Result<(JoinPlan, LoweredQuery), JoinError> {
    // the relational lowering (predicate pushdown, GROUP BY composite
    // strata, kernel projections) is inner-join algebra throughout; the
    // parser already rejects non-inner + relational features, so this gate
    // only fires for programmatically-built queries over typed tables
    if !query.variant.is_inner() {
        return Err(JoinError::Unsupported {
            strategy: "relational".to_string(),
            reason: format!(
                "{} joins are not supported on the relational path \
                 (typed tables / predicates / GROUP BY); use plain datasets",
                query.variant.tag()
            ),
        });
    }
    let owned = wrap_datasets(session, query)?;
    let relations: Vec<&Relation> = query
        .tables
        .iter()
        .zip(&owned)
        .map(|(t, o)| match o {
            Some(r) => r,
            None => session.tables.get(t).expect("checked by wrap_datasets"),
        })
        .collect();
    let partitions = session.engine.cfg.workers.max(1) * 2;
    let mut lowered = lower(&LogicalPlan::from_query(query), &relations, partitions)?;

    // Join-order optimization over the *lowered* (post-pushdown) inputs:
    // predicate selectivity is already baked into their cardinalities, and
    // learned selectivities are tagged by the predicate mix. Reordering is
    // only sound when every aggregate's combine op is commutative.
    let commutative = lowered.ops.iter().all(|op| {
        matches!(
            op,
            crate::join::CombineOp::Sum | crate::join::CombineOp::Product
        )
    });
    let tag = predicate_tag(query);
    let ctx = crate::join::order::OrderContext {
        feedback: Some(&session.engine.feedback),
        predicate_tag: tag,
        beta_compute: session.engine.cost.beta_compute,
        workers: session.engine.cfg.workers,
        bandwidth: session.engine.cfg.time_model.bandwidth,
        enabled: session.engine.cfg.reorder_joins,
    };
    let tstats =
        crate::join::TableStats::collect(&lowered.per_aggregate[0], &query.tables);
    let order = crate::join::order::plan_query_order(
        &query.tables,
        &query.join_clauses,
        commutative,
        &tstats,
        &ctx,
    );
    if let Some(r) = &order {
        if r.reordered {
            for inputs in &mut lowered.per_aggregate {
                *inputs = crate::join::order::permute(inputs, &r.order);
            }
        }
    }

    let stats = InputStats::collect(
        &lowered.per_aggregate[0],
        session.engine.cfg.workers,
        &session.engine.cfg.time_model,
    );
    let plan = Planner::new(&session.registry, &session.engine.cost)
        .plan(&stats, choice, &query.budget)?
        .with_lowering(lowered.info.clone())
        .with_order(order);
    Ok((plan, lowered))
}

/// The engine's §3.2 exact-vs-sampled decision, replayed on the lowered
/// inputs with the *measured* filter+shuffle time d_dt. `n_aggregates`
/// kernel runs share the user's latency budget, so each run is sized to
/// an equal share — the query's total stays within `WITHIN D SECONDS`.
fn section32_mode(
    budget: &Budget,
    cost: &crate::cost::CostModel,
    d_dt: f64,
    total_pairs: f64,
    n_aggregates: usize,
) -> ExecutionMode {
    if let Some(d_desired) = budget.latency_secs {
        let share = d_desired / n_aggregates.max(1) as f64;
        let s = cost
            .fraction_for_latency(share, d_dt, total_pairs)
            .max(1e-6);
        if s >= 1.0 {
            return ExecutionMode::Exact;
        }
        return ExecutionMode::Sampled { fraction: s };
    }
    if budget.error.is_some() {
        return ExecutionMode::Sampled { fraction: f64::NAN };
    }
    ExecutionMode::Exact
}

/// One aggregate's kernel execution result.
struct AggRun {
    strata: HashMap<u64, StratumAgg>,
    draws: HashMap<u64, f64>,
    sampled: bool,
    metrics: crate::cluster::JoinMetrics,
    ledger: crate::cluster::ShuffleLedger,
    d_dt: f64,
    filter_report: Option<crate::bloom::FilterReport>,
    fault_report: Option<crate::faults::FaultReport>,
}

/// Execute the full relational query: one kernel run per aggregate
/// expression over identical stratum keys, then per-group assembly.
pub(crate) fn run_relational(
    session: &mut Session,
    query: &Query,
    choice: &StrategyChoice,
) -> anyhow::Result<QueryOutcome> {
    let (plan, lowered) = plan_relational(session, query, choice)?;
    let cfg = session.engine.cfg.clone();
    let sketches = session.engine.sketches.clone();
    let predicate_tag = predicate_tag(query);
    // per_aggregate inputs are already in execution order (plan_relational
    // permuted them when the optimizer reordered); cache keys and the
    // calibration loop use the executed table order
    let exec_tables: Vec<String> = plan
        .order
        .as_ref()
        .map(|r| r.tables.clone())
        .unwrap_or_else(|| query.tables.clone());
    let confidence = query
        .budget
        .error
        .map(|e| e.confidence)
        .unwrap_or(0.95);

    // the sampled §3.2 path re-decides per aggregate with measured d_dt
    let budgeted_approx = plan.approximate && !query.budget.is_unbounded();
    if !plan.approximate
        && !query.budget.is_unbounded()
        && matches!(choice, StrategyChoice::Named(_))
    {
        eprintln!(
            "warning: strategy {} is exact; the query's latency/error \
             budget is ignored",
            plan.strategy
        );
    }

    let mut runs: Vec<AggRun> = Vec::with_capacity(lowered.per_aggregate.len());
    for (ai, inputs) in lowered.per_aggregate.iter().enumerate() {
        let op = lowered.ops[ai];
        let agg_fp = format!(
            "{}#{}",
            query.fingerprint(),
            query.aggregates[ai].render()
        );
        let mut cluster = SimCluster::new(cfg.workers, cfg.time_model)
            .with_parallelism(cfg.parallelism)
            .with_faults(cfg.faults);
        let run = if budgeted_approx {
            // §3.2 on the lowered inputs: measure filtering, then decide.
            // This path runs the native prober/aggregator with eq-27
            // filter sizing; unlike the scalar engine path it does not
            // engage the pinned XLA artifact geometry (the engine owns
            // those executors privately) — native execution is the
            // always-available reference implementation.
            let filter_cfg =
                FilterConfig::for_inputs_kind(inputs, cfg.fp_rate, cfg.filter_kind);
            let mut prober = NativeProber;
            let (filtered, cache_hit) = match &sketches {
                Some(cache) => cache.filtered(
                    &mut cluster,
                    inputs,
                    &exec_tables,
                    &predicate_tag,
                    &query.aggregates[ai].render(),
                    query.variant,
                    filter_cfg,
                    &mut prober,
                )?,
                None => (
                    filter_and_shuffle(&mut cluster, inputs, filter_cfg, &mut prober)?,
                    crate::bloom::SketchCacheHit::None,
                ),
            };
            let d_dt = filtered.d_dt;
            let filter_report = filtered.join_filter.report().with_cache_hit(cache_hit);
            let total_pairs: f64 = filtered.total_pairs();
            let mode = section32_mode(
                &query.budget,
                &session.engine.cost,
                d_dt,
                total_pairs,
                lowered.per_aggregate.len(),
            );
            let (mut strata, mut draws, sampled) = match mode {
                ExecutionMode::Exact => {
                    let strata = cross_product_stage(&mut cluster, &filtered, op);
                    (strata, HashMap::new(), false)
                }
                ExecutionMode::Sampled { fraction } => {
                    let params = if fraction.is_nan() {
                        let err = query.budget.error.expect("error-driven plan needs budget");
                        SamplingParams::ErrorBound {
                            err_desired: err.bound,
                            confidence: err.confidence,
                            sigmas: session.engine.feedback.sigmas(&agg_fp),
                            default_sigma: session.engine.feedback.default_sigma(&agg_fp),
                        }
                    } else {
                        SamplingParams::Fraction(fraction)
                    };
                    let acfg = ApproxConfig {
                        params,
                        estimator: cfg.estimator,
                        seed: cfg.seed,
                    };
                    let mut agg = NativeAggregator::default();
                    let (strata, draws) =
                        sample_stage(&mut cluster, &filtered, op, &acfg, &mut agg)?;
                    (strata, draws, true)
                }
            };
            // degrade BEFORE estimation: drop unrecoverable strata,
            // re-weight survivors, widen the CI downstream
            let mut fault_report = cluster.take_fault_report();
            if let Some(rep) = fault_report.as_mut() {
                crate::faults::degrade_strata(
                    rep,
                    &mut strata,
                    &mut draws,
                    cfg.workers,
                    sampled,
                )?;
            }
            AggRun {
                strata,
                draws,
                sampled,
                metrics: cluster.take_metrics(),
                ledger: cluster.take_ledger(),
                d_dt,
                filter_report: Some(filter_report),
                fault_report,
            }
        } else {
            let strategy = session
                .registry
                .get(&plan.strategy)
                .expect("planned strategy is registered");
            let run = strategy.execute(&mut cluster, inputs, op)?;
            let d_dt = run.metrics.stage_secs("build_filter")
                + run.metrics.stage_secs("filter_shuffle");
            AggRun {
                strata: run.strata,
                draws: run.draws,
                sampled: run.sampled,
                metrics: run.metrics,
                ledger: run.ledger,
                d_dt,
                filter_report: run.filter_report,
                fault_report: run.fault_report,
            }
        };
        session.engine.feedback.record(&agg_fp, &run.strata);
        runs.push(run);
    }

    // ---- assemble: overall result from the first aggregate, per-group
    // estimates for every aggregate
    let mut grouped_aggs = Vec::with_capacity(runs.len());
    let mut overall = None;
    for (ai, run) in runs.iter().enumerate() {
        let estimator = if run.draws.is_empty() {
            EstimatorKind::Clt
        } else {
            EstimatorKind::HorvitzThompson
        };
        let func = query.aggregates[ai].func;
        let label = query.aggregates[ai].label();
        let total = estimate_result(
            func,
            run.sampled,
            estimator,
            &run.strata,
            &run.draws,
            confidence,
        );
        if ai == 0 {
            overall = Some(total);
        }
        grouped_aggs.push(match &lowered.groups {
            Some(dict) => assemble_grouped(
                dict,
                label,
                func,
                run.sampled,
                estimator,
                &run.strata,
                &run.draws,
                confidence,
            ),
            None => assemble_ungrouped(label, func, total, &run.strata),
        });
    }

    // ---- merge accounting: one aggregate keeps raw stage names; several
    // get an `agg{i}/` prefix so attribution survives the merge
    let multi = runs.len() > 1;
    let mut metrics = crate::cluster::JoinMetrics::default();
    let mut ledger = crate::cluster::ShuffleLedger::default();
    for (ai, run) in runs.iter().enumerate() {
        if multi {
            let mut m = run.metrics.clone();
            for s in &mut m.stages {
                s.name = format!("agg{ai}/{}", s.name);
            }
            metrics.merge(m);
            ledger.merge(run.ledger.tagged(&format!("agg{ai}")));
        } else {
            metrics.merge(run.metrics.clone());
            ledger.merge(run.ledger.clone());
        }
    }

    // one report per query: per-aggregate fault reports merge (counters
    // add, dead-worker sets union) so callers see the combined damage
    let mut fault_report: Option<crate::faults::FaultReport> = None;
    for run in &runs {
        if let Some(rep) = &run.fault_report {
            match fault_report.as_mut() {
                Some(acc) => acc.merge(rep),
                None => fault_report = Some(rep.clone()),
            }
        }
    }

    let first = &runs[0];
    let output_cardinality: f64 = first.strata.values().map(|s| s.population).sum();
    let sampled_count: f64 = first.strata.values().map(|s| s.count).sum();
    let mode = if first.sampled {
        ExecutionMode::Sampled {
            fraction: if output_cardinality > 0.0 {
                sampled_count / output_cardinality
            } else {
                1.0
            },
        }
    } else {
        ExecutionMode::Exact
    };
    let result = overall.expect("at least one aggregate");

    // close the calibration loop: record measured per-pair selectivities
    // and the predicted→measured byte ratio under this predicate tag
    let mut join_order = plan.order.clone();
    if let Some(r) = join_order.as_mut() {
        r.set_measured(&crate::join::order::measure_step_cardinalities(
            &lowered.per_aggregate[0],
        ));
        crate::join::order::calibrate(
            &mut session.engine.feedback,
            &predicate_tag,
            &exec_tables,
            &lowered.per_aggregate[0],
            r.cost.shuffle_bytes,
            ledger.total_bytes() as f64,
        );
    }

    Ok(QueryOutcome {
        sim_secs: metrics.total_sim_secs(),
        d_dt: first.d_dt,
        result,
        mode,
        output_cardinality,
        metrics,
        strategy: plan.strategy.clone(),
        plan: Some(
            plan.with_order(join_order.clone())
                .with_measured_shuffle(ledger.total_bytes())
                .with_filter_report(first.filter_report),
        ),
        ledger,
        grouped: Some(GroupedApproxResult {
            group_column: lowered.groups.as_ref().map(|d| d.column.clone()),
            aggregates: grouped_aggs,
        }),
        filter_report: first.filter_report,
        join_order,
        fault_report,
    })
}
