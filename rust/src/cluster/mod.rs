//! Simulated Spark-like cluster substrate.
//!
//! `SimCluster` hosts `k` logical workers. Join strategies execute their
//! real work through [`Stage`] handles: every task's CPU time is *measured*
//! on this host and every byte crossing the (simulated) network is
//! *counted*; the [`TimeModel`] then translates (max-over-workers compute,
//! most-loaded-node bytes) into cluster seconds. See DESIGN.md §3 for why
//! this substitution preserves the paper's relative claims.

pub mod metrics;
pub mod shuffle;
pub mod time_model;
pub mod tree_reduce;

pub use metrics::{JoinMetrics, ShuffleLedger, StageMetrics, StageTraffic};
pub use time_model::TimeModel;

use crate::faults::{FaultPlan, FaultReport, FaultState};
use crate::runtime::parallel::ParallelExecutor;
use std::time::Instant;

/// A simulated cluster of `k` workers.
///
/// `k` is the *accounting* model (how shuffle traffic and per-worker
/// compute are attributed); `exec` is the *execution* model (how many OS
/// threads actually run the per-worker tasks on this host). The two are
/// independent: join results and the shuffle ledger are bit-identical for
/// any thread count. Per-worker compute *seconds* are wall-clock measured,
/// though, so simulated-latency readings are cleanest at parallelism 1
/// (concurrent threads contend for cores); the figure benches use the
/// sequential executor for exactly that reason.
#[derive(Clone, Debug)]
pub struct SimCluster {
    pub k: usize,
    pub time_model: TimeModel,
    pub metrics: JoinMetrics,
    /// Measured per-stage / per-worker shuffle traffic of the current run.
    pub ledger: ShuffleLedger,
    /// Partition-parallel executor the strategies run their loops through.
    pub exec: ParallelExecutor,
    /// Deterministic fault injection + recovery state (None: perfect
    /// cluster, the default — bit-identical to pre-fault behaviour).
    faults: Option<FaultState>,
}

impl SimCluster {
    /// A sequential cluster (one execution thread) — the reference path.
    pub fn new(k: usize, time_model: TimeModel) -> Self {
        assert!(k >= 1);
        Self {
            k,
            time_model,
            metrics: JoinMetrics::default(),
            ledger: ShuffleLedger::default(),
            exec: ParallelExecutor::sequential(),
            faults: None,
        }
    }

    /// Run the per-worker task loops on up to `threads` OS threads.
    pub fn with_parallelism(mut self, threads: usize) -> Self {
        self.exec = ParallelExecutor::new(threads);
        self
    }

    /// Inject a deterministic [`FaultPlan`] into every recorded stage.
    /// `None` (and a zero plan) leave every run bit-identical to a
    /// fault-free cluster.
    pub fn with_faults(mut self, plan: Option<FaultPlan>) -> Self {
        self.faults = plan.map(FaultState::new);
        self
    }

    /// The injected plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref().map(|f| f.plan())
    }

    /// Detach the finished run's [`FaultReport`] and reset the fault state
    /// for the next run; `None` when no plan is injected.
    pub fn take_fault_report(&mut self) -> Option<FaultReport> {
        self.faults.as_mut().map(|f| f.take_report())
    }

    /// Begin a named stage. Finish it with [`Stage::finish`] to record
    /// metrics and obtain the simulated stage time.
    pub fn stage(&mut self, name: &str) -> Stage {
        Stage {
            name: name.to_string(),
            k: self.k,
            compute: vec![0.0; self.k],
            bytes_in: vec![0; self.k],
            bytes_out: vec![0; self.k],
            shuffled: 0,
            items: 0,
            wall: 0.0,
        }
    }

    /// Record a finished stage; returns its simulated seconds (including
    /// any priced fault-recovery time). The injected fault plan, if any,
    /// is consulted here — the one chokepoint every strategy's stages
    /// pass through — and recovery appends *additive* `recovery/{stage}`
    /// ledger/metrics rows after the untouched primary rows, so a
    /// zero-fault plan stays bit-identical.
    pub fn record(&mut self, stage: Stage) -> f64 {
        let per_worker_bytes: Vec<u64> = (0..self.k)
            .map(|w| stage.bytes_in[w] + stage.bytes_out[w])
            .collect();
        let mut sim = self
            .time_model
            .stage_secs(&stage.compute, &per_worker_bytes);
        let recovery = self.faults.as_mut().and_then(|f| {
            f.inject(
                &stage.name,
                &stage.compute,
                &stage.bytes_in,
                &stage.bytes_out,
                &self.time_model,
            )
        });
        self.ledger.push(StageTraffic {
            stage: stage.name.clone(),
            bytes_in: stage.bytes_in,
            bytes_out: stage.bytes_out,
        });
        self.metrics.push(StageMetrics {
            name: stage.name,
            sim_secs: sim,
            wall_secs: stage.wall,
            shuffled_bytes: stage.shuffled,
            items: stage.items,
        });
        if let Some(rec) = recovery {
            sim += rec.extra_secs;
            self.ledger.push(rec.traffic);
            self.metrics.push(rec.metrics);
        }
        sim
    }

    /// Reset metrics between runs (the cluster itself is stateless).
    pub fn take_metrics(&mut self) -> JoinMetrics {
        std::mem::take(&mut self.metrics)
    }

    /// Detach the measured shuffle ledger of the finished run.
    pub fn take_ledger(&mut self) -> ShuffleLedger {
        std::mem::take(&mut self.ledger)
    }

    /// The worker that owns partition `j` (partitions are striped).
    pub fn worker_of_partition(&self, partition: usize) -> usize {
        partition % self.k
    }
}

/// An in-flight stage: accumulates per-worker compute time and network
/// traffic until `finish`ed.
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    k: usize,
    compute: Vec<f64>,
    bytes_in: Vec<u64>,
    bytes_out: Vec<u64>,
    shuffled: u64,
    items: u64,
    wall: f64,
}

impl Stage {
    /// Run a task attributed to `worker`, measuring its CPU time.
    pub fn task<T>(&mut self, worker: usize, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        self.compute[worker % self.k] += dt;
        self.wall += dt;
        out
    }

    /// Attribute already-measured compute seconds to a worker (for work
    /// measured in bulk and apportioned by item count).
    pub fn add_compute(&mut self, worker: usize, secs: f64) {
        self.compute[worker % self.k] += secs;
        self.wall += secs;
    }

    /// Account a point-to-point transfer. Same-worker transfers are free
    /// (local disk/memory), matching how Spark counts shuffled bytes.
    pub fn transfer(&mut self, src: usize, dst: usize, bytes: u64) {
        let (src, dst) = (src % self.k, dst % self.k);
        if src == dst {
            return;
        }
        self.bytes_out[src] += bytes;
        self.bytes_in[dst] += bytes;
        self.shuffled += bytes;
    }

    /// Account a broadcast of `bytes` from `src` to every other worker.
    pub fn broadcast(&mut self, src: usize, bytes: u64) {
        for w in 0..self.k {
            if w != src % self.k {
                self.transfer(src, w, bytes);
            }
        }
    }

    /// Count processed work items (records, pairs) for the metrics row.
    pub fn add_items(&mut self, n: u64) {
        self.items += n;
    }

    pub fn shuffled_bytes(&self) -> u64 {
        self.shuffled
    }

    /// Finish the stage on its cluster, recording metrics.
    pub fn finish(self, cluster: &mut SimCluster) -> f64 {
        cluster.record(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tm0() -> TimeModel {
        TimeModel {
            bandwidth: 1000.0,
            stage_latency: 0.0,
            compute_scale: 1.0,
        }
    }

    #[test]
    fn stage_accounts_transfers() {
        let mut c = SimCluster::new(4, tm0());
        let mut s = c.stage("shuffle");
        s.transfer(0, 1, 500);
        s.transfer(1, 1, 999); // local: free
        s.transfer(2, 3, 250);
        assert_eq!(s.shuffled_bytes(), 750);
        let sim = s.finish(&mut c);
        // most loaded node: worker 1 (500 in) or worker 0 (500 out) -> 0.5s
        assert!((sim - 0.5).abs() < 1e-9, "sim={sim}");
        assert_eq!(c.metrics.total_shuffled_bytes(), 750);
    }

    #[test]
    fn broadcast_counts_k_minus_1() {
        let mut c = SimCluster::new(5, tm0());
        let mut s = c.stage("bcast");
        s.broadcast(0, 100);
        assert_eq!(s.shuffled_bytes(), 400);
        s.finish(&mut c);
    }

    #[test]
    fn tasks_measure_time() {
        let mut c = SimCluster::new(2, tm0());
        let mut s = c.stage("work");
        let v = s.task(0, || {
            let mut acc = 0u64;
            for i in 0..100_000 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(v > 0);
        let sim = s.finish(&mut c);
        assert!(sim > 0.0);
        assert!(c.metrics.total_wall_secs() > 0.0);
    }

    #[test]
    fn worker_striping() {
        let c = SimCluster::new(3, tm0());
        assert_eq!(c.worker_of_partition(0), 0);
        assert_eq!(c.worker_of_partition(4), 1);
        assert_eq!(c.worker_of_partition(5), 2);
    }

    #[test]
    fn take_metrics_resets() {
        let mut c = SimCluster::new(2, tm0());
        c.stage("a").finish(&mut c);
        let m = c.take_metrics();
        assert_eq!(m.stages.len(), 1);
        assert_eq!(c.metrics.stages.len(), 0);
    }

    #[test]
    fn ledger_mirrors_stage_traffic() {
        let mut c = SimCluster::new(4, tm0());
        let mut s = c.stage("shuffle");
        s.transfer(0, 1, 500);
        s.transfer(2, 3, 250);
        s.finish(&mut c);
        c.stage("local").finish(&mut c);
        assert_eq!(c.ledger.total_bytes(), 750);
        assert_eq!(c.ledger.stage_bytes("shuffle"), 750);
        assert_eq!(c.ledger.stages[0].bytes_out, vec![500, 0, 250, 0]);
        assert_eq!(c.ledger.stages[0].bytes_in, vec![0, 500, 0, 250]);
        // ledger totals always agree with the metrics' shuffled bytes
        assert_eq!(c.ledger.total_bytes(), c.metrics.total_shuffled_bytes());
        let l = c.take_ledger();
        assert_eq!(l.stages.len(), 2);
        assert!(c.ledger.stages.is_empty());
    }

    #[test]
    fn zero_fault_plan_is_bit_identical_to_no_plan() {
        let run = |faults: Option<FaultPlan>| {
            let mut c = SimCluster::new(4, tm0()).with_faults(faults);
            let mut s = c.stage("shuffle");
            s.transfer(0, 1, 500);
            s.transfer(2, 3, 250);
            s.finish(&mut c);
            c.stage("sample").finish(&mut c);
            (c.take_ledger(), c.metrics.total_shuffled_bytes())
        };
        let baseline = run(None);
        let zero = run(Some(FaultPlan::default()));
        assert_eq!(baseline, zero);
    }

    #[test]
    fn faulted_stage_appends_additive_recovery_rows() {
        let plan = FaultPlan {
            lost_prob: 1.0,
            ..FaultPlan::default()
        };
        let mut c = SimCluster::new(4, tm0()).with_faults(Some(plan));
        let mut s = c.stage("shuffle");
        s.transfer(0, 1, 500);
        s.transfer(2, 3, 250);
        let sim = s.finish(&mut c);
        // primary rows untouched, one recovery row appended after them
        assert_eq!(c.ledger.stages[0].stage, "shuffle");
        assert_eq!(c.ledger.stage_bytes("shuffle"), 750);
        assert_eq!(c.ledger.stages[1].stage, "recovery/shuffle");
        assert!(c.ledger.stage_bytes("recovery/shuffle") > 0);
        // ledger and metrics shuffled bytes stay in lockstep
        assert_eq!(c.ledger.total_bytes(), c.metrics.total_shuffled_bytes());
        // the returned stage time includes the priced recovery seconds
        assert!(sim > 0.75, "sim={sim} must include recovery time");
        let report = c.take_fault_report().expect("plan injected");
        assert!(report.any_injected());
        assert_eq!(report.retry_bytes, c.ledger.stage_bytes("recovery/shuffle"));
        assert!(report.extra_sim_secs > 0.0);
        // the report harvest resets state for the next run
        let fresh = c.take_fault_report().expect("plan persists");
        assert!(!fresh.any_injected());
    }

    #[test]
    fn parallelism_is_a_pure_throughput_knob() {
        let c = SimCluster::new(4, tm0()).with_parallelism(8);
        assert_eq!(c.exec.threads(), 8);
        assert_eq!(c.k, 4);
        assert!(SimCluster::new(4, tm0()).exec.is_sequential());
    }
}
