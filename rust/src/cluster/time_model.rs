//! The calibrated cluster-time model — the documented substitution for the
//! paper's physical 10-node testbed (DESIGN.md §3).
//!
//! Everything a join *does* (records probed, pairs crossed, bytes shuffled)
//! is executed/accounted exactly on this host; only the translation into
//! cluster seconds is modeled:
//!
//!   stage_time = max_w(compute_w) / compute_scale
//!             + max_w(bytes_in_w + bytes_out_w) / bandwidth
//!             + stage_latency
//!
//! `compute_w` is *measured* CPU time of worker w's task on this host, so
//! relative algorithmic costs (the paper's claims) carry through; the
//! parallelism max() is over logical workers; the network term uses the
//! most-loaded node (GbE is full-duplex per-port, so in+out is slightly
//! pessimistic, matching the paper's saturated-shuffle behaviour).

/// Parameters of the simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct TimeModel {
    /// Per-node network bandwidth (bytes/sec). Default: 1 GbE = 117 MiB/s.
    pub bandwidth: f64,
    /// Fixed per-stage scheduling/setup latency (Spark task launch, ~s).
    pub stage_latency: f64,
    /// Relative compute speed of one cluster node vs this host (the
    /// paper's 2008-era Xeon E5405 cores are slower than this host; <1
    /// slows simulated compute down).
    pub compute_scale: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        Self {
            bandwidth: 117.0 * 1024.0 * 1024.0,
            stage_latency: 0.5,
            compute_scale: 1.0,
        }
    }
}

impl TimeModel {
    /// Calibrated to the paper's testbed: 10 nodes of 2×4-core Xeon E5405
    /// (2007, ~1/20 the per-core throughput of this host), GbE, SATA HDDs,
    /// Spark ~1.x task-launch overhead ~100ms per stage. The figure benches
    /// use this so executed workloads produce paper-shaped latencies.
    pub fn paper_cluster() -> Self {
        Self {
            bandwidth: 117.0 * 1024.0 * 1024.0,
            stage_latency: 0.1,
            compute_scale: 0.05,
        }
    }

    /// Simulated seconds for a stage given per-worker measured compute
    /// seconds and per-worker network bytes (in + out).
    pub fn stage_secs(&self, per_worker_compute: &[f64], per_worker_bytes: &[u64]) -> f64 {
        let compute = per_worker_compute.iter().cloned().fold(0.0, f64::max);
        let bytes = per_worker_bytes.iter().cloned().max().unwrap_or(0);
        compute / self.compute_scale + bytes as f64 / self.bandwidth + self.stage_latency
    }

    /// Seconds to move `bytes` across one link — the unit the fault
    /// recovery layer prices retransmits and lineage re-fetches in.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bandwidth
    }

    /// Simulated seconds for a broadcast of `bytes` from one node to k-1
    /// others (tree topology: ceil(log2 k) rounds of full-bandwidth sends).
    pub fn broadcast_secs(&self, bytes: u64, k: usize) -> f64 {
        if k <= 1 {
            return self.stage_latency;
        }
        let rounds = (k as f64).log2().ceil();
        rounds * bytes as f64 / self.bandwidth + self.stage_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_is_max_over_workers() {
        let tm = TimeModel {
            bandwidth: 1e9,
            stage_latency: 0.0,
            compute_scale: 1.0,
        };
        let t = tm.stage_secs(&[1.0, 5.0, 2.0], &[0, 0, 0]);
        assert!((t - 5.0).abs() < 1e-9);
    }

    #[test]
    fn network_term_uses_most_loaded_node() {
        let tm = TimeModel {
            bandwidth: 100.0,
            stage_latency: 0.0,
            compute_scale: 1.0,
        };
        let t = tm.stage_secs(&[0.0], &[50, 200, 100]);
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn compute_scale_slows_down() {
        let fast = TimeModel {
            compute_scale: 1.0,
            stage_latency: 0.0,
            bandwidth: 1e12,
        };
        let slow = TimeModel {
            compute_scale: 0.25,
            ..fast
        };
        assert!(slow.stage_secs(&[1.0], &[0]) > fast.stage_secs(&[1.0], &[0]));
    }

    #[test]
    fn broadcast_scales_logarithmically() {
        let tm = TimeModel {
            bandwidth: 1000.0,
            stage_latency: 0.0,
            compute_scale: 1.0,
        };
        let t2 = tm.broadcast_secs(1000, 2);
        let t8 = tm.broadcast_secs(1000, 8);
        assert!((t2 - 1.0).abs() < 1e-9);
        assert!((t8 - 3.0).abs() < 1e-9);
        assert_eq!(tm.broadcast_secs(1000, 1), 0.0);
    }
}
