//! treeReduce merge of per-partition Bloom filters (paper §4 I): merging
//! all partition filters at the driver makes it a bottleneck, so reducers
//! are arranged in a binary tree — each round halves the live workers and
//! ships one filter per merge; the root holds the dataset filter. Adding
//! workers adds tree *levels*, keeping the driver load flat.

use super::{SimCluster, Stage};
use crate::bloom::{BlockedBloomFilter, BloomFilter, JoinFilter};
use crate::join::bloom_join::FilterConfig;

/// Anything the reduction tree can ship: it only needs the payload's wire
/// size to account each merge's transfer. Implemented for every filter
/// shape the kernel merges (standard, blocked, and the kind-dispatched
/// [`JoinFilter`]).
pub trait MergePayload {
    fn payload_bytes(&self) -> u64;
}

impl MergePayload for BloomFilter {
    fn payload_bytes(&self) -> u64 {
        self.size_bytes()
    }
}

impl MergePayload for BlockedBloomFilter {
    fn payload_bytes(&self) -> u64 {
        self.size_bytes()
    }
}

impl MergePayload for JoinFilter {
    fn payload_bytes(&self) -> u64 {
        self.size_bytes()
    }
}

/// Merge one filter per worker into a single filter at worker 0 via a
/// binary reduction tree, accounting one filter-sized transfer per merge.
/// `op` is the merge (union for partition→dataset, intersection never goes
/// through the tree — it happens once at the master over n dataset filters).
pub fn tree_reduce<F: MergePayload>(
    stage: &mut Stage,
    mut filters: Vec<(usize, F)>,
    op: impl Fn(&mut F, &F),
) -> Option<F> {
    while filters.len() > 1 {
        let mut next = Vec::with_capacity(filters.len().div_ceil(2));
        let mut it = filters.into_iter();
        while let Some((w_dst, mut acc)) = it.next() {
            if let Some((w_src, other)) = it.next() {
                stage.transfer(w_src, w_dst, other.payload_bytes());
                stage.task(w_dst, || op(&mut acc, &other));
            }
            next.push((w_dst, acc));
        }
        filters = next;
    }
    // the loop only exits at length 0 (empty input: every round preserves
    // non-emptiness) or exactly 1 — pop() is the root, never a panic
    filters.pop().map(|(_, f)| f)
}

/// Build the dataset filter for one input (Alg 1 buildInputFilter): map
/// phase builds one partition filter per worker-resident partition chunk —
/// the per-worker Bloom *shards* run data-parallel through the cluster's
/// executor — and the reduce phase tree-merges the shards with OR,
/// accounting one filter-sized transfer per merge as before. Bit insertion
/// is idempotent, so the shard contents are identical for any thread count.
pub fn build_dataset_filter(
    cluster: &SimCluster,
    stage: &mut Stage,
    dataset: &crate::data::Dataset,
    log2_bits: u32,
    num_hashes: u32,
) -> BloomFilter {
    let cfg = FilterConfig {
        log2_bits,
        num_hashes,
        kind: crate::bloom::FilterKind::Standard,
    };
    match build_dataset_join_filter(cluster, stage, dataset, cfg) {
        JoinFilter::Standard(f) => f,
        // invariant, not a runtime condition: `build_dataset_join_filter`
        // constructs every shard and the empty-dataset fallback from
        // `cfg.kind` (Standard here), so a Blocked variant can only mean a
        // bug in that function — covered by the degenerate-input tests
        JoinFilter::Blocked(_) => unreachable!("standard kind requested"),
    }
}

/// Kind-dispatched [`build_dataset_filter`]: the same map-shards +
/// tree-reduce construction, building filters of the configured
/// [`crate::bloom::FilterKind`]. Shuffle accounting is identical — both
/// kinds ship `size_bytes()` per merge.
pub fn build_dataset_join_filter(
    cluster: &SimCluster,
    stage: &mut Stage,
    dataset: &crate::data::Dataset,
    cfg: FilterConfig,
) -> JoinFilter {
    // map: one shard per worker, built from its striped partitions
    let k = cluster.k;
    let shards: Vec<(Option<JoinFilter>, f64)> = cluster.exec.map(k, |w| {
        let t0 = std::time::Instant::now();
        let mut f: Option<JoinFilter> = None;
        for part in dataset.partitions.iter().skip(w).step_by(k) {
            let f = f
                .get_or_insert_with(|| JoinFilter::new(cfg.kind, cfg.log2_bits, cfg.num_hashes));
            for r in part {
                f.insert_key64(r.key);
            }
        }
        (f, t0.elapsed().as_secs_f64())
    });
    let mut filters: Vec<(usize, JoinFilter)> = Vec::with_capacity(k);
    for (w, (f, secs)) in shards.into_iter().enumerate() {
        stage.add_compute(w, secs);
        if let Some(f) = f {
            filters.push((w, f));
        }
    }
    stage.add_items(dataset.len());
    tree_reduce(stage, filters, |a, b| a.union_with(b))
        .unwrap_or_else(|| JoinFilter::new(cfg.kind, cfg.log2_bits, cfg.num_hashes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::{Dataset, Record};

    fn cluster(k: usize) -> SimCluster {
        SimCluster::new(
            k,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    #[test]
    fn tree_reduce_merges_all() {
        let mut c = cluster(8);
        let mut s = c.stage("reduce");
        let filters: Vec<(usize, BloomFilter)> = (0..8)
            .map(|w| {
                let mut f = BloomFilter::new(14, 4);
                f.insert(w as u32 * 100);
                (w, f)
            })
            .collect();
        let merged = tree_reduce(&mut s, filters, |a, b| a.union_with(b)).unwrap();
        for w in 0..8u32 {
            assert!(merged.contains(w * 100));
        }
        // 7 merges x filter size bytes
        let f = BloomFilter::new(14, 4);
        assert_eq!(s.shuffled_bytes(), 7 * f.size_bytes());
        s.finish(&mut c);
    }

    #[test]
    fn tree_reduce_empty_and_single() {
        let mut c = cluster(4);
        let mut s = c.stage("reduce");
        assert!(tree_reduce(&mut s, vec![], |a: &mut BloomFilter, b| a.union_with(b)).is_none());
        let mut f = BloomFilter::new(10, 3);
        f.insert(7);
        let out = tree_reduce(&mut s, vec![(2, f)], |a, b| a.union_with(b)).unwrap();
        assert!(out.contains(7));
        assert_eq!(s.shuffled_bytes(), 0);
    }

    #[test]
    fn dataset_filter_covers_all_keys() {
        let mut c = cluster(4);
        let d = Dataset::from_records(
            "t",
            (0..5000u64).map(|k| Record::new(k, 1.0)).collect(),
            8,
            10,
        );
        let mut s = c.stage("build");
        let f = build_dataset_filter(&c, &mut s, &d, 17, 5);
        s.finish(&mut c);
        assert!((0..5000u64).all(|k| f.contains_key64(k)));
    }

    #[test]
    fn blocked_dataset_filter_covers_all_keys_same_accounting() {
        use crate::bloom::FilterKind;
        let d = Dataset::from_records(
            "t",
            (0..5000u64).map(|k| Record::new(k, 1.0)).collect(),
            8,
            10,
        );
        let mut run = |kind: FilterKind| {
            let mut c = cluster(4);
            let mut s = c.stage("build");
            let f = build_dataset_join_filter(
                &c,
                &mut s,
                &d,
                FilterConfig {
                    log2_bits: 17,
                    num_hashes: 5,
                    kind,
                },
            );
            let bytes = s.shuffled_bytes();
            s.finish(&mut c);
            (f, bytes)
        };
        let (std_f, std_bytes) = run(FilterKind::Standard);
        let (blk_f, blk_bytes) = run(FilterKind::Blocked);
        assert!((0..5000u64).all(|k| std_f.contains_key64(k)));
        assert!((0..5000u64).all(|k| blk_f.contains_key64(k)));
        // equal geometry ⇒ equal tree-reduce traffic for either kind
        assert_eq!(std_bytes, blk_bytes);
    }

    #[test]
    fn empty_dataset_builds_empty_filter_without_panicking() {
        // zero records → zero shards → tree_reduce(None) → the fallback
        // empty filter; the empty-filter edge must not unwrap its way into
        // a panic on any cluster size, including the k=1 degenerate
        for k in [1usize, 4] {
            let mut c = cluster(k);
            let d = Dataset::from_records("empty", Vec::new(), 4, 10);
            let mut s = c.stage("build");
            let f = build_dataset_filter(&c, &mut s, &d, 12, 3);
            assert_eq!(s.shuffled_bytes(), 0);
            s.finish(&mut c);
            assert!(!f.contains_key64(1));
        }
    }

    #[test]
    fn single_worker_cluster_reduces_locally() {
        // k=1: every shard lives on worker 0, the tree has no transfers
        let mut c = cluster(1);
        let d = Dataset::from_records(
            "t",
            (0..100u64).map(|k| Record::new(k, 1.0)).collect(),
            4,
            10,
        );
        let mut s = c.stage("build");
        let f = build_dataset_filter(&c, &mut s, &d, 12, 3);
        assert_eq!(s.shuffled_bytes(), 0);
        s.finish(&mut c);
        assert!((0..100u64).all(|k| f.contains_key64(k)));
    }

    #[test]
    fn transfers_scale_logarithmically_per_round() {
        // with k workers the tree does k-1 merges total but ceil(log2 k)
        // rounds; per-worker byte load stays ~1-2 filters regardless of k
        let mut c = cluster(16);
        let mut s = c.stage("reduce");
        let filters: Vec<(usize, BloomFilter)> =
            (0..16).map(|w| (w, BloomFilter::new(12, 3))).collect();
        tree_reduce(&mut s, filters, |a, b| a.union_with(b));
        let fsize = BloomFilter::new(12, 3).size_bytes();
        assert_eq!(s.shuffled_bytes(), 15 * fsize);
        s.finish(&mut c);
    }
}
