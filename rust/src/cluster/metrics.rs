//! Per-stage metrics: the latency breakdowns (Figure 8's build-filter /
//! shuffle / cross-product bars) and the shuffled-byte counters (Figures 4,
//! 9b, 13a) every experiment reports.

/// One named execution stage of a join.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    pub name: String,
    /// Simulated cluster time for the stage (see `TimeModel`): parallel
    /// compute = max over workers, plus modeled network transfer time.
    pub sim_secs: f64,
    /// Real single-host wall time spent executing the stage's work.
    pub wall_secs: f64,
    /// Bytes crossing the network in this stage.
    pub shuffled_bytes: u64,
    /// Work items processed (records filtered, pairs crossed, ...).
    pub items: u64,
}

/// Metrics for a whole join execution.
#[derive(Clone, Debug, Default)]
pub struct JoinMetrics {
    pub stages: Vec<StageMetrics>,
}

impl JoinMetrics {
    pub fn push(&mut self, s: StageMetrics) {
        self.stages.push(s);
    }

    pub fn total_sim_secs(&self) -> f64 {
        self.stages.iter().map(|s| s.sim_secs).sum()
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.stages.iter().map(|s| s.wall_secs).sum()
    }

    pub fn total_shuffled_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffled_bytes).sum()
    }

    pub fn stage(&self, name: &str) -> Option<&StageMetrics> {
        self.stages.iter().find(|s| s.name == name)
    }

    /// Seconds attributed to a stage (0 if absent) — for breakdown tables.
    pub fn stage_secs(&self, name: &str) -> f64 {
        self.stage(name).map(|s| s.sim_secs).unwrap_or(0.0)
    }

    pub fn merge(&mut self, other: JoinMetrics) {
        self.stages.extend(other.stages);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut m = JoinMetrics::default();
        m.push(StageMetrics {
            name: "filter".into(),
            sim_secs: 1.0,
            wall_secs: 0.5,
            shuffled_bytes: 100,
            items: 10,
        });
        m.push(StageMetrics {
            name: "crossproduct".into(),
            sim_secs: 2.0,
            wall_secs: 1.0,
            shuffled_bytes: 50,
            items: 20,
        });
        assert_eq!(m.total_sim_secs(), 3.0);
        assert_eq!(m.total_wall_secs(), 1.5);
        assert_eq!(m.total_shuffled_bytes(), 150);
        assert_eq!(m.stage_secs("filter"), 1.0);
        assert_eq!(m.stage_secs("missing"), 0.0);
    }

    #[test]
    fn merge_concatenates() {
        let mut a = JoinMetrics::default();
        a.push(StageMetrics {
            name: "x".into(),
            ..Default::default()
        });
        let mut b = JoinMetrics::default();
        b.push(StageMetrics {
            name: "y".into(),
            ..Default::default()
        });
        a.merge(b);
        assert_eq!(a.stages.len(), 2);
    }
}
