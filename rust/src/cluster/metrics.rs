//! Per-stage metrics: the latency breakdowns (Figure 8's build-filter /
//! shuffle / cross-product bars) and the shuffled-byte counters (Figures 4,
//! 9b, 13a) every experiment reports — plus the [`ShuffleLedger`], the
//! per-stage / per-worker record of *measured* bytes in and out that the
//! planner's shuffle predictions are checked against.

/// One named execution stage of a join.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    pub name: String,
    /// Simulated cluster time for the stage (see `TimeModel`): parallel
    /// compute = max over workers, plus modeled network transfer time.
    pub sim_secs: f64,
    /// Real single-host wall time spent executing the stage's work.
    pub wall_secs: f64,
    /// Bytes crossing the network in this stage.
    pub shuffled_bytes: u64,
    /// Work items processed (records filtered, pairs crossed, ...).
    pub items: u64,
}

/// Metrics for a whole join execution.
#[derive(Clone, Debug, Default)]
pub struct JoinMetrics {
    pub stages: Vec<StageMetrics>,
}

impl JoinMetrics {
    pub fn push(&mut self, s: StageMetrics) {
        self.stages.push(s);
    }

    pub fn total_sim_secs(&self) -> f64 {
        self.stages.iter().map(|s| s.sim_secs).sum()
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.stages.iter().map(|s| s.wall_secs).sum()
    }

    pub fn total_shuffled_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffled_bytes).sum()
    }

    pub fn stage(&self, name: &str) -> Option<&StageMetrics> {
        self.stages.iter().find(|s| s.name == name)
    }

    /// Seconds attributed to a stage (0 if absent) — for breakdown tables.
    pub fn stage_secs(&self, name: &str) -> f64 {
        self.stage(name).map(|s| s.sim_secs).unwrap_or(0.0)
    }

    pub fn merge(&mut self, other: JoinMetrics) {
        self.stages.extend(other.stages);
    }
}

/// Measured network traffic of one stage, per logical worker (partitions
/// are striped onto workers, partition j → worker j mod k).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageTraffic {
    pub stage: String,
    /// Bytes received by each worker in this stage.
    pub bytes_in: Vec<u64>,
    /// Bytes sent by each worker in this stage.
    pub bytes_out: Vec<u64>,
}

impl StageTraffic {
    /// Total bytes that crossed the network in this stage (Σ out == Σ in).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_out.iter().sum()
    }

    /// in + out of the most-loaded worker — the stage's network bottleneck.
    /// A stage with no workers (empty vectors) has no bottleneck: 0, not a
    /// panic — the `max()` edge is absorbed, never unwrapped.
    pub fn max_worker_bytes(&self) -> u64 {
        self.bytes_in
            .iter()
            .zip(&self.bytes_out)
            .map(|(&i, &o)| i + o)
            .max()
            .unwrap_or(0)
    }
}

/// The measured shuffle ledger of a join execution: per stage, per worker,
/// how many bytes actually moved. The analytic cost model *predicts*
/// shuffle volume; the ledger is what the shuffle fabric *counted* —
/// `JoinPlan::explain` renders the two side by side, and the Fig 8/9b
/// shuffle-reduction claims are asserted against the ledger in tests.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShuffleLedger {
    pub stages: Vec<StageTraffic>,
}

impl ShuffleLedger {
    pub fn push(&mut self, t: StageTraffic) {
        self.stages.push(t);
    }

    /// Total measured bytes across all stages.
    pub fn total_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.total_bytes()).sum()
    }

    /// Measured bytes of one named stage (0 if absent).
    pub fn stage_bytes(&self, name: &str) -> u64 {
        self.stages
            .iter()
            .filter(|s| s.stage == name)
            .map(|s| s.total_bytes())
            .sum()
    }

    /// Ratio of the most-loaded worker's traffic to the per-worker mean,
    /// over the whole run — 1.0 means perfectly balanced partitions.
    ///
    /// Degenerate edges all answer 1.0 (perfectly balanced) instead of
    /// panicking or dividing by zero: an empty ledger, stages with no
    /// workers, and runs that moved zero bytes. Stages with ragged
    /// per-worker vectors (shorter than the run's widest stage) only
    /// contribute the workers they report — zip truncation, no indexing.
    pub fn skew(&self) -> f64 {
        let k = self
            .stages
            .iter()
            .map(|s| s.bytes_in.len())
            .max()
            .unwrap_or(0);
        if k == 0 {
            return 1.0;
        }
        let mut per_worker = vec![0u64; k];
        for s in &self.stages {
            for (w, (&bi, &bo)) in s.bytes_in.iter().zip(&s.bytes_out).enumerate() {
                per_worker[w] += bi + bo;
            }
        }
        let total: u64 = per_worker.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / k as f64;
        per_worker.iter().copied().max().unwrap_or(0) as f64 / mean
    }

    pub fn merge(&mut self, other: ShuffleLedger) {
        self.stages.extend(other.stages);
    }

    /// A copy with every stage renamed to `{prefix}/{stage}` — how the
    /// streaming runtime folds per-window ledgers into one run ledger
    /// without losing the window attribution (`w3/filter_shuffle`).
    pub fn tagged(&self, prefix: &str) -> ShuffleLedger {
        ShuffleLedger {
            stages: self
                .stages
                .iter()
                .map(|s| StageTraffic {
                    stage: format!("{prefix}/{}", s.stage),
                    bytes_in: s.bytes_in.clone(),
                    bytes_out: s.bytes_out.clone(),
                })
                .collect(),
        }
    }

    /// Measured bytes of every stage whose name starts with `prefix` — the
    /// per-window lookup on a tagged run ledger (`prefix_bytes("w3/")`).
    pub fn prefix_bytes(&self, prefix: &str) -> u64 {
        self.stages
            .iter()
            .filter(|s| s.stage.starts_with(prefix))
            .map(|s| s.total_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut m = JoinMetrics::default();
        m.push(StageMetrics {
            name: "filter".into(),
            sim_secs: 1.0,
            wall_secs: 0.5,
            shuffled_bytes: 100,
            items: 10,
        });
        m.push(StageMetrics {
            name: "crossproduct".into(),
            sim_secs: 2.0,
            wall_secs: 1.0,
            shuffled_bytes: 50,
            items: 20,
        });
        assert_eq!(m.total_sim_secs(), 3.0);
        assert_eq!(m.total_wall_secs(), 1.5);
        assert_eq!(m.total_shuffled_bytes(), 150);
        assert_eq!(m.stage_secs("filter"), 1.0);
        assert_eq!(m.stage_secs("missing"), 0.0);
    }

    #[test]
    fn ledger_totals_and_stage_lookup() {
        let mut l = ShuffleLedger::default();
        l.push(StageTraffic {
            stage: "shuffle".into(),
            bytes_in: vec![100, 50, 0, 0],
            bytes_out: vec![0, 0, 100, 50],
        });
        l.push(StageTraffic {
            stage: "crossproduct".into(),
            bytes_in: vec![0, 0, 0, 0],
            bytes_out: vec![0, 0, 0, 0],
        });
        assert_eq!(l.total_bytes(), 150);
        assert_eq!(l.stage_bytes("shuffle"), 150);
        assert_eq!(l.stage_bytes("crossproduct"), 0);
        assert_eq!(l.stage_bytes("missing"), 0);
        assert_eq!(l.stages[0].max_worker_bytes(), 100);
    }

    #[test]
    fn ledger_skew_balanced_vs_hot() {
        let mut balanced = ShuffleLedger::default();
        balanced.push(StageTraffic {
            stage: "s".into(),
            bytes_in: vec![10, 10],
            bytes_out: vec![10, 10],
        });
        assert!((balanced.skew() - 1.0).abs() < 1e-12);
        let mut hot = ShuffleLedger::default();
        hot.push(StageTraffic {
            stage: "s".into(),
            bytes_in: vec![100, 0],
            bytes_out: vec![0, 0],
        });
        assert!((hot.skew() - 2.0).abs() < 1e-12);
        assert!((ShuffleLedger::default().skew() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_edges_answer_instead_of_panicking() {
        // no workers at all: no bottleneck, no skew, no bytes
        let empty = StageTraffic {
            stage: "empty".into(),
            bytes_in: vec![],
            bytes_out: vec![],
        };
        assert_eq!(empty.max_worker_bytes(), 0);
        assert_eq!(empty.total_bytes(), 0);
        let mut l = ShuffleLedger::default();
        l.push(empty);
        assert!((l.skew() - 1.0).abs() < 1e-12);
        // zero-byte stages with workers: balanced by definition
        l.push(StageTraffic {
            stage: "idle".into(),
            bytes_in: vec![0, 0, 0],
            bytes_out: vec![0, 0, 0],
        });
        assert!((l.skew() - 1.0).abs() < 1e-12);
        assert_eq!(l.total_bytes(), 0);
        // ragged per-worker vectors (a 2-worker stage in a 3-worker run)
        // truncate safely instead of indexing out of bounds
        l.push(StageTraffic {
            stage: "ragged".into(),
            bytes_in: vec![30, 0],
            bytes_out: vec![0, 30],
        });
        assert!(l.skew() >= 1.0);
        assert_eq!(l.stage_bytes("ragged"), 30);
    }

    #[test]
    fn tagged_ledger_keeps_bytes_and_prefix_lookup_works() {
        let mut l = ShuffleLedger::default();
        l.push(StageTraffic {
            stage: "filter_shuffle".into(),
            bytes_in: vec![0, 100],
            bytes_out: vec![100, 0],
        });
        l.push(StageTraffic {
            stage: "sample".into(),
            bytes_in: vec![0, 0],
            bytes_out: vec![0, 0],
        });
        let mut run = ShuffleLedger::default();
        run.merge(l.tagged("w0"));
        run.merge(l.tagged("w1"));
        assert_eq!(run.stages.len(), 4);
        assert_eq!(run.stages[0].stage, "w0/filter_shuffle");
        assert_eq!(run.prefix_bytes("w0/"), 100);
        assert_eq!(run.prefix_bytes("w1/"), 100);
        assert_eq!(run.prefix_bytes("w2/"), 0);
        assert_eq!(run.total_bytes(), 200);
        assert_eq!(run.stage_bytes("w1/filter_shuffle"), 100);
    }

    #[test]
    fn merge_concatenates() {
        let mut a = JoinMetrics::default();
        a.push(StageMetrics {
            name: "x".into(),
            ..Default::default()
        });
        let mut b = JoinMetrics::default();
        b.push(StageMetrics {
            name: "y".into(),
            ..Default::default()
        });
        a.merge(b);
        assert_eq!(a.stages.len(), 2);
    }
}
