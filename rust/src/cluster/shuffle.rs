//! The shuffle fabric: repartition records by join key across workers with
//! exact byte accounting — Spark's `cogroup()` data movement (§4: "the data
//! shuffled by the cogroup() function is the output of the filtering
//! stage").
//!
//! Both shuffles are single accounting-bound passes: hashing a key costs
//! no more than recording its transfer, so there is nothing to win from
//! parallelizing here. (The filtering stage's expensive predicate — the
//! Bloom probe — runs data-parallel in `join::bloom_join` before its
//! shuffle walk.)
//!
//! Filter traffic (tree-reduce merges, join-filter broadcasts) is
//! accounted through the same [`Stage`] transfer primitives by
//! [`super::tree_reduce`]; payload sizes come from
//! [`super::tree_reduce::MergePayload`], so standard and blocked filter
//! layouts of equal geometry cost identical bytes on the wire.

use super::{SimCluster, Stage};
use crate::data::{partition_of, Dataset, Record};

/// Repartition a dataset's records by key hash onto `k` workers, counting
/// bytes for every record that changes workers. Returns per-worker record
/// vectors (tagged with nothing — the caller tracks input identity).
pub fn shuffle_dataset(
    cluster: &SimCluster,
    stage: &mut Stage,
    dataset: &Dataset,
) -> Vec<Vec<Record>> {
    let k = cluster.k;
    let mut out: Vec<Vec<Record>> = vec![Vec::new(); k];
    for (j, part) in dataset.partitions.iter().enumerate() {
        let src = cluster.worker_of_partition(j);
        for r in part {
            let dst = partition_of(r.key, k);
            stage.transfer(src, dst, dataset.record_bytes);
            out[dst].push(*r);
        }
    }
    stage.add_items(dataset.len());
    out
}

/// Shuffle only the records passing `keep` — the shape of ApproxJoin's
/// stage-1 post-filter shuffle (`join::bloom_join::filter_and_shuffle`
/// inlines this walk over its precomputed probe masks).
pub fn shuffle_filtered(
    cluster: &SimCluster,
    stage: &mut Stage,
    dataset: &Dataset,
    keep: impl Fn(&Record) -> bool,
) -> Vec<Vec<Record>> {
    let k = cluster.k;
    let mut out: Vec<Vec<Record>> = vec![Vec::new(); k];
    let mut kept = 0u64;
    for (j, part) in dataset.partitions.iter().enumerate() {
        let src = cluster.worker_of_partition(j);
        for r in part {
            if keep(r) {
                let dst = partition_of(r.key, k);
                stage.transfer(src, dst, dataset.record_bytes);
                out[dst].push(*r);
                kept += 1;
            }
        }
    }
    stage.add_items(kept);
    out
}

/// Broadcast a whole dataset to every worker (broadcast join's movement of
/// the smaller inputs): (k−1) copies of every byte.
pub fn broadcast_dataset(cluster: &SimCluster, stage: &mut Stage, dataset: &Dataset) {
    // each partition is sent from its owner to the k-1 other workers
    for (j, part) in dataset.partitions.iter().enumerate() {
        let src = cluster.worker_of_partition(j);
        stage.broadcast(src, part.len() as u64 * dataset.record_bytes);
    }
    stage.add_items(dataset.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;

    fn cluster(k: usize) -> SimCluster {
        SimCluster::new(
            k,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn dataset(keys: &[u64], parts: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            "t",
            keys.iter().map(|&k| Record::new(k, 1.0)).collect(),
            parts,
            10,
        )
    }

    #[test]
    fn shuffle_routes_by_key() {
        let mut c = cluster(4);
        let d = dataset(&(0..100).collect::<Vec<_>>(), 4);
        let mut s = c.stage("shuffle");
        let out = shuffle_dataset(&c, &mut s, &d);
        // all records present, each on the worker its key hashes to
        let total: usize = out.iter().map(|v| v.len()).sum();
        assert_eq!(total, 100);
        for (w, recs) in out.iter().enumerate() {
            assert!(recs.iter().all(|r| partition_of(r.key, 4) == w));
        }
        s.finish(&mut c);
    }

    #[test]
    fn copartitioned_data_is_free() {
        let mut c = cluster(4);
        // Dataset::from_records hash-partitions with the same partitioner:
        // a 4-partition dataset on a 4-worker cluster shuffles zero bytes.
        let d = Dataset::from_records(
            "t",
            (0..100).map(|k| Record::new(k, 1.0)).collect(),
            4,
            10,
        );
        let mut s = c.stage("shuffle");
        shuffle_dataset(&c, &mut s, &d);
        assert_eq!(s.shuffled_bytes(), 0);
        s.finish(&mut c);
    }

    #[test]
    fn uncopartitioned_data_pays() {
        let mut c = cluster(4);
        let d = dataset(&(0..1000).collect::<Vec<_>>(), 4); // round-robin
        let mut s = c.stage("shuffle");
        shuffle_dataset(&c, &mut s, &d);
        // ~3/4 of records move: bytes ~ 1000 * 10 * 0.75
        let b = s.shuffled_bytes();
        assert!((6000..9000).contains(&b), "bytes {b}");
        s.finish(&mut c);
    }

    #[test]
    fn filtered_shuffle_moves_less() {
        let mut c = cluster(4);
        let d = dataset(&(0..1000).collect::<Vec<_>>(), 4);
        let mut s_all = c.stage("all");
        shuffle_dataset(&c, &mut s_all, &d);
        let all = s_all.shuffled_bytes();
        let mut s_f = c.stage("filtered");
        let out = shuffle_filtered(&c, &mut s_f, &d, |r| r.key < 100);
        let filt = s_f.shuffled_bytes();
        assert!(filt < all / 5, "filtered {filt} vs all {all}");
        let total: usize = out.iter().map(|v| v.len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn broadcast_costs_k_minus_1_copies() {
        let mut c = cluster(5);
        let d = dataset(&(0..10).collect::<Vec<_>>(), 2);
        let mut s = c.stage("bcast");
        broadcast_dataset(&c, &mut s, &d);
        assert_eq!(s.shuffled_bytes(), 10 * 10 * 4);
        s.finish(&mut c);
    }
}
