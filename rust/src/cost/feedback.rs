//! The feedback mechanism (paper §3.2 II / §4 IV): the per-stratum standard
//! deviation σ_i cannot be known before the first execution, so the first
//! run records it and subsequent runs of the *same query* use the stored
//! values in eq 10 to pick optimal sample sizes.

use crate::stats::StratumAgg;
use crate::util::Json;
use std::collections::HashMap;
use std::path::PathBuf;

/// Persistent map: query fingerprint → (join key → σ_i).
#[derive(Clone, Debug, Default)]
pub struct FeedbackStore {
    path: Option<PathBuf>,
    runs: HashMap<String, HashMap<u64, f64>>,
    /// Session scope prefixed onto every fingerprint. Empty (the default)
    /// shares entries across all callers of this store; the serving layer
    /// gives each concurrent client session its own scope so two sessions
    /// running the same query shape never interleave σ feedback.
    scope: String,
}

impl FeedbackStore {
    /// In-memory store (tests, one-shot runs).
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// Namespace every fingerprint under `scope` — entries written through
    /// a scoped store are invisible to other scopes (and to the unscoped
    /// view) of the same underlying map.
    pub fn with_scope(mut self, scope: impl Into<String>) -> Self {
        self.scope = scope.into();
        self
    }

    /// Change the scope in place (see [`FeedbackStore::with_scope`]).
    pub fn set_scope(&mut self, scope: impl Into<String>) {
        self.scope = scope.into();
    }

    /// The scoped key a fingerprint is stored under.
    fn key(&self, fingerprint: &str) -> String {
        if self.scope.is_empty() {
            fingerprint.to_string()
        } else {
            format!("{}::{}", self.scope, fingerprint)
        }
    }

    /// Store backed by a JSON file; loads existing content if present.
    pub fn open(path: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let path = path.into();
        let mut store = Self {
            path: Some(path.clone()),
            runs: HashMap::new(),
            scope: String::new(),
        };
        if path.exists() {
            let j = Json::parse(&std::fs::read_to_string(&path)?)?;
            if let Some(obj) = j.as_obj() {
                for (fp, sig) in obj {
                    let mut m = HashMap::new();
                    if let Some(sobj) = sig.as_obj() {
                        for (k, v) in sobj {
                            if let (Ok(key), Some(val)) = (k.parse::<u64>(), v.as_f64()) {
                                m.insert(key, val);
                            }
                        }
                    }
                    store.runs.insert(fp.clone(), m);
                }
            }
        }
        Ok(store)
    }

    /// Record the observed per-stratum σ of a finished run.
    pub fn record(&mut self, fingerprint: &str, strata: &HashMap<u64, StratumAgg>) {
        let entry = self.runs.entry(self.key(fingerprint)).or_default();
        for (&key, agg) in strata {
            if agg.count > 1.0 {
                entry.insert(key, agg.stddev());
            }
        }
    }

    /// Record one scalar calibration value under `fingerprint`/`slot` —
    /// the join-order optimizer stores learned pair selectivities
    /// (`joinsel:…`) and measured/predicted shuffle-byte ratios
    /// (`joinbytes:…`) this way, riding the same scoping and JSON
    /// persistence as the σ feedback.
    pub fn record_value(&mut self, fingerprint: &str, slot: u64, value: f64) {
        self.runs
            .entry(self.key(fingerprint))
            .or_default()
            .insert(slot, value);
    }

    /// Read back a scalar recorded with [`FeedbackStore::record_value`].
    pub fn value(&self, fingerprint: &str, slot: u64) -> Option<f64> {
        self.runs
            .get(&self.key(fingerprint))
            .and_then(|m| m.get(&slot))
            .copied()
    }

    /// Stored σ map for a query (empty on first execution).
    pub fn sigmas(&self, fingerprint: &str) -> HashMap<u64, f64> {
        self.runs.get(&self.key(fingerprint)).cloned().unwrap_or_default()
    }

    pub fn has(&self, fingerprint: &str) -> bool {
        self.runs.contains_key(&self.key(fingerprint))
    }

    /// Median stored σ — the `default_sigma` for strata unseen so far.
    pub fn default_sigma(&self, fingerprint: &str) -> f64 {
        let mut v: Vec<f64> = self
            .runs
            .get(&self.key(fingerprint))
            .map(|m| m.values().copied().collect())
            .unwrap_or_default();
        if v.is_empty() {
            return 1.0;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    pub fn save(&self) -> anyhow::Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let obj = Json::Obj(
            self.runs
                .iter()
                .map(|(fp, m)| {
                    (
                        fp.clone(),
                        Json::Obj(
                            m.iter()
                                .map(|(k, v)| (k.to_string(), Json::num(*v)))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, obj.to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agg(count: f64, sum: f64, sumsq: f64) -> StratumAgg {
        StratumAgg {
            population: 100.0,
            count,
            sum,
            sumsq,
        }
    }

    #[test]
    fn record_then_query() {
        let mut s = FeedbackStore::in_memory();
        let mut strata = HashMap::new();
        strata.insert(1u64, agg(10.0, 50.0, 300.0)); // sd > 0
        strata.insert(2u64, agg(1.0, 5.0, 25.0)); // singleton: skipped
        s.record("q1", &strata);
        let sig = s.sigmas("q1");
        assert!(sig.contains_key(&1));
        assert!(!sig.contains_key(&2));
        assert!(s.has("q1"));
        assert!(!s.has("q2"));
    }

    #[test]
    fn default_sigma_median() {
        let mut s = FeedbackStore::in_memory();
        let mut strata = HashMap::new();
        for (k, sd) in [(1u64, 1.0f64), (2, 3.0), (3, 100.0)] {
            // construct agg with desired sd: n=2, values {m-sd/sqrt2 ...}
            // simpler: sum=0, sumsq = sd^2 * (n-1) with n=2
            strata.insert(k, agg(2.0, 0.0, sd * sd));
        }
        s.record("q", &strata);
        let d = s.default_sigma("q");
        assert!((d - 3.0).abs() < 1e-9, "median {d}");
        assert_eq!(FeedbackStore::in_memory().default_sigma("nope"), 1.0);
    }

    #[test]
    fn scalar_values_roundtrip_and_respect_scope() {
        let mut s = FeedbackStore::in_memory();
        assert_eq!(s.value("joinsel:a|b:", 0), None);
        s.record_value("joinsel:a|b:", 0, 0.25);
        assert_eq!(s.value("joinsel:a|b:", 0), Some(0.25));
        s.record_value("joinsel:a|b:", 0, 0.5); // latest wins
        assert_eq!(s.value("joinsel:a|b:", 0), Some(0.5));

        let mut scoped = s.clone();
        scoped.set_scope("client0");
        assert_eq!(scoped.value("joinsel:a|b:", 0), None);
        scoped.record_value("joinsel:a|b:", 0, 0.75);
        assert_eq!(scoped.value("joinsel:a|b:", 0), Some(0.75));
        assert_eq!(s.value("joinsel:a|b:", 0), Some(0.5));
    }

    #[test]
    fn scoped_entries_never_interleave() {
        let mut strata = HashMap::new();
        strata.insert(1u64, agg(10.0, 50.0, 300.0));

        // two scoped views writing the same fingerprint stay disjoint
        let mut s1 = FeedbackStore::in_memory().with_scope("client0");
        s1.record("q", &strata);
        assert!(s1.has("q"));
        let mut s2 = s1.clone();
        s2.set_scope("client1");
        assert!(!s2.has("q"), "client1 must not see client0's sigmas");
        s2.record("q", &strata);
        assert!(s2.has("q"));

        // the unscoped view of the same map sees neither
        let mut unscoped = s2.clone();
        unscoped.set_scope("");
        assert!(!unscoped.has("q"));
        assert_eq!(unscoped.default_sigma("q"), 1.0);
    }

    #[test]
    fn persistence_roundtrip() {
        let dir = std::env::temp_dir().join(format!("aj_fb_{}", std::process::id()));
        let path = dir.join("feedback.json");
        {
            let mut s = FeedbackStore::open(&path).unwrap();
            let mut strata = HashMap::new();
            strata.insert(42u64, agg(5.0, 10.0, 40.0));
            s.record("fp", &strata);
            s.save().unwrap();
        }
        let s = FeedbackStore::open(&path).unwrap();
        let sig = s.sigmas("fp");
        assert!(sig[&42] > 0.0);
        std::fs::remove_dir_all(dir).ok();
    }
}
