//! The cost functions (paper §3.2): translating a user's query budget —
//! desired latency *or* desired error bound — into per-stratum sample
//! sizes, plus the β_compute profiling (Fig 5) and the feedback mechanism
//! that stores per-stratum σ between runs.

pub mod feedback;

pub use feedback::FeedbackStore;

use crate::util::Json;
use std::time::Instant;

/// The latency cost model: d_cp = β_compute · CP_total + ε (eq 5).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Seconds per cross-product pair on this cluster (paper: 4.16e-9).
    pub beta_compute: f64,
    /// Fixed noise/overhead term ε.
    pub epsilon: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // a sensible prior before profiling; `profile_host` replaces it
        Self {
            beta_compute: 4.16e-9,
            epsilon: 0.05,
        }
    }
}

impl CostModel {
    /// Least-squares fit of (pairs, seconds) observations to eq 5.
    pub fn fit(samples: &[(u64, f64)]) -> Self {
        assert!(samples.len() >= 2, "need >= 2 profile points");
        let n = samples.len() as f64;
        let sx: f64 = samples.iter().map(|&(p, _)| p as f64).sum();
        let sy: f64 = samples.iter().map(|&(_, t)| t).sum();
        let sxx: f64 = samples.iter().map(|&(p, _)| (p as f64) * (p as f64)).sum();
        let sxy: f64 = samples.iter().map(|&(p, t)| p as f64 * t).sum();
        let denom = n * sxx - sx * sx;
        let beta = if denom.abs() < 1e-30 {
            CostModel::default().beta_compute
        } else {
            ((n * sxy - sx * sy) / denom).max(1e-12)
        };
        let eps = (sy / n - beta * sx / n).max(0.0);
        Self {
            beta_compute: beta,
            epsilon: eps,
        }
    }

    /// Offline profiling of this host (Fig 5): time full cross products of
    /// growing sizes and fit the linear model. Returns the model and the
    /// raw (pairs, secs) curve for reporting.
    pub fn profile_host(sizes: &[u64]) -> (Self, Vec<(u64, f64)>) {
        let mut samples = Vec::with_capacity(sizes.len());
        for &pairs in sizes {
            let side = (pairs as f64).sqrt().ceil() as usize;
            let a: Vec<f64> = (0..side).map(|i| i as f64 * 0.5).collect();
            let b: Vec<f64> = (0..side).map(|i| i as f64 * 0.25).collect();
            let t0 = Instant::now();
            let agg = crate::join::cross_product_agg(
                &[a, b],
                crate::join::CombineOp::Sum,
            );
            let dt = t0.elapsed().as_secs_f64();
            assert!(agg.count > 0.0);
            samples.push((agg.population as u64, dt));
        }
        (Self::fit(&samples), samples)
    }

    /// Offline profiling of the *sampling* path: seconds per sampled edge
    /// draw. The paper prices sampled pairs with the same β as full
    /// cross-product pairs (eq 3-5); per-draw work (two uniform picks + an
    /// aggregate push) is costlier than a fused cross-product inner loop,
    /// so engines wanting the eq-6 fraction to land on the budget should
    /// calibrate with this instead.
    pub fn profile_sampling_host(sizes: &[u64]) -> (Self, Vec<(u64, f64)>) {
        use crate::sampling::edge_sampling::sample_edges_with_replacement;
        let mut rng = crate::util::Rng::new(0x5EED);
        let side_a: Vec<f64> = (0..512).map(|i| i as f64).collect();
        let side_b: Vec<f64> = (0..512).map(|i| i as f64 * 0.5).collect();
        let sides = [side_a, side_b];
        let mut samples = Vec::with_capacity(sizes.len());
        for &draws in sizes {
            let t0 = Instant::now();
            let agg = sample_edges_with_replacement(
                &mut rng,
                &sides,
                draws,
                crate::join::CombineOp::Sum,
            );
            let dt = t0.elapsed().as_secs_f64();
            assert!(agg.count > 0.0);
            samples.push((draws, dt));
        }
        (Self::fit(&samples), samples)
    }

    /// Predicted cross-product latency for CP_total pairs (eq 5).
    pub fn cp_latency(&self, pairs: f64) -> f64 {
        self.beta_compute * pairs + self.epsilon
    }

    /// Sampling fraction for a latency budget (eq 6): the share of the
    /// total bipartite population we can afford to sample in
    /// d_rem = d_desired − d_dt. Clamped to [0, 1]; a result of 1 means the
    /// exact join fits the budget (§3.1.1's "no approximation needed").
    pub fn fraction_for_latency(&self, d_desired: f64, d_dt: f64, total_pairs: f64) -> f64 {
        if total_pairs <= 0.0 {
            return 1.0;
        }
        let d_rem = d_desired - d_dt - self.epsilon;
        if d_rem <= 0.0 {
            return 0.0;
        }
        (d_rem / self.beta_compute / total_pairs).clamp(0.0, 1.0)
    }

    /// The combined trade-off (eq 11): predicted end-to-end latency of
    /// meeting `err_desired` on a stratum with stddev sigma and population
    /// share B_i of ΣB.
    pub fn latency_for_error(
        &self,
        err_desired: f64,
        confidence: f64,
        sigma: f64,
        stratum_pop: f64,
        total_pop: f64,
        d_dt: f64,
    ) -> f64 {
        let b = crate::stats::estimators::sample_size_for_error(sigma, err_desired, confidence);
        let s = (b as f64 / stratum_pop).min(1.0);
        self.beta_compute * s * total_pop + d_dt + self.epsilon
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("beta_compute", Json::num(self.beta_compute)),
            ("epsilon", Json::num(self.epsilon)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            beta_compute: j
                .get("beta_compute")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("missing beta_compute"))?,
            epsilon: j
                .get("epsilon")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("missing epsilon"))?,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_line() {
        // t = 2e-8 * p + 0.1
        let samples: Vec<(u64, f64)> = [1e6, 5e6, 1e7, 5e7]
            .iter()
            .map(|&p| (p as u64, 2e-8 * p + 0.1))
            .collect();
        let m = CostModel::fit(&samples);
        assert!((m.beta_compute - 2e-8).abs() / 2e-8 < 1e-6);
        assert!((m.epsilon - 0.1).abs() < 1e-6);
    }

    #[test]
    fn profile_host_is_roughly_linear() {
        let (m, curve) = CostModel::profile_host(&[100_000, 400_000, 1_600_000]);
        assert!(m.beta_compute > 0.0);
        // predictions track measurements within 3x at the largest size
        let (p, t) = *curve.last().unwrap();
        let pred = m.cp_latency(p as f64);
        assert!(
            pred / t < 3.0 && t / pred < 3.0,
            "pred {pred} vs measured {t}"
        );
    }

    #[test]
    fn fraction_for_latency_behaviour() {
        let m = CostModel {
            beta_compute: 1e-6,
            epsilon: 0.0,
        };
        // 1s budget, no filter time, 1e6 pairs cost 1s -> fraction 1
        assert!((m.fraction_for_latency(1.0, 0.0, 1e6) - 1.0).abs() < 1e-9);
        // half the budget -> half the pairs
        assert!((m.fraction_for_latency(0.5, 0.0, 1e6) - 0.5).abs() < 1e-9);
        // budget exhausted by filtering -> 0
        assert_eq!(m.fraction_for_latency(1.0, 2.0, 1e6), 0.0);
        // empty join -> exact is free
        assert_eq!(m.fraction_for_latency(1.0, 0.0, 0.0), 1.0);
    }

    #[test]
    fn latency_for_error_monotonic_in_error() {
        let m = CostModel::default();
        let tight = m.latency_for_error(0.01, 0.95, 5.0, 1e4, 1e6, 2.0);
        let loose = m.latency_for_error(0.1, 0.95, 5.0, 1e4, 1e6, 2.0);
        assert!(tight >= loose);
    }

    #[test]
    fn json_roundtrip() {
        let m = CostModel {
            beta_compute: 3.5e-9,
            epsilon: 0.25,
        };
        let j = m.to_json();
        let back = CostModel::from_json(&j).unwrap();
        assert_eq!(back.beta_compute, m.beta_compute);
        assert_eq!(back.epsilon, m.epsilon);
    }
}
