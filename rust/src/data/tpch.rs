//! Mini TPC-H dbgen (paper §5.5): CUSTOMER / ORDERS / LINEITEM with the
//! spec's key relations and value distributions, at a configurable scale
//! factor, plus the join-only projections of Q3, Q4 and Q10 the paper uses
//! (it strips every non-join operator).
//!
//! Cardinalities follow the TPC-H spec: |CUSTOMER| = 150k·SF,
//! |ORDERS| = 1.5M·SF (10 per customer over a 1/3 customer subset pattern —
//! the spec leaves 1/3 of customers without orders), |LINEITEM| ≈ 4·|ORDERS|
//! (1..7 lines per order, uniform).

use super::{Dataset, Record};
use crate::relation::{ColumnType, Relation, Schema, Value};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct Customer {
    pub custkey: u64,
    pub acctbal: f64,
    pub mktsegment: u8,
}

#[derive(Clone, Copy, Debug)]
pub struct Order {
    pub orderkey: u64,
    pub custkey: u64,
    pub totalprice: f64,
    /// days since epoch start of the TPC-H date range
    pub orderdate: u32,
}

#[derive(Clone, Copy, Debug)]
pub struct Lineitem {
    pub orderkey: u64,
    pub extendedprice: f64,
    pub discount: f64,
    pub shipdate: u32,
    pub commitdate: u32,
    pub receiptdate: u32,
}

/// The generated database.
#[derive(Clone, Debug)]
pub struct TpchDb {
    pub customers: Vec<Customer>,
    pub orders: Vec<Order>,
    pub lineitems: Vec<Lineitem>,
    pub scale_factor: f64,
}

/// TPC-H date range spans ~2406 days (1992-01-01 .. 1998-08-02).
const DATE_RANGE: u32 = 2406;

pub fn generate(scale_factor: f64, seed: u64) -> TpchDb {
    assert!(scale_factor > 0.0);
    let mut r = Rng::new(seed ^ 0x7c94);
    let n_cust = ((150_000.0 * scale_factor) as u64).max(10);
    let n_orders = n_cust * 10;

    let customers: Vec<Customer> = (1..=n_cust)
        .map(|custkey| Customer {
            custkey,
            acctbal: r.range_f64(-999.99, 9999.99),
            mktsegment: r.index(5) as u8,
        })
        .collect();

    let mut orders = Vec::with_capacity(n_orders as usize);
    let mut lineitems = Vec::with_capacity(n_orders as usize * 4);
    for orderkey in 1..=n_orders {
        // spec: only 2/3 of customers have orders
        let custkey = loop {
            let c = 1 + r.below(n_cust);
            if c % 3 != 0 {
                break c;
            }
        };
        let orderdate = r.below(DATE_RANGE as u64 - 151) as u32;
        let nlines = 1 + r.index(7);
        let mut totalprice = 0.0;
        for _ in 0..nlines {
            let extendedprice = r.range_f64(900.0, 104_000.0);
            let discount = r.range_f64(0.0, 0.1);
            let shipdate = orderdate + 1 + r.below(121) as u64 as u32;
            let commitdate = orderdate + 30 + r.below(61) as u32;
            let receiptdate = shipdate + 1 + r.below(30) as u32;
            totalprice += extendedprice * (1.0 - discount);
            lineitems.push(Lineitem {
                orderkey,
                extendedprice,
                discount,
                shipdate,
                commitdate,
                receiptdate,
            });
        }
        orders.push(Order {
            orderkey,
            custkey,
            totalprice,
            orderdate,
        });
    }

    TpchDb {
        customers,
        orders,
        lineitems,
        scale_factor,
    }
}

/// Wire widths (bytes) of the full tuples, per the TPC-H table layouts.
pub const CUSTOMER_BYTES: u64 = 179;
pub const ORDERS_BYTES: u64 = 104;
pub const LINEITEM_BYTES: u64 = 112;

impl TpchDb {
    /// CUSTOMER keyed by custkey, value = c_acctbal.
    pub fn customer_by_custkey(&self, partitions: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            "customer",
            self.customers
                .iter()
                .map(|c| Record::new(c.custkey, c.acctbal))
                .collect(),
            partitions,
            CUSTOMER_BYTES,
        )
    }

    /// ORDERS keyed by custkey (Q3/Q10/§5.5 CUSTOMER⋈ORDERS side),
    /// value = o_totalprice.
    pub fn orders_by_custkey(&self, partitions: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            "orders",
            self.orders
                .iter()
                .map(|o| Record::new(o.custkey, o.totalprice))
                .collect(),
            partitions,
            ORDERS_BYTES,
        )
    }

    /// ORDERS keyed by orderkey (Q3/Q4 ORDERS⋈LINEITEM side).
    pub fn orders_by_orderkey(&self, partitions: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            "orders",
            self.orders
                .iter()
                .map(|o| Record::new(o.orderkey, o.totalprice))
                .collect(),
            partitions,
            ORDERS_BYTES,
        )
    }

    /// LINEITEM keyed by orderkey, value = l_extendedprice·(1−l_discount).
    pub fn lineitem_by_orderkey(&self, partitions: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            "lineitem",
            self.lineitems
                .iter()
                .map(|l| Record::new(l.orderkey, l.extendedprice * (1.0 - l.discount)))
                .collect(),
            partitions,
            LINEITEM_BYTES,
        )
    }

    /// CUSTOMER as a typed relation: custkey, acctbal, mktsegment — the
    /// relational front end's view (GROUP BY mktsegment, WHERE acctbal).
    pub fn customer_relation(&self, partitions: usize) -> Relation {
        let schema = Schema::new(vec![
            ("custkey", ColumnType::Key),
            ("acctbal", ColumnType::Float),
            ("mktsegment", ColumnType::Int),
        ]);
        let rows = self
            .customers
            .iter()
            .map(|c| {
                vec![
                    Value::Key(c.custkey),
                    Value::Float(c.acctbal),
                    Value::Int(c.mktsegment as i64),
                ]
            })
            .collect();
        let mut r = Relation::new("customer", schema, rows, partitions).expect("valid rows");
        r.row_bytes = CUSTOMER_BYTES;
        r
    }

    /// ORDERS as a typed relation: custkey + orderkey join keys,
    /// totalprice, orderdate (days since the TPC-H epoch).
    pub fn orders_relation(&self, partitions: usize) -> Relation {
        let schema = Schema::new(vec![
            ("custkey", ColumnType::Key),
            ("orderkey", ColumnType::Key),
            ("totalprice", ColumnType::Float),
            ("orderdate", ColumnType::Int),
        ]);
        let rows = self
            .orders
            .iter()
            .map(|o| {
                vec![
                    Value::Key(o.custkey),
                    Value::Key(o.orderkey),
                    Value::Float(o.totalprice),
                    Value::Int(o.orderdate as i64),
                ]
            })
            .collect();
        let mut r = Relation::new("orders", schema, rows, partitions).expect("valid rows");
        r.row_bytes = ORDERS_BYTES;
        r
    }

    /// LINEITEM as a typed relation: orderkey, extendedprice, discount,
    /// shipdate, and the Q3/Q10 revenue expression
    /// `extendedprice · (1 − discount)` materialized as `revenue`.
    pub fn lineitem_relation(&self, partitions: usize) -> Relation {
        let schema = Schema::new(vec![
            ("orderkey", ColumnType::Key),
            ("extendedprice", ColumnType::Float),
            ("discount", ColumnType::Float),
            ("shipdate", ColumnType::Int),
            ("revenue", ColumnType::Float),
        ]);
        let rows = self
            .lineitems
            .iter()
            .map(|l| {
                vec![
                    Value::Key(l.orderkey),
                    Value::Float(l.extendedprice),
                    Value::Float(l.discount),
                    Value::Int(l.shipdate as i64),
                    Value::Float(l.extendedprice * (1.0 - l.discount)),
                ]
            })
            .collect();
        let mut r = Relation::new("lineitem", schema, rows, partitions).expect("valid rows");
        r.row_bytes = LINEITEM_BYTES;
        r
    }

    /// Q4-flavoured LINEITEM: only lines with l_commitdate < l_receiptdate
    /// (the EXISTS predicate of Q4), keyed by orderkey.
    pub fn lineitem_q4(&self, partitions: usize) -> Dataset {
        Dataset::from_records_unpartitioned(
            "lineitem_q4",
            self.lineitems
                .iter()
                .filter(|l| l.commitdate < l.receiptdate)
                .map(|l| Record::new(l.orderkey, 1.0))
                .collect(),
            partitions,
            LINEITEM_BYTES,
        )
    }
}

/// The join-only TPC-H queries of §5.5. Each step is a 2-way equi-join on
/// a single attribute; Q3/Q10 chain two steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TpchQuery {
    Q3,
    Q4,
    Q10,
}

impl TpchQuery {
    /// The join steps (left dataset, right dataset) this query performs,
    /// in order. Chained steps re-key intermediate output downstream; for
    /// the paper's latency comparison the per-step joins dominate.
    pub fn join_steps(&self, db: &TpchDb, partitions: usize) -> Vec<(Dataset, Dataset)> {
        match self {
            TpchQuery::Q3 => vec![
                (
                    db.customer_by_custkey(partitions),
                    db.orders_by_custkey(partitions),
                ),
                (
                    db.orders_by_orderkey(partitions),
                    db.lineitem_by_orderkey(partitions),
                ),
            ],
            TpchQuery::Q4 => vec![(
                db.orders_by_orderkey(partitions),
                db.lineitem_q4(partitions),
            )],
            TpchQuery::Q10 => vec![
                (
                    db.customer_by_custkey(partitions),
                    db.orders_by_custkey(partitions),
                ),
                (
                    db.orders_by_orderkey(partitions),
                    db.lineitem_by_orderkey(partitions),
                ),
            ],
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TpchQuery::Q3 => "Q3",
            TpchQuery::Q4 => "Q4",
            TpchQuery::Q10 => "Q10",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TpchDb {
        generate(0.001, 1)
    }

    #[test]
    fn cardinalities_scale() {
        let db = small();
        assert_eq!(db.customers.len(), 150);
        assert_eq!(db.orders.len(), 1500);
        let ratio = db.lineitems.len() as f64 / db.orders.len() as f64;
        assert!((3.0..5.0).contains(&ratio), "lineitem ratio {ratio}");
    }

    #[test]
    fn referential_integrity() {
        let db = small();
        let custkeys: std::collections::HashSet<u64> =
            db.customers.iter().map(|c| c.custkey).collect();
        assert!(db.orders.iter().all(|o| custkeys.contains(&o.custkey)));
        let orderkeys: std::collections::HashSet<u64> =
            db.orders.iter().map(|o| o.orderkey).collect();
        assert!(db.lineitems.iter().all(|l| orderkeys.contains(&l.orderkey)));
    }

    #[test]
    fn a_third_of_customers_have_no_orders() {
        let db = generate(0.01, 2);
        let with_orders: std::collections::HashSet<u64> =
            db.orders.iter().map(|o| o.custkey).collect();
        let frac = with_orders.len() as f64 / db.customers.len() as f64;
        // 2/3 of customers eligible; with 10x orders per customer nearly
        // all eligible ones appear
        assert!((0.55..0.69).contains(&frac), "frac {frac}");
    }

    #[test]
    fn totalprice_consistent_with_lineitems() {
        let db = small();
        let o = &db.orders[0];
        let sum: f64 = db
            .lineitems
            .iter()
            .filter(|l| l.orderkey == o.orderkey)
            .map(|l| l.extendedprice * (1.0 - l.discount))
            .sum();
        assert!((sum - o.totalprice).abs() < 1e-6);
    }

    #[test]
    fn q4_filter_selects_subset() {
        let db = small();
        let all = db.lineitem_by_orderkey(4).len();
        let q4 = db.lineitem_q4(4).len();
        assert!(q4 > 0 && q4 < all);
    }

    #[test]
    fn join_steps_shapes() {
        let db = small();
        assert_eq!(TpchQuery::Q3.join_steps(&db, 4).len(), 2);
        assert_eq!(TpchQuery::Q4.join_steps(&db, 4).len(), 1);
        assert_eq!(TpchQuery::Q10.join_steps(&db, 4).len(), 2);
    }

    #[test]
    fn relations_mirror_tables() {
        let db = small();
        let c = db.customer_relation(4);
        assert_eq!(c.len() as usize, db.customers.len());
        assert_eq!(c.schema.col("mktsegment"), Some(2));
        assert_eq!(c.row_bytes, CUSTOMER_BYTES);
        let o = db.orders_relation(4);
        assert_eq!(o.len() as usize, db.orders.len());
        assert_eq!(o.schema.col("custkey"), Some(0));
        assert_eq!(o.schema.col("orderkey"), Some(1));
        let l = db.lineitem_relation(4);
        assert_eq!(l.len() as usize, db.lineitems.len());
        // revenue column is the materialized Q3 expression
        let row = l.iter().next().unwrap();
        let (ep, d, rev) = (
            row[1].as_f64().unwrap(),
            row[2].as_f64().unwrap(),
            row[4].as_f64().unwrap(),
        );
        assert!((rev - ep * (1.0 - d)).abs() < 1e-9);
    }

    #[test]
    fn dates_within_spec_windows() {
        let db = small();
        for l in &db.lineitems {
            assert!(l.receiptdate > l.shipdate);
            assert!(l.shipdate < DATE_RANGE + 200);
        }
    }
}
