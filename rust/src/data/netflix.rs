//! Netflix-Prize-like generator (paper §6.2). The real dataset: ~100.5M
//! ratings of 17,770 movies by 480,189 users (training_set) plus a
//! qualifying file of (movie, user, date) probes. The join the paper
//! evaluates is training_set ⋈ qualifying on the movie key — a join with
//! extreme per-key multiplicity skew (popular movies have hundreds of
//! thousands of ratings; the median has a few hundred).
//!
//! The generator reproduces: the movie population, Zipf-like per-movie
//! rating counts calibrated so the default 1/100 scale yields ~1M training
//! rows, 1-5 star values, and a qualifying set that touches a subset of
//! movies (the real one has ~2.8M probes over 17,470 movies).

use super::{Dataset, Record};
use crate::relation::{ColumnType, Relation, Schema, Value};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct NetflixSpec {
    pub movies: u64,
    /// Target total training ratings.
    pub training_ratings: u64,
    /// Target qualifying probes.
    pub qualifying_probes: u64,
    /// Fraction of movies that appear in qualifying.
    pub qualifying_movie_fraction: f64,
    /// Zipf exponent over movie popularity.
    pub skew: f64,
    pub partitions: usize,
    pub seed: u64,
}

impl Default for NetflixSpec {
    fn default() -> Self {
        Self {
            movies: 17_770,
            training_ratings: 1_000_000, // 1/100 scale
            qualifying_probes: 28_000,
            qualifying_movie_fraction: 0.983, // 17470/17770
            skew: 1.1,
            partitions: 8,
            seed: 2006,
        }
    }
}

/// Training row ~ (MovieID, UserID, Rating, Date) — 16 bytes packed wire.
pub const TRAINING_BYTES: u64 = 16;
/// Qualifying row ~ (MovieID, UserID, Date).
pub const QUALIFYING_BYTES: u64 = 12;

/// Generate [training, qualifying], both keyed by MovieID; training value =
/// rating (1-5), qualifying value = 1 (probe marker).
pub fn generate(spec: &NetflixSpec) -> Vec<Dataset> {
    let mut rng = Rng::new(spec.seed);

    // training: draw movie per rating via Zipf over movie ranks
    let mut r = rng.fork(1);
    let mut training = Vec::with_capacity(spec.training_ratings as usize);
    for _ in 0..spec.training_ratings {
        let movie = r.zipf(spec.movies, spec.skew);
        // ratings skew positive (empirical mean ~3.6)
        let rating = match r.f64() {
            x if x < 0.05 => 1.0,
            x if x < 0.15 => 2.0,
            x if x < 0.45 => 3.0,
            x if x < 0.80 => 4.0,
            _ => 5.0,
        };
        training.push(Record::new(movie, rating));
    }

    // qualifying: subset of movies, popularity-biased probes
    let mut r = rng.fork(2);
    let qual_movies = (spec.movies as f64 * spec.qualifying_movie_fraction) as u64;
    let mut qualifying = Vec::with_capacity(spec.qualifying_probes as usize);
    for _ in 0..spec.qualifying_probes {
        let movie = r.zipf(qual_movies.max(1), spec.skew);
        qualifying.push(Record::new(movie, 1.0));
    }

    vec![
        Dataset::from_records_unpartitioned(
            "training_set",
            training,
            spec.partitions,
            TRAINING_BYTES,
        ),
        Dataset::from_records_unpartitioned(
            "qualifying",
            qualifying,
            spec.partitions,
            QUALIFYING_BYTES,
        ),
    ]
}

/// The Netflix user population (480,189 in the real dataset).
const USERS: u64 = 480_189;
/// Days in the rating window (1999-11-11 .. 2005-12-31).
const DATE_DAYS: u64 = 2_243;

/// Generate `[training_set, qualifying]` as typed relations:
/// `training_set(movie, user, rating, date)` and
/// `qualifying(movie, user, date, probe)`. The `(movie, rating)` /
/// `(movie, probe)` projections match [`generate`]'s datasets row for
/// row; user and date columns are synthesized from forked streams.
pub fn generate_relations(spec: &NetflixSpec) -> Vec<Relation> {
    let datasets = generate(spec);
    let mut rng = Rng::new(spec.seed ^ 0x9e37);
    let mut r = rng.fork(11);
    let training_schema = Schema::new(vec![
        ("movie", ColumnType::Key),
        ("user", ColumnType::Int),
        ("rating", ColumnType::Float),
        ("date", ColumnType::Int),
    ]);
    // preserve the datasets' partition layout so the (movie, rating) /
    // (movie, probe) projections match the legacy generator row for row
    let training = Relation {
        name: "training_set".to_string(),
        schema: training_schema,
        partitions: datasets[0]
            .partitions
            .iter()
            .map(|p| {
                p.iter()
                    .map(|rec| {
                        vec![
                            Value::Key(rec.key),
                            Value::Int(r.zipf(USERS, 1.05) as i64),
                            Value::Float(rec.value),
                            Value::Int(r.below(DATE_DAYS) as i64),
                        ]
                    })
                    .collect()
            })
            .collect(),
        row_bytes: TRAINING_BYTES,
        degenerate: false,
    };

    let mut r = rng.fork(12);
    let qualifying_schema = Schema::new(vec![
        ("movie", ColumnType::Key),
        ("user", ColumnType::Int),
        ("date", ColumnType::Int),
        ("probe", ColumnType::Float),
    ]);
    let qualifying = Relation {
        name: "qualifying".to_string(),
        schema: qualifying_schema,
        partitions: datasets[1]
            .partitions
            .iter()
            .map(|p| {
                p.iter()
                    .map(|rec| {
                        vec![
                            Value::Key(rec.key),
                            Value::Int(r.zipf(USERS, 1.05) as i64),
                            Value::Int(r.below(DATE_DAYS) as i64),
                            Value::Float(rec.value),
                        ]
                    })
                    .collect()
            })
            .collect(),
        row_bytes: QUALIFYING_BYTES,
        degenerate: false,
    };

    vec![training, qualifying]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> NetflixSpec {
        NetflixSpec {
            training_ratings: 100_000,
            qualifying_probes: 5_000,
            ..Default::default()
        }
    }

    #[test]
    fn cardinalities() {
        let ds = generate(&small());
        assert_eq!(ds[0].len(), 100_000);
        assert_eq!(ds[1].len(), 5_000);
    }

    #[test]
    fn ratings_in_range_and_positively_skewed() {
        let ds = generate(&small());
        let mut sum = 0.0;
        for rec in ds[0].iter() {
            assert!((1.0..=5.0).contains(&rec.value));
            sum += rec.value;
        }
        let mean = sum / ds[0].len() as f64;
        assert!((3.2..4.0).contains(&mean), "mean rating {mean}");
    }

    #[test]
    fn popularity_skew() {
        let ds = generate(&small());
        let mut counts = std::collections::HashMap::new();
        for rec in ds[0].iter() {
            *counts.entry(rec.key).or_insert(0u64) += 1;
        }
        let max = *counts.values().max().unwrap();
        let mean = ds[0].len() / counts.len() as u64;
        assert!(max > 10 * mean, "max {max} mean {mean}: no skew?");
    }

    #[test]
    fn movie_keys_in_range() {
        let ds = generate(&small());
        for d in &ds {
            assert!(d.iter().all(|r| (1..=17_770).contains(&r.key)));
        }
    }

    #[test]
    fn relations_mirror_datasets() {
        let spec = small();
        let rels = generate_relations(&spec);
        let ds = generate(&spec);
        assert_eq!(rels[0].len(), ds[0].len());
        assert_eq!(rels[1].len(), ds[1].len());
        assert_eq!(rels[0].schema.col("rating"), Some(2));
        assert_eq!(rels[1].schema.col("probe"), Some(3));
        // the (movie, rating) projection matches the dataset rows
        for (row, rec) in rels[0].iter().zip(ds[0].iter()) {
            assert_eq!(row[0].as_key(), Some(rec.key));
            assert_eq!(row[2].as_f64(), Some(rec.value));
            let user = row[1].as_f64().unwrap();
            assert!(user >= 1.0 && user <= USERS as f64);
        }
    }

    #[test]
    fn join_overlap_high_by_movie() {
        // nearly every qualifying movie has training ratings
        let ds = generate(&small());
        let train_keys = ds[0].distinct_keys();
        let qual_keys = ds[1].distinct_keys();
        let covered = qual_keys.iter().filter(|k| train_keys.contains(k)).count();
        // at 1/1000 test scale the deep tail of movies has no ratings yet;
        // at default (1/100) scale coverage exceeds 95%
        assert!(
            covered as f64 / qual_keys.len() as f64 > 0.8,
            "coverage {covered}/{}",
            qual_keys.len()
        );
    }
}
