//! Synthetic workload generator (paper §5.1): datasets whose per-key
//! multiplicities follow a Poisson(λ) distribution, with a *controlled
//! overlap fraction* — the single knob every microbenchmark figure sweeps.
//!
//! Construction: a pool of `shared` keys appears in **all** inputs; each
//! input additionally gets its own disjoint key pool. Multiplicities are
//! Poisson(λ) per (input, key). Given the target overlap fraction f and the
//! requested input sizes, the generator solves for the shared-pool size so
//! the realized fraction lands on target (and `overlap_fraction()` in
//! data/mod.rs verifies it exactly in the tests).

use super::{Dataset, Record};
use crate::util::Rng;

/// Specification for one family of overlapping synthetic datasets.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Number of input datasets (n-way join).
    pub num_inputs: usize,
    /// Approximate items per input.
    pub items_per_input: u64,
    /// Poisson multiplicity parameter λ (paper: 10..10000).
    pub lambda: f64,
    /// Target overlap fraction per the paper's §3.1.1 definition.
    pub overlap_fraction: f64,
    /// Partitions per dataset.
    pub partitions: usize,
    /// Wire width of one tuple (bytes) for shuffle accounting.
    pub record_bytes: u64,
    /// Value distribution: Uniform(lo, hi) or Normal(mean, sd).
    pub values: ValueDist,
    pub seed: u64,
}

#[derive(Clone, Copy, Debug)]
pub enum ValueDist {
    Uniform(f64, f64),
    Normal(f64, f64),
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        Self {
            num_inputs: 2,
            items_per_input: 100_000,
            lambda: 100.0,
            overlap_fraction: 0.01,
            partitions: 8,
            record_bytes: 100,
            values: ValueDist::Uniform(0.0, 100.0),
            seed: 42,
        }
    }
}

impl ValueDist {
    /// Draw one value (public: the streaming event generator shares the
    /// batch generators' value distributions).
    pub fn sample(&self, r: &mut Rng) -> f64 {
        match *self {
            ValueDist::Uniform(lo, hi) => r.range_f64(lo, hi),
            ValueDist::Normal(mu, sd) => mu + sd * r.normal(),
        }
    }
}

/// Tag for shared keys (present in every input) vs per-input keys; keeps
/// the pools disjoint by construction.
#[inline]
fn shared_key(i: u64) -> u64 {
    (1 << 40) | i
}

#[inline]
fn private_key(input: usize, i: u64) -> u64 {
    ((input as u64 + 2) << 41) | i
}

/// Generate `spec.num_inputs` datasets with the requested overlap fraction.
pub fn generate_overlapping(spec: &SyntheticSpec) -> Vec<Dataset> {
    assert!(spec.num_inputs >= 2);
    assert!((0.0..=1.0).contains(&spec.overlap_fraction));
    let mut rng = Rng::new(spec.seed);

    // Target: participating items per input = f * items_per_input (the
    // fraction is symmetric when all inputs have the same size).
    let participating_per_input = (spec.overlap_fraction * spec.items_per_input as f64) as u64;
    let num_shared_keys = ((participating_per_input as f64 / spec.lambda).round() as u64).max(
        if spec.overlap_fraction > 0.0 { 1 } else { 0 },
    );

    let mut datasets = Vec::with_capacity(spec.num_inputs);
    for input in 0..spec.num_inputs {
        let mut r = rng.fork(input as u64 + 1);
        let mut records = Vec::with_capacity(spec.items_per_input as usize + 1024);
        // shared keys: Poisson(λ) copies each, at least one so the key
        // really does appear in every input
        for i in 0..num_shared_keys {
            let copies = r.poisson(spec.lambda).max(1);
            for _ in 0..copies {
                records.push(Record::new(shared_key(i), spec.values.sample(&mut r)));
            }
        }
        // private keys fill the remainder
        let mut i = 0u64;
        while (records.len() as u64) < spec.items_per_input {
            let copies = r
                .poisson(spec.lambda)
                .max(1)
                .min(spec.items_per_input - records.len() as u64);
            for _ in 0..copies {
                records.push(Record::new(private_key(input, i), spec.values.sample(&mut r)));
            }
            i += 1;
        }
        datasets.push(Dataset::from_records_unpartitioned(
            format!("synthetic_{input}"),
            records,
            spec.partitions,
            spec.record_bytes,
        ));
    }
    datasets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::overlap_fraction;

    #[test]
    fn sizes_match_spec() {
        let spec = SyntheticSpec {
            items_per_input: 50_000,
            ..Default::default()
        };
        let ds = generate_overlapping(&spec);
        assert_eq!(ds.len(), 2);
        for d in &ds {
            let n = d.len();
            // shared keys may overshoot slightly (>= 1 copy each)
            assert!(
                (50_000..52_000).contains(&n),
                "size {n} out of tolerance"
            );
        }
    }

    #[test]
    fn overlap_fraction_on_target() {
        for &target in &[0.01, 0.05, 0.2, 0.4] {
            let spec = SyntheticSpec {
                items_per_input: 30_000,
                overlap_fraction: target,
                lambda: 50.0,
                seed: 7,
                ..Default::default()
            };
            let ds = generate_overlapping(&spec);
            let measured = overlap_fraction(&ds);
            assert!(
                (measured - target).abs() < target * 0.25 + 0.005,
                "target {target} measured {measured}"
            );
        }
    }

    #[test]
    fn three_way_overlap() {
        let spec = SyntheticSpec {
            num_inputs: 3,
            items_per_input: 30_000,
            overlap_fraction: 0.05,
            seed: 8,
            ..Default::default()
        };
        let ds = generate_overlapping(&spec);
        assert_eq!(ds.len(), 3);
        let measured = overlap_fraction(&ds);
        assert!(
            (measured - 0.05).abs() < 0.02,
            "measured {measured}"
        );
    }

    #[test]
    fn zero_overlap_possible() {
        let spec = SyntheticSpec {
            overlap_fraction: 0.0,
            items_per_input: 10_000,
            ..Default::default()
        };
        let ds = generate_overlapping(&spec);
        assert_eq!(overlap_fraction(&ds), 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SyntheticSpec {
            items_per_input: 5_000,
            ..Default::default()
        };
        let a = generate_overlapping(&spec);
        let b = generate_overlapping(&spec);
        assert_eq!(a[0].partitions, b[0].partitions);
        let spec2 = SyntheticSpec { seed: 43, ..spec };
        let c = generate_overlapping(&spec2);
        assert_ne!(a[0].partitions, c[0].partitions);
    }

    #[test]
    fn key_pools_disjoint() {
        let spec = SyntheticSpec {
            items_per_input: 10_000,
            overlap_fraction: 0.1,
            ..Default::default()
        };
        let ds = generate_overlapping(&spec);
        let a_private: std::collections::HashSet<u64> = ds[0]
            .iter()
            .map(|r| r.key)
            .filter(|k| k >> 41 != 0)
            .collect();
        let b_keys = ds[1].distinct_keys();
        assert!(a_private.is_disjoint(&b_keys));
    }
}
