//! Data model: the RDD-like partitioned datasets the join operates on.
//!
//! A [`Record`] is the unit of join input — a 64-bit join key plus the
//! numeric value the aggregation query touches. Real tuples are wider than
//! 16 bytes, so every [`Dataset`] carries a `record_bytes` width used by the
//! shuffle fabric for byte accounting (the paper's "shuffled data size"
//! metric counts tuple bytes on the wire, not struct-of-two-fields bytes).

pub mod generators;
pub mod netflix;
pub mod network;
pub mod tpch;

pub use generators::{generate_overlapping, SyntheticSpec};

/// One tuple of a join input, projected to (join key, aggregated value).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Record {
    pub key: u64,
    pub value: f64,
}

impl Record {
    pub fn new(key: u64, value: f64) -> Self {
        Self { key, value }
    }
}

/// A named, hash-partitioned dataset — the Spark-RDD analogue.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Partition j holds the records co-located on worker j % k.
    pub partitions: Vec<Vec<Record>>,
    /// Serialized width of one record on the wire, for shuffle accounting.
    pub record_bytes: u64,
}

impl Dataset {
    /// Hash-partition `records` into `num_partitions` by join key (the
    /// same partitioner the shuffle uses, so co-partitioned inputs do not
    /// move — exactly Spark's HashPartitioner semantics).
    pub fn from_records(
        name: impl Into<String>,
        records: Vec<Record>,
        num_partitions: usize,
        record_bytes: u64,
    ) -> Self {
        assert!(num_partitions > 0);
        let mut partitions = vec![Vec::new(); num_partitions];
        for r in records {
            partitions[partition_of(r.key, num_partitions)].push(r);
        }
        Self {
            name: name.into(),
            partitions,
            record_bytes,
        }
    }

    /// A dataset that keeps records in arrival order, split round-robin —
    /// models raw ingestion before any shuffle has happened.
    pub fn from_records_unpartitioned(
        name: impl Into<String>,
        records: Vec<Record>,
        num_partitions: usize,
        record_bytes: u64,
    ) -> Self {
        assert!(num_partitions > 0);
        let mut partitions = vec![Vec::new(); num_partitions];
        for (i, r) in records.into_iter().enumerate() {
            partitions[i % num_partitions].push(r);
        }
        Self {
            name: name.into(),
            partitions,
            record_bytes,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn len(&self) -> u64 {
        self.partitions.iter().map(|p| p.len() as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.partitions.iter().all(|p| p.is_empty())
    }

    /// Total bytes this dataset occupies on the wire if fully shuffled.
    pub fn total_bytes(&self) -> u64 {
        self.len() * self.record_bytes
    }

    pub fn iter(&self) -> impl Iterator<Item = &Record> {
        self.partitions.iter().flatten()
    }

    /// Distinct join keys (exact, for tests and the analytic model).
    pub fn distinct_keys(&self) -> std::collections::HashSet<u64> {
        self.iter().map(|r| r.key).collect()
    }
}

/// The hash partitioner: worker/partition index for a key.
#[inline]
pub fn partition_of(key: u64, num_partitions: usize) -> usize {
    (crate::bloom::hashing::fold_key(key) as usize) % num_partitions
}

/// Exact overlap fraction of a set of datasets, per the paper's definition
/// (§3.1.1): items whose key appears in *all* inputs ÷ total items.
pub fn overlap_fraction(datasets: &[Dataset]) -> f64 {
    if datasets.is_empty() {
        return 0.0;
    }
    let mut common = datasets[0].distinct_keys();
    for d in &datasets[1..] {
        let keys = d.distinct_keys();
        common.retain(|k| keys.contains(k));
    }
    let total: u64 = datasets.iter().map(|d| d.len()).sum();
    if total == 0 {
        return 0.0;
    }
    let participating: u64 = datasets
        .iter()
        .map(|d| d.iter().filter(|r| common.contains(&r.key)).count() as u64)
        .sum();
    participating as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(keys: &[u64]) -> Vec<Record> {
        keys.iter().map(|&k| Record::new(k, k as f64)).collect()
    }

    #[test]
    fn hash_partitioning_is_stable_and_complete() {
        let d = Dataset::from_records("t", recs(&(0..1000).collect::<Vec<_>>()), 7, 64);
        assert_eq!(d.num_partitions(), 7);
        assert_eq!(d.len(), 1000);
        // every record is in the partition its key hashes to
        for (j, p) in d.partitions.iter().enumerate() {
            assert!(p.iter().all(|r| partition_of(r.key, 7) == j));
        }
    }

    #[test]
    fn copartitioned_inputs_align() {
        let a = Dataset::from_records("a", recs(&[1, 2, 3, 4, 5]), 4, 64);
        let b = Dataset::from_records("b", recs(&[3, 4, 5, 6]), 4, 64);
        // same key lands in the same partition index in both datasets
        for j in 0..4 {
            for r in &a.partitions[j] {
                assert_eq!(partition_of(r.key, 4), j);
            }
            for r in &b.partitions[j] {
                assert_eq!(partition_of(r.key, 4), j);
            }
        }
    }

    #[test]
    fn byte_accounting() {
        let d = Dataset::from_records("t", recs(&[1, 2, 3]), 2, 100);
        assert_eq!(d.total_bytes(), 300);
    }

    #[test]
    fn overlap_fraction_definition() {
        // a: keys {1,2,3,4}, b: keys {3,4,5,6}; common {3,4}
        // participating = 2 (in a) + 2 (in b) = 4; total = 8 -> 0.5
        let a = Dataset::from_records("a", recs(&[1, 2, 3, 4]), 2, 64);
        let b = Dataset::from_records("b", recs(&[3, 4, 5, 6]), 2, 64);
        assert!((overlap_fraction(&[a, b]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn overlap_fraction_disjoint_and_identical() {
        let a = Dataset::from_records("a", recs(&[1, 2]), 2, 64);
        let b = Dataset::from_records("b", recs(&[3, 4]), 2, 64);
        assert_eq!(overlap_fraction(&[a.clone(), b]), 0.0);
        let c = a.clone();
        assert_eq!(overlap_fraction(&[a, c]), 1.0);
    }

    #[test]
    fn round_robin_split() {
        let d = Dataset::from_records_unpartitioned("t", recs(&[1, 2, 3, 4, 5]), 2, 64);
        assert_eq!(d.partitions[0].len(), 3);
        assert_eq!(d.partitions[1].len(), 2);
    }
}
