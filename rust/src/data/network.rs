//! CAIDA-like network-trace generator (paper §6.1). The real 2015 Chicago
//! backbone traces (115.5M TCP / 67.1M UDP / 2.8M ICMP two-tuple flows) are
//! not redistributable, so this generator reproduces the *structure* the
//! join experiment depends on: three protocol datasets keyed by
//! (src,dst)-flow, heavy-tailed flow sizes (packet/byte counts follow a
//! Zipf-like law on backbone links), a small host population generating
//! most flows, and a small cross-protocol key overlap (flows that appear in
//! TCP *and* UDP *and* ICMP — the paper's query joins all three).
//!
//! Default scale is 1/100 of CAIDA's counts; both scale and overlap are
//! configurable.

use super::{Dataset, Record};
use crate::relation::{ColumnType, Relation, Schema, Value};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct NetworkSpec {
    pub tcp_flows: u64,
    pub udp_flows: u64,
    pub icmp_flows: u64,
    /// Flows present in all three protocol datasets.
    pub common_flows: u64,
    /// Distinct host population (drives flow-key reuse / skew).
    pub hosts: u64,
    pub partitions: usize,
    pub seed: u64,
}

impl Default for NetworkSpec {
    fn default() -> Self {
        Self {
            // 1/1000 of CAIDA 2015 equinix-chicago dirA
            tcp_flows: 115_472,
            udp_flows: 67_098,
            icmp_flows: 2_801,
            common_flows: 1_400,
            hosts: 20_000,
            partitions: 8,
            seed: 2015,
        }
    }
}

/// Bytes of one flow record on the wire (two IPs, ports, proto, counters).
pub const FLOW_BYTES: u64 = 48;

/// A (src,dst) two-tuple flow key. Hosts are drawn Zipf so a few talkers
/// dominate — the skew the paper observes ("dataset distributed quite
/// uniformly" only at the *partition* level).
fn flow_key(r: &mut Rng, hosts: u64) -> u64 {
    let src = r.zipf(hosts, 1.05);
    let dst = r.zipf(hosts, 1.05);
    (src << 32) | (dst & 0xFFFF_FFFF)
}

/// Heavy-tailed flow size (bytes): log-normal-ish body with a Pareto tail.
fn flow_size(r: &mut Rng) -> f64 {
    let body = (40.0 + r.exponential(1200.0)).min(1.5e6);
    if r.f64() < 0.02 {
        body * (1.0 + r.exponential(50.0)) // elephant flows
    } else {
        body
    }
}

/// Generate the three protocol datasets: [TCP, UDP, ICMP].
pub fn generate(spec: &NetworkSpec) -> Vec<Dataset> {
    let mut rng = Rng::new(spec.seed);
    // the cross-protocol common flows (e.g. hosts doing TCP+UDP+ICMP)
    let mut common = Vec::with_capacity(spec.common_flows as usize);
    {
        let mut r = rng.fork(0xC0FFEE);
        let mut seen = std::collections::HashSet::new();
        while (common.len() as u64) < spec.common_flows {
            let k = flow_key(&mut r, spec.hosts) | (1 << 63);
            if seen.insert(k) {
                common.push(k);
            }
        }
    }

    let counts = [spec.tcp_flows, spec.udp_flows, spec.icmp_flows];
    let names = ["tcp", "udp", "icmp"];
    let mut out = Vec::with_capacity(3);
    for (i, (&n, name)) in counts.iter().zip(names).enumerate() {
        let mut r = rng.fork(i as u64 + 1);
        let mut records = Vec::with_capacity(n as usize);
        for &k in &common {
            records.push(Record::new(k, flow_size(&mut r)));
        }
        // protocol-private flows: tag with protocol id so pools stay
        // disjoint across protocols (a real flow key collision across
        // protocols is exactly the "common" population we model above)
        while (records.len() as u64) < n {
            let k = (flow_key(&mut r, spec.hosts) & !(0b11 << 61)) | ((i as u64 + 1) << 61);
            records.push(Record::new(k, flow_size(&mut r)));
        }
        out.push(Dataset::from_records_unpartitioned(
            name,
            records,
            spec.partitions,
            FLOW_BYTES,
        ));
    }
    out
}

/// Generate `[tcp, udp, icmp]` as typed relations:
/// `proto(flow, src, dst, bytes, packets)`. The `(flow, bytes)`
/// projection matches [`generate`]'s datasets row for row; src/dst are
/// decoded from the flow key, packets derived from the byte count (~600B
/// MTU-ish packets, at least 1).
pub fn generate_relations(spec: &NetworkSpec) -> Vec<Relation> {
    let schema = Schema::new(vec![
        ("flow", ColumnType::Key),
        ("src", ColumnType::Int),
        ("dst", ColumnType::Int),
        ("bytes", ColumnType::Float),
        ("packets", ColumnType::Float),
    ]);
    generate(spec)
        .into_iter()
        .map(|d| Relation {
            name: d.name.clone(),
            schema: schema.clone(),
            // preserve the dataset's partition layout so the (flow,
            // bytes) projection matches the legacy generator row for row
            partitions: d
                .partitions
                .iter()
                .map(|p| {
                    p.iter()
                        .map(|rec| {
                            vec![
                                Value::Key(rec.key),
                                Value::Int((rec.key >> 32) as i64),
                                Value::Int((rec.key & 0xFFFF_FFFF) as i64),
                                Value::Float(rec.value),
                                Value::Float((rec.value / 600.0).ceil().max(1.0)),
                            ]
                        })
                        .collect()
                })
                .collect(),
            row_bytes: FLOW_BYTES,
            degenerate: false,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::overlap_fraction;

    #[test]
    fn cardinalities() {
        let ds = generate(&NetworkSpec::default());
        assert_eq!(ds.len(), 3);
        assert_eq!(ds[0].len(), 115_472);
        assert_eq!(ds[1].len(), 67_098);
        assert_eq!(ds[2].len(), 2_801);
    }

    #[test]
    fn common_flows_present_in_all() {
        let spec = NetworkSpec {
            tcp_flows: 5000,
            udp_flows: 3000,
            icmp_flows: 1000,
            common_flows: 200,
            ..Default::default()
        };
        let ds = generate(&spec);
        let mut inter = ds[0].distinct_keys();
        for d in &ds[1..] {
            let keys = d.distinct_keys();
            inter.retain(|k| keys.contains(k));
        }
        assert_eq!(inter.len(), 200);
    }

    #[test]
    fn overlap_small_like_paper() {
        let ds = generate(&NetworkSpec::default());
        let f = overlap_fraction(&ds);
        assert!(f > 0.0 && f < 0.1, "overlap {f}");
    }

    #[test]
    fn flow_sizes_heavy_tailed() {
        let mut r = Rng::new(5);
        let sizes: Vec<f64> = (0..50_000).map(|_| flow_size(&mut r)).collect();
        let mean = sizes.iter().sum::<f64>() / sizes.len() as f64;
        let mut sorted = sizes.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sizes.len() / 2];
        assert!(mean > 1.5 * median, "mean {mean} median {median}");
        assert!(sizes.iter().all(|&s| s >= 40.0));
    }

    #[test]
    fn deterministic() {
        let a = generate(&NetworkSpec::default());
        let b = generate(&NetworkSpec::default());
        assert_eq!(a[0].partitions[0], b[0].partitions[0]);
    }

    #[test]
    fn relations_mirror_datasets() {
        let spec = NetworkSpec {
            tcp_flows: 2000,
            udp_flows: 1000,
            icmp_flows: 500,
            common_flows: 50,
            ..Default::default()
        };
        let rels = generate_relations(&spec);
        let ds = generate(&spec);
        assert_eq!(rels.len(), 3);
        for (r, d) in rels.iter().zip(&ds) {
            assert_eq!(r.len(), d.len());
            assert_eq!(r.name, d.name);
            for (row, rec) in r.iter().zip(d.iter()) {
                assert_eq!(row[0].as_key(), Some(rec.key));
                assert_eq!(row[3].as_f64(), Some(rec.value));
                assert!(row[4].as_f64().unwrap() >= 1.0);
            }
        }
    }

    #[test]
    fn key_skew_exists() {
        // zipf hosts -> some flow keys repeat across records
        let ds = generate(&NetworkSpec {
            tcp_flows: 50_000,
            ..Default::default()
        });
        let distinct = ds[0].distinct_keys().len() as u64;
        assert!(distinct < ds[0].len(), "no key reuse at all?");
    }
}
