//! Per-group error bounds: the grouped result assembly that turns the
//! kernel's per-stratum aggregates into one `estimate ± CI` per group.
//!
//! The lowering pass made every stratum a composite `(join key, group)`
//! pair, so a group's estimate is simply the stratified estimator run
//! over *its* strata — the same CLT / Horvitz-Thompson machinery as the
//! ungrouped total, restricted to the group's slice. Strata are visited
//! in ascending composite-id order, so every f64 accumulation is
//! reproducible run-to-run and thread-count independent.

use super::lowering::GroupDict;
use super::Value;
use crate::query::AggFunc;
use crate::stats::{
    clt_avg, clt_stdev, clt_sum, exact_count, horvitz_thompson_sum, ApproxResult, EstimatorKind,
    StratumAgg,
};
use std::collections::HashMap;

/// Estimator dispatch over already-sorted stratum slices — shared by the
/// engine's scalar path ([`crate::coordinator`]) and the grouped assembly.
pub fn estimate_slice(
    func: AggFunc,
    sampled: bool,
    estimator: EstimatorKind,
    strata: &[StratumAgg],
    draws: &[f64],
    confidence: f64,
) -> ApproxResult {
    match (func, sampled, estimator) {
        (AggFunc::Count, _, _) => exact_count(strata, confidence),
        (AggFunc::Sum, true, EstimatorKind::HorvitzThompson) => {
            horvitz_thompson_sum(strata, draws, confidence)
        }
        (AggFunc::Sum, _, _) => clt_sum(strata, confidence),
        (AggFunc::Avg, _, _) => clt_avg(strata, confidence),
        (AggFunc::Stdev, _, _) => clt_stdev(strata, confidence),
    }
}

/// Per-group sampling ledger: what the estimate is based on.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GroupLedger {
    /// Composite (join key, group) strata contributing to this group.
    pub strata: u64,
    /// Σ B_i over the group's strata — the group's exact join-output
    /// cardinality (known from the filter stage even when sampled).
    pub population: f64,
    /// Σ b_i samples the estimate is based on.
    pub samples: u64,
}

/// One group's estimate with its confidence interval and ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupEstimate {
    pub group: Value,
    pub result: ApproxResult,
    pub ledger: GroupLedger,
}

/// One aggregate expression's per-group estimates, groups in sorted order.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupedAggregate {
    /// The aggregate's display label (alias or rendered call).
    pub label: String,
    pub func: AggFunc,
    pub groups: Vec<GroupEstimate>,
}

impl GroupedAggregate {
    /// The estimate for one group value, if present.
    pub fn group(&self, v: &Value) -> Option<&GroupEstimate> {
        self.groups.iter().find(|g| &g.group == v)
    }
}

/// The grouped half of a [`crate::coordinator::QueryOutcome`]: per-group
/// estimates for every aggregate of the SELECT list. Ungrouped relational
/// queries carry a single `*` group per aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupedApproxResult {
    /// The GROUP BY column; `None` for ungrouped multi-aggregate queries.
    pub group_column: Option<String>,
    pub aggregates: Vec<GroupedAggregate>,
}

impl GroupedApproxResult {
    pub fn aggregate(&self, label: &str) -> Option<&GroupedAggregate> {
        self.aggregates.iter().find(|a| a.label == label)
    }
}

/// Assemble one aggregate's per-group estimates from the kernel's
/// composite strata.
#[allow(clippy::too_many_arguments)]
pub fn assemble_grouped(
    dict: &GroupDict,
    label: String,
    func: AggFunc,
    sampled: bool,
    estimator: EstimatorKind,
    strata: &HashMap<u64, StratumAgg>,
    draws: &HashMap<u64, f64>,
    confidence: f64,
) -> GroupedAggregate {
    let mut groups = Vec::new();
    // one pass over the dictionary; BTreeMap keeps groups sorted
    for (gv, ids) in dict.ids_by_group() {
        // ascending composite ids -> deterministic accumulation order
        let mut svec = Vec::new();
        let mut dvec = Vec::new();
        for id in ids {
            if let Some(s) = strata.get(&id) {
                svec.push(*s);
                dvec.push(draws.get(&id).copied().unwrap_or(0.0));
            }
        }
        let result = estimate_slice(func, sampled, estimator, &svec, &dvec, confidence);
        let ledger = GroupLedger {
            strata: svec.len() as u64,
            population: svec.iter().map(|s| s.population).sum(),
            samples: svec.iter().map(|s| s.count as u64).sum(),
        };
        groups.push(GroupEstimate {
            group: gv,
            result,
            ledger,
        });
    }
    GroupedAggregate {
        label,
        func,
        groups,
    }
}

/// The single-`*`-group shape for ungrouped relational aggregates.
pub fn assemble_ungrouped(
    label: String,
    func: AggFunc,
    result: ApproxResult,
    strata: &HashMap<u64, StratumAgg>,
) -> GroupedAggregate {
    let ledger = GroupLedger {
        strata: strata.len() as u64,
        population: strata.values().map(|s| s.population).sum(),
        samples: strata.values().map(|s| s.count as u64).sum(),
    };
    GroupedAggregate {
        label,
        func,
        groups: vec![GroupEstimate {
            group: Value::Str("*".into()),
            result,
            ledger,
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dict() -> GroupDict {
        GroupDict {
            column: "g".into(),
            entries: vec![
                (1, Value::Int(10)),
                (1, Value::Int(20)),
                (2, Value::Int(10)),
            ],
        }
    }

    fn stratum(population: f64, values: &[f64]) -> StratumAgg {
        let mut s = StratumAgg {
            population,
            ..Default::default()
        };
        for &v in values {
            s.push(v);
        }
        s
    }

    #[test]
    fn exact_grouped_sums() {
        // full samples (b == B): CLT bound is 0 and the sum is exact
        let mut strata = HashMap::new();
        strata.insert(0u64, stratum(2.0, &[1.0, 2.0]));
        strata.insert(1u64, stratum(1.0, &[5.0]));
        strata.insert(2u64, stratum(2.0, &[10.0, 10.0]));
        let agg = assemble_grouped(
            &dict(),
            "SUM".into(),
            AggFunc::Sum,
            false,
            EstimatorKind::Clt,
            &strata,
            &HashMap::new(),
            0.95,
        );
        assert_eq!(agg.groups.len(), 2);
        let g10 = agg.group(&Value::Int(10)).unwrap();
        assert_eq!(g10.result.estimate, 23.0); // ids 0 and 2
        assert_eq!(g10.result.error_bound, 0.0);
        assert_eq!(g10.ledger.strata, 2);
        assert_eq!(g10.ledger.population, 4.0);
        let g20 = agg.group(&Value::Int(20)).unwrap();
        assert_eq!(g20.result.estimate, 5.0);
        assert_eq!(g20.ledger.samples, 1);
    }

    #[test]
    fn sampled_group_scales_by_population() {
        // stratum of 10 edges, 2 sampled with mean 3 -> estimate 30
        let mut strata = HashMap::new();
        strata.insert(0u64, stratum(10.0, &[2.0, 4.0]));
        let agg = assemble_grouped(
            &dict(),
            "SUM".into(),
            AggFunc::Sum,
            true,
            EstimatorKind::Clt,
            &strata,
            &HashMap::new(),
            0.95,
        );
        let g10 = agg.group(&Value::Int(10)).unwrap();
        assert_eq!(g10.result.estimate, 30.0);
        assert!(g10.result.error_bound > 0.0);
        // group 20 has no surviving strata -> zero estimate, zero ledger
        let g20 = agg.group(&Value::Int(20)).unwrap();
        assert_eq!(g20.result.estimate, 0.0);
        assert_eq!(g20.ledger.strata, 0);
    }

    #[test]
    fn ht_grouped_uses_draws() {
        let mut strata = HashMap::new();
        // dedup sample of 1 distinct edge from a 1-edge stratum
        strata.insert(1u64, stratum(1.0, &[7.0]));
        let mut draws = HashMap::new();
        draws.insert(1u64, 3.0);
        let agg = assemble_grouped(
            &dict(),
            "SUM".into(),
            AggFunc::Sum,
            true,
            EstimatorKind::HorvitzThompson,
            &strata,
            &draws,
            0.95,
        );
        let g20 = agg.group(&Value::Int(20)).unwrap();
        // pi = 1 for B=1 -> estimate exactly 7
        assert_eq!(g20.result.estimate, 7.0);
    }

    #[test]
    fn ungrouped_wrapper_shape() {
        let mut strata = HashMap::new();
        strata.insert(5u64, stratum(2.0, &[1.0, 1.0]));
        let res = estimate_slice(
            AggFunc::Sum,
            false,
            EstimatorKind::Clt,
            &[strata[&5u64]],
            &[0.0],
            0.95,
        );
        let agg = assemble_ungrouped("SUM(a.v)".into(), AggFunc::Sum, res, &strata);
        assert_eq!(agg.groups.len(), 1);
        assert_eq!(agg.groups[0].group, Value::Str("*".into()));
        assert_eq!(agg.groups[0].ledger.population, 2.0);
    }
}
