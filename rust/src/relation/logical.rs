//! The logical plan above the join kernel:
//! `scan → filter(Predicate) → equi-join(attr) → group_by → aggregate`.
//!
//! The plan is deliberately small — it captures exactly the query shapes
//! the paper's case studies use (grouped, filtered aggregations over an
//! n-way single-attribute equi-join) and nothing the kernel cannot
//! execute. [`super::lowering`] turns it into kernel inputs.

use crate::join::CombineOp;
use crate::query::{AggFunc, Query};
use std::fmt;

/// A (possibly table-qualified) column reference. Unqualified references
/// resolve at lowering time by searching every scanned relation's schema
/// (ambiguity is an error).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ColumnRef {
    pub table: Option<String>,
    pub column: String,
}

impl ColumnRef {
    pub fn qualified(table: impl Into<String>, column: impl Into<String>) -> Self {
        Self {
            table: Some(table.into()),
            column: column.into(),
        }
    }

    pub fn bare(column: impl Into<String>) -> Self {
        Self {
            table: None,
            column: column.into(),
        }
    }
}

impl fmt::Display for ColumnRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.table {
            Some(t) => write!(f, "{t}.{}", self.column),
            None => write!(f, "{}", self.column),
        }
    }
}

/// Comparison operators WHERE predicates support.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    pub fn symbol(&self) -> &'static str {
        match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Eq => "=",
            CmpOp::Ne => "!=",
        }
    }

    pub fn eval(&self, lhs: f64, rhs: f64) -> bool {
        match self {
            CmpOp::Lt => lhs < rhs,
            CmpOp::Le => lhs <= rhs,
            CmpOp::Gt => lhs > rhs,
            CmpOp::Ge => lhs >= rhs,
            CmpOp::Eq => lhs == rhs,
            CmpOp::Ne => lhs != rhs,
        }
    }
}

/// One pushable selection predicate: `column <op> literal`. Predicates
/// compare numerically; the lowering pass rejects string columns.
#[derive(Clone, Debug, PartialEq)]
pub struct Predicate {
    pub column: ColumnRef,
    pub op: CmpOp,
    pub literal: f64,
}

impl fmt::Display for Predicate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {} {}", self.column, self.op.symbol(), self.literal)
    }
}

/// One aggregate expression of the SELECT list:
/// `FUNC(t1.c1 [+|*] t2.c2 ...) [AS alias]`, or `COUNT(*)` (empty terms).
#[derive(Clone, Debug, PartialEq)]
pub struct AggExpr {
    pub func: AggFunc,
    /// How the per-input values combine inside the aggregate.
    pub combine: CombineOp,
    /// The value column of each participating table. Tables absent from
    /// the expression contribute the combine op's neutral element.
    pub terms: Vec<ColumnRef>,
    pub alias: Option<String>,
}

impl AggExpr {
    /// COUNT(*) — population-exact, values are markers.
    pub fn count_star() -> Self {
        Self {
            func: AggFunc::Count,
            combine: CombineOp::Left,
            terms: Vec::new(),
            alias: None,
        }
    }

    /// The display label: the alias when given, else the rendered call.
    pub fn label(&self) -> String {
        if let Some(a) = &self.alias {
            return a.clone();
        }
        self.render()
    }

    /// The rendered call, e.g. `SUM(a.v + b.v)`.
    pub fn render(&self) -> String {
        if self.terms.is_empty() {
            return format!("{}(*)", self.func.name());
        }
        let sep = match self.combine {
            CombineOp::Product => " * ",
            _ => " + ",
        };
        format!(
            "{}({})",
            self.func.name(),
            self.terms
                .iter()
                .map(|t| t.to_string())
                .collect::<Vec<_>>()
                .join(sep)
        )
    }
}

impl fmt::Display for AggExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.render())?;
        if let Some(a) = &self.alias {
            write!(f, " AS {a}")?;
        }
        Ok(())
    }
}

/// The logical plan of one relational query. Built from a parsed
/// [`Query`]; consumed by [`super::lowering::lower`].
#[derive(Clone, Debug)]
pub struct LogicalPlan {
    /// Scanned relations, in FROM order.
    pub tables: Vec<String>,
    /// The single equi-join attribute (the paper's A).
    pub join_attr: String,
    /// Selection predicates, pushed below the join at lowering time.
    pub predicates: Vec<Predicate>,
    /// GROUP BY column, if any.
    pub group_by: Option<ColumnRef>,
    /// Aggregate expressions of the SELECT list, in order.
    pub aggregates: Vec<AggExpr>,
}

impl LogicalPlan {
    pub fn from_query(query: &Query) -> Self {
        Self {
            tables: query.tables.clone(),
            join_attr: query.join_attr.clone(),
            predicates: query.predicates.clone(),
            group_by: query.group_by.clone(),
            aggregates: query.aggregates.clone(),
        }
    }

    /// EXPLAIN-style rendering of the operator tree, leaves first.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for t in &self.tables {
            let preds: Vec<String> = self
                .predicates
                .iter()
                .filter(|p| p.column.table.as_deref() == Some(t.as_str()))
                .map(|p| p.to_string())
                .collect();
            if preds.is_empty() {
                out.push_str(&format!("    scan {t}\n"));
            } else {
                out.push_str(&format!("    scan {t} -> filter({})\n", preds.join(" AND ")));
            }
        }
        out.push_str(&format!(
            "    equi-join on {} ({} inputs)\n",
            self.join_attr,
            self.tables.len()
        ));
        if let Some(g) = &self.group_by {
            out.push_str(&format!("    group_by {g}\n"));
        }
        out.push_str(&format!(
            "    aggregate [{}]\n",
            self.aggregates
                .iter()
                .map(|a| a.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmp_ops_evaluate() {
        assert!(CmpOp::Gt.eval(2.0, 1.0));
        assert!(!CmpOp::Gt.eval(1.0, 1.0));
        assert!(CmpOp::Ge.eval(1.0, 1.0));
        assert!(CmpOp::Lt.eval(0.0, 1.0));
        assert!(CmpOp::Le.eval(1.0, 1.0));
        assert!(CmpOp::Eq.eval(3.0, 3.0));
        assert!(CmpOp::Ne.eval(3.0, 4.0));
    }

    #[test]
    fn display_shapes() {
        let p = Predicate {
            column: ColumnRef::qualified("a", "x"),
            op: CmpOp::Gt,
            literal: 5.0,
        };
        assert_eq!(p.to_string(), "a.x > 5");
        let e = AggExpr {
            func: AggFunc::Sum,
            combine: CombineOp::Sum,
            terms: vec![ColumnRef::qualified("a", "v"), ColumnRef::qualified("b", "v")],
            alias: Some("total".into()),
        };
        assert_eq!(e.render(), "SUM(a.v + b.v)");
        assert_eq!(e.label(), "total");
        assert_eq!(AggExpr::count_star().render(), "COUNT(*)");
        assert_eq!(ColumnRef::bare("g").to_string(), "g");
    }
}
