//! Lowering: from a [`LogicalPlan`] over typed [`Relation`]s down to the
//! bit-deterministic (key64, f64) join kernel.
//!
//! The pass does three things, all *before* a byte moves:
//!
//! 1. **Predicate pushdown** — WHERE predicates over non-join columns are
//!    evaluated against the scanned rows first, so the Bloom sketching
//!    stage sees post-filter keys only (fewer keys → smaller, tighter
//!    join filter, fewer shuffled survivors).
//! 2. **Projection** — each input is projected to the kernel's
//!    `(key64, value)` record per aggregate expression. Tables absent
//!    from an expression contribute the combine op's neutral element.
//! 3. **Group encoding** — GROUP BY maps onto the existing per-stratum
//!    machinery: the stratum key becomes a dense composite id for the
//!    pair `(join key, group value)`. The grouping table keys each row by
//!    its own group; every other input is replicated once per group its
//!    join key co-occurs with (usually 1 — the replication factor is the
//!    number of distinct groups per join key). Rows whose key never
//!    appears in the grouping input are dropped at lowering time — a
//!    semi-join prefilter, since they cannot join anyway. The kernel then
//!    samples *per (join key, group)* stratum, which is exactly what
//!    per-group CLT / Horvitz-Thompson confidence intervals need.
//!
//! The dictionary is built from sorted maps, so composite ids — and with
//! them every downstream sampling decision — are bit-identical for any
//! thread count.

use super::logical::{AggExpr, ColumnRef, LogicalPlan, Predicate};
use super::{ColumnType, Relation, Value};
use crate::data::{Dataset, Record};
use crate::join::{CombineOp, JoinError};
use std::collections::{BTreeMap, BTreeSet};

/// The composite-stratum dictionary of a grouped query: dense stratum id
/// → (join key, group value), in sorted (key, group) order.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupDict {
    /// Display name of the group column.
    pub column: String,
    /// entries[id] = (join key, group value).
    pub entries: Vec<(u64, Value)>,
}

impl GroupDict {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The group value of one composite stratum id.
    pub fn group_of(&self, id: u64) -> Option<&Value> {
        self.entries.get(id as usize).map(|(_, g)| g)
    }

    /// Sorted distinct group values.
    pub fn group_values(&self) -> Vec<Value> {
        let set: BTreeSet<&Value> = self.entries.iter().map(|(_, g)| g).collect();
        set.into_iter().cloned().collect()
    }

    /// Composite ids belonging to one group, ascending.
    pub fn ids_of_group(&self, group: &Value) -> Vec<u64> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, (_, g))| g == group)
            .map(|(i, _)| i as u64)
            .collect()
    }

    /// All groups with their composite ids (ascending), in one pass —
    /// what per-group assembly iterates so high-cardinality GROUP BY
    /// stays O(strata), not O(groups × strata).
    pub fn ids_by_group(&self) -> BTreeMap<Value, Vec<u64>> {
        let mut out: BTreeMap<Value, Vec<u64>> = BTreeMap::new();
        for (i, (_, g)) in self.entries.iter().enumerate() {
            out.entry(g.clone()).or_default().push(i as u64);
        }
        out
    }
}

/// One pushed-down predicate, with its measured selectivity.
#[derive(Clone, Debug)]
pub struct PushedPredicate {
    pub table: String,
    pub predicate: String,
    pub rows_before: u64,
    pub rows_after: u64,
}

/// One input's projection onto the kernel record.
#[derive(Clone, Debug)]
pub struct ProjectionInfo {
    pub table: String,
    /// What the kernel key encodes (`k` or `(k, g) composite`).
    pub key: String,
    /// The first aggregate's value expression for this input.
    pub value: String,
    pub rows: u64,
}

/// GROUP BY lowering accounting.
#[derive(Clone, Debug)]
pub struct GroupLoweringInfo {
    pub column: String,
    pub groups: u64,
    /// Composite (join key, group) strata.
    pub strata: u64,
    /// Extra records created by replicating non-grouping inputs.
    pub replicated_rows: u64,
    /// Records dropped because their key never joins the grouping input.
    pub dropped_rows: u64,
}

/// Everything `JoinPlan::explain()` shows about the relational lowering.
#[derive(Clone, Debug)]
pub struct LoweringInfo {
    /// The logical operator tree, rendered.
    pub plan: String,
    pub pushed: Vec<PushedPredicate>,
    pub projections: Vec<ProjectionInfo>,
    pub group: Option<GroupLoweringInfo>,
    pub aggregates: Vec<String>,
}

impl LoweringInfo {
    /// The explain section appended by [`crate::join::JoinPlan::explain`].
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("  relational lowering:\n");
        out.push_str(&self.plan);
        for p in &self.pushed {
            out.push_str(&format!(
                "    pushed down below join: {} [{}] ({} -> {} rows)\n",
                p.predicate, p.table, p.rows_before, p.rows_after
            ));
        }
        for pr in &self.projections {
            out.push_str(&format!(
                "    kernel input {}: key={} value={} ({} records)\n",
                pr.table, pr.key, pr.value, pr.rows
            ));
        }
        if let Some(g) = &self.group {
            out.push_str(&format!(
                "    group_by {}: {} groups -> {} composite strata \
                 (+{} replicated, -{} non-joining records)\n",
                g.column, g.groups, g.strata, g.replicated_rows, g.dropped_rows
            ));
        }
        if self.aggregates.len() > 1 {
            out.push_str(&format!(
                "    aggregates: {}\n",
                self.aggregates.join(", ")
            ));
        }
        out
    }
}

/// The lowered query: one kernel input set per aggregate expression
/// (identical keys, per-expression values), the effective kernel combine
/// op per aggregate, and the group dictionary when grouped.
#[derive(Clone, Debug)]
pub struct LoweredQuery {
    pub per_aggregate: Vec<Vec<Dataset>>,
    /// Effective kernel combine op per aggregate (single-column terms
    /// lower to Sum-with-neutral-fill so any table can own the column).
    pub ops: Vec<CombineOp>,
    pub groups: Option<GroupDict>,
    pub info: LoweringInfo,
}

fn runtime_err(msg: String) -> JoinError {
    JoinError::Runtime(msg)
}

/// Resolve a possibly-bare column against the scanned relations: returns
/// (table index, column index). Bare references match strict schema
/// columns only and must be unambiguous.
pub(crate) fn resolve_column(
    col: &ColumnRef,
    tables: &[String],
    relations: &[&Relation],
    join_attr: &str,
) -> Result<(usize, usize), JoinError> {
    if let Some(t) = &col.table {
        let ti = tables
            .iter()
            .position(|x| x.eq_ignore_ascii_case(t))
            .ok_or_else(|| runtime_err(format!("unknown table {t} in {col}")))?;
        let ci = relations[ti]
            .resolve(&col.column, join_attr)
            .ok_or_else(|| {
                runtime_err(format!(
                    "column {col} not found (table {} has: {})",
                    tables[ti],
                    relations[ti].schema.describe()
                ))
            })?;
        return Ok((ti, ci));
    }
    let mut hits: Vec<(usize, usize)> = Vec::new();
    for (ti, r) in relations.iter().enumerate() {
        if let Some(ci) = r.schema.col(&col.column) {
            hits.push((ti, ci));
        }
    }
    match hits.len() {
        1 => Ok(hits[0]),
        0 => Err(runtime_err(format!(
            "column {col} not found in any scanned relation"
        ))),
        _ => Err(runtime_err(format!(
            "column {col} is ambiguous (matches {})",
            hits.iter()
                .map(|&(ti, _)| tables[ti].clone())
                .collect::<Vec<_>>()
                .join(", ")
        ))),
    }
}

/// Canonicalize a group cell by its column type so `Key(5)` and `Int(5)`
/// land in the same group.
pub(crate) fn canon_group(cell: &Value, ty: ColumnType) -> Value {
    match ty {
        ColumnType::Key => cell
            .as_key()
            .map(Value::Key)
            .unwrap_or_else(|| cell.clone()),
        ColumnType::Int => match cell.as_key() {
            Some(k) => Value::Int(k as i64),
            None => cell.clone(),
        },
        _ => cell.clone(),
    }
}

/// Lower a logical plan over `relations` (FROM order, borrowed — the
/// pass only reads them) onto kernel inputs.
pub fn lower(
    plan: &LogicalPlan,
    relations: &[&Relation],
    partitions: usize,
) -> Result<LoweredQuery, JoinError> {
    assert_eq!(plan.tables.len(), relations.len());
    assert!(partitions > 0);
    let n = relations.len();
    if plan.aggregates.is_empty() {
        return Err(runtime_err("query has no aggregates".into()));
    }

    // join-key column per input
    let mut key_cols = Vec::with_capacity(n);
    for (ti, r) in relations.iter().enumerate() {
        let ci = r.resolve(&plan.join_attr, &plan.join_attr).ok_or_else(|| {
            runtime_err(format!(
                "join attribute {} not found in table {} ({})",
                plan.join_attr,
                plan.tables[ti],
                r.schema.describe()
            ))
        })?;
        let ty = r.schema.columns[ci].ty;
        if !matches!(ty, ColumnType::Key | ColumnType::Int) {
            return Err(runtime_err(format!(
                "join attribute {}.{} must be a KEY/INT column, is {}",
                plan.tables[ti],
                plan.join_attr,
                ty.name()
            )));
        }
        key_cols.push(ci);
    }

    // ---- 1. predicate pushdown: filter each scan before anything else
    let mut per_table_preds: Vec<Vec<(usize, &Predicate)>> = vec![Vec::new(); n];
    for p in &plan.predicates {
        let (ti, ci) = resolve_column(&p.column, &plan.tables, relations, &plan.join_attr)?;
        if relations[ti].schema.columns[ci].ty == ColumnType::Str {
            return Err(runtime_err(format!(
                "predicate {p} compares a STR column numerically"
            )));
        }
        per_table_preds[ti].push((ci, p));
    }
    let mut filtered: Vec<Vec<&super::Row>> = Vec::with_capacity(n);
    let mut pushed = Vec::new();
    for (ti, r) in relations.iter().enumerate() {
        let rows_before = r.len();
        let keep: Vec<&super::Row> = r
            .iter()
            .filter(|row| {
                per_table_preds[ti].iter().all(|&(ci, p)| {
                    row[ci]
                        .as_f64()
                        .map(|v| p.op.eval(v, p.literal))
                        .unwrap_or(false)
                })
            })
            .collect();
        for &(_, p) in &per_table_preds[ti] {
            pushed.push(PushedPredicate {
                table: plan.tables[ti].clone(),
                predicate: p.to_string(),
                rows_before,
                rows_after: keep.len() as u64,
            });
        }
        filtered.push(keep);
    }

    // ---- 3a. group dictionary (built before projection: every input's
    // stratum key depends on it)
    struct GroupState {
        /// FROM index of the grouping table.
        table: usize,
        /// Column index of the group key within it.
        col: usize,
        ty: ColumnType,
        dict: GroupDict,
        /// (join key, group value) -> composite stratum id.
        ids: BTreeMap<(u64, Value), u64>,
    }
    let mut group_state: Option<GroupState> = None;
    let mut replicated_rows = 0u64;
    let mut dropped_rows = 0u64;
    if let Some(g) = &plan.group_by {
        let (gt, gc) = resolve_column(g, &plan.tables, relations, &plan.join_attr)?;
        let gty = relations[gt].schema.columns[gc].ty;
        // join key -> distinct groups, in sorted order
        let mut by_key: BTreeMap<u64, BTreeSet<Value>> = BTreeMap::new();
        for row in &filtered[gt] {
            let Some(k) = row[key_cols[gt]].as_key() else {
                return Err(runtime_err(format!(
                    "join key {}.{} has a non-integral value",
                    plan.tables[gt], plan.join_attr
                )));
            };
            by_key
                .entry(k)
                .or_default()
                .insert(canon_group(&row[gc], gty));
        }
        let mut entries = Vec::new();
        let mut ids = BTreeMap::new();
        for (k, groups) in &by_key {
            for gv in groups {
                ids.insert((*k, gv.clone()), entries.len() as u64);
                entries.push((*k, gv.clone()));
            }
        }
        group_state = Some(GroupState {
            table: gt,
            col: gc,
            ty: gty,
            dict: GroupDict {
                column: g.to_string(),
                entries,
            },
            ids,
        });
    }

    // ---- 2 + 3b. project each input per aggregate expression
    let mut per_aggregate = Vec::with_capacity(plan.aggregates.len());
    let mut ops = Vec::with_capacity(plan.aggregates.len());
    let mut projections: Vec<ProjectionInfo> = Vec::new();
    for (ai, agg) in plan.aggregates.iter().enumerate() {
        let (op, fill) = effective_op(agg);
        // value column per input (None -> neutral fill)
        let mut value_cols: Vec<Option<usize>> = vec![None; n];
        for term in &agg.terms {
            let (ti, ci) =
                resolve_column(term, &plan.tables, relations, &plan.join_attr)?;
            if value_cols[ti].is_some() {
                return Err(runtime_err(format!(
                    "aggregate {} references table {} twice",
                    agg.render(),
                    plan.tables[ti]
                )));
            }
            value_cols[ti] = Some(ci);
        }
        let mut datasets = Vec::with_capacity(n);
        for ti in 0..n {
            let r = &relations[ti];
            let kc = key_cols[ti];
            let mut records = Vec::with_capacity(filtered[ti].len());
            for row in &filtered[ti] {
                let Some(k) = row[kc].as_key() else {
                    return Err(runtime_err(format!(
                        "join key {}.{} has a non-integral value",
                        plan.tables[ti], plan.join_attr
                    )));
                };
                let v = match value_cols[ti] {
                    Some(ci) => row[ci].as_f64().ok_or_else(|| {
                        runtime_err(format!(
                            "aggregate {} reads non-numeric column {}.{}",
                            agg.render(),
                            plan.tables[ti],
                            r.schema.columns[ci].name
                        ))
                    })?,
                    None => fill,
                };
                match &group_state {
                    Some(gs) if gs.table == ti => {
                        let gv = canon_group(&row[gs.col], gs.ty);
                        // the dictionary was built from exactly these rows
                        let id = gs.ids[&(k, gv)];
                        records.push(Record::new(id, v));
                    }
                    Some(gs) => {
                        let ids = &gs.ids;
                        // replicate per group this key co-occurs with;
                        // keys absent from the grouping input cannot join
                        use std::ops::Bound;
                        let lo = Bound::Included((k, Value::Key(0)));
                        let hi = match k.checked_add(1) {
                            Some(next) => Bound::Excluded((next, Value::Key(0))),
                            None => Bound::Unbounded,
                        };
                        let mut hit = false;
                        for (&(ik, _), &id) in ids.range((lo, hi)) {
                            debug_assert_eq!(ik, k);
                            if hit && ai == 0 {
                                replicated_rows += 1;
                            }
                            hit = true;
                            records.push(Record::new(id, v));
                        }
                        if !hit && ai == 0 {
                            dropped_rows += 1;
                        }
                    }
                    None => records.push(Record::new(k, v)),
                }
            }
            if ai == 0 {
                projections.push(ProjectionInfo {
                    table: plan.tables[ti].clone(),
                    key: match &plan.group_by {
                        Some(g) => format!("({}, {g}) composite", plan.join_attr),
                        None => plan.join_attr.clone(),
                    },
                    value: match value_cols[ti] {
                        Some(ci) => {
                            format!("{}.{}", plan.tables[ti], r.schema.columns[ci].name)
                        }
                        None => fill.to_string(),
                    },
                    rows: records.len() as u64,
                });
            }
            datasets.push(Dataset::from_records_unpartitioned(
                plan.tables[ti].clone(),
                records,
                partitions,
                r.row_bytes,
            ));
        }
        per_aggregate.push(datasets);
        ops.push(op);
    }

    let (groups, group_info) = match group_state {
        Some(gs) => {
            let info = GroupLoweringInfo {
                column: gs.dict.column.clone(),
                groups: gs.dict.group_values().len() as u64,
                strata: gs.dict.len() as u64,
                replicated_rows,
                dropped_rows,
            };
            (Some(gs.dict), Some(info))
        }
        None => (None, None),
    };

    let info = LoweringInfo {
        plan: plan.render(),
        pushed,
        projections,
        group: group_info,
        aggregates: plan.aggregates.iter().map(|a| a.label()).collect(),
    };

    Ok(LoweredQuery {
        per_aggregate,
        ops,
        groups,
        info,
    })
}

/// The kernel combine op an aggregate expression lowers to, plus the
/// neutral fill value for inputs absent from the expression. Single-term
/// expressions lower to Sum-with-0-fill so *any* table can own the
/// column (legacy `CombineOp::Left` only reads input 0).
pub(crate) fn effective_op(agg: &AggExpr) -> (CombineOp, f64) {
    if agg.terms.is_empty() {
        // COUNT(*) — values are markers, the estimate is population-based
        return (CombineOp::Left, 1.0);
    }
    match agg.combine {
        CombineOp::Product => (CombineOp::Product, 1.0),
        _ => (CombineOp::Sum, 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::AggFunc;
    use crate::relation::{CmpOp, ColumnType, Schema};

    fn rel_a() -> Relation {
        // k, g, v, x
        let schema = Schema::new(vec![
            ("k", ColumnType::Key),
            ("g", ColumnType::Int),
            ("v", ColumnType::Float),
            ("x", ColumnType::Float),
        ]);
        let rows = vec![
            vec![Value::Key(1), Value::Int(10), Value::Float(1.0), Value::Float(5.0)],
            vec![Value::Key(1), Value::Int(20), Value::Float(2.0), Value::Float(1.0)],
            vec![Value::Key(2), Value::Int(10), Value::Float(3.0), Value::Float(9.0)],
            vec![Value::Key(3), Value::Int(30), Value::Float(4.0), Value::Float(9.0)],
        ];
        Relation::new("a", schema, rows, 2).unwrap()
    }

    fn rel_b() -> Relation {
        let schema = Schema::new(vec![("k", ColumnType::Key), ("w", ColumnType::Float)]);
        let rows = vec![
            vec![Value::Key(1), Value::Float(10.0)],
            vec![Value::Key(2), Value::Float(20.0)],
            vec![Value::Key(2), Value::Float(30.0)],
            vec![Value::Key(9), Value::Float(99.0)],
        ];
        Relation::new("b", schema, rows, 2).unwrap()
    }

    /// Lower over fresh rel_a/rel_b (lower borrows its relations).
    fn lower_ab(plan: &LogicalPlan) -> Result<LoweredQuery, JoinError> {
        let (a, b) = (rel_a(), rel_b());
        lower(plan, &[&a, &b], 2)
    }

    fn plan(predicates: Vec<Predicate>, group_by: Option<ColumnRef>) -> LogicalPlan {
        LogicalPlan {
            tables: vec!["a".into(), "b".into()],
            join_attr: "k".into(),
            predicates,
            group_by,
            aggregates: vec![AggExpr {
                func: AggFunc::Sum,
                combine: CombineOp::Sum,
                terms: vec![ColumnRef::qualified("a", "v"), ColumnRef::qualified("b", "w")],
                alias: None,
            }],
        }
    }

    #[test]
    fn ungrouped_projection_keys_by_join_attr() {
        let lowered = lower_ab(&plan(vec![], None)).unwrap();
        assert_eq!(lowered.per_aggregate.len(), 1);
        let ds = &lowered.per_aggregate[0];
        assert_eq!(ds[0].len(), 4);
        assert_eq!(ds[1].len(), 4);
        assert!(lowered.groups.is_none());
        assert_eq!(lowered.ops, vec![CombineOp::Sum]);
        // keys are the raw join keys
        let keys: std::collections::HashSet<u64> = ds[0].iter().map(|r| r.key).collect();
        assert_eq!(keys, [1, 2, 3].into_iter().collect());
    }

    #[test]
    fn pushdown_filters_before_projection() {
        let p = Predicate {
            column: ColumnRef::qualified("a", "x"),
            op: CmpOp::Gt,
            literal: 2.0,
        };
        let lowered = lower_ab(&plan(vec![p], None)).unwrap();
        // rows (1,20,...) with x=1.0 dropped pre-kernel
        assert_eq!(lowered.per_aggregate[0][0].len(), 3);
        assert_eq!(lowered.info.pushed.len(), 1);
        assert_eq!(lowered.info.pushed[0].rows_before, 4);
        assert_eq!(lowered.info.pushed[0].rows_after, 3);
        assert!(lowered.info.render().contains("pushed down below join"));
    }

    #[test]
    fn grouped_lowering_builds_composite_strata() {
        let lowered = lower_ab(&plan(vec![], Some(ColumnRef::qualified("a", "g"))))
        .unwrap();
        let dict = lowered.groups.as_ref().unwrap();
        // (1,10) (1,20) (2,10) (3,30) — sorted by (key, group)
        assert_eq!(dict.len(), 4);
        assert_eq!(dict.entries[0], (1, Value::Int(10)));
        assert_eq!(dict.entries[1], (1, Value::Int(20)));
        assert_eq!(dict.entries[2], (2, Value::Int(10)));
        assert_eq!(dict.entries[3], (3, Value::Int(30)));
        assert_eq!(dict.group_values(), vec![Value::Int(10), Value::Int(20), Value::Int(30)]);
        assert_eq!(dict.ids_of_group(&Value::Int(10)), vec![0, 2]);

        // b: key 1 appears with 2 groups -> replicated; key 9 dropped
        let b = &lowered.per_aggregate[0][1];
        assert_eq!(b.len(), 4); // 1 -> ids {0,1}; 2,2 -> id 2 twice
        let info = lowered.info.group.as_ref().unwrap();
        assert_eq!(info.replicated_rows, 1);
        assert_eq!(info.dropped_rows, 1);
        assert_eq!(info.groups, 3);
        assert_eq!(info.strata, 4);

        // the a side keys by its own (k, g) composite
        let a = &lowered.per_aggregate[0][0];
        let ids: Vec<u64> = {
            let mut v: Vec<u64> = a.iter().map(|r| r.key).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn grouped_join_matches_partitioned_exact_join() {
        // the composite-key lowering must preserve join semantics: the
        // exact grouped sums equal a hand-computed per-group join
        use crate::cluster::{SimCluster, TimeModel};
        use crate::join::native::native_join;
        let lowered = lower_ab(&plan(vec![], Some(ColumnRef::qualified("a", "g"))))
        .unwrap();
        let mut cluster = SimCluster::new(2, TimeModel::default());
        let run = native_join(
            &mut cluster,
            &lowered.per_aggregate[0],
            lowered.ops[0],
            u64::MAX,
        )
        .unwrap();
        let dict = lowered.groups.as_ref().unwrap();
        let mut by_group: BTreeMap<Value, f64> = BTreeMap::new();
        for (id, agg) in &run.strata {
            *by_group.entry(dict.group_of(*id).unwrap().clone()).or_default() += agg.sum;
        }
        // group 10: key1(a.v=1.0 x b.w=10) + key2(a.v=3 x {20,30})
        //   = (1+10) + (3+20)+(3+30) = 11 + 56 = 67
        // group 20: key1(a.v=2 x 10) = 12
        // group 30: key3 joins nothing = absent or 0
        assert_eq!(by_group.get(&Value::Int(10)).copied().unwrap_or(0.0), 67.0);
        assert_eq!(by_group.get(&Value::Int(20)).copied().unwrap_or(0.0), 12.0);
        assert_eq!(by_group.get(&Value::Int(30)).copied().unwrap_or(0.0), 0.0);
    }

    #[test]
    fn single_term_aggregate_lowers_to_sum_with_fill() {
        let mut p = plan(vec![], None);
        p.aggregates = vec![AggExpr {
            func: AggFunc::Sum,
            combine: CombineOp::Left,
            terms: vec![ColumnRef::qualified("b", "w")],
            alias: None,
        }];
        let lowered = lower_ab(&p).unwrap();
        assert_eq!(lowered.ops, vec![CombineOp::Sum]);
        // a contributes the neutral 0.0
        assert!(lowered.per_aggregate[0][0].iter().all(|r| r.value == 0.0));
        assert!(lowered.per_aggregate[0][1].iter().any(|r| r.value == 10.0));
    }

    #[test]
    fn multiple_aggregates_share_keys() {
        let mut p = plan(vec![], Some(ColumnRef::qualified("a", "g")));
        p.aggregates.push(AggExpr {
            func: AggFunc::Avg,
            combine: CombineOp::Left,
            terms: vec![ColumnRef::qualified("a", "x")],
            alias: Some("mean_x".into()),
        });
        let lowered = lower_ab(&p).unwrap();
        assert_eq!(lowered.per_aggregate.len(), 2);
        let keys = |ds: &Dataset| -> Vec<u64> {
            let mut v: Vec<u64> = ds.iter().map(|r| r.key).collect();
            v.sort_unstable();
            v
        };
        // identical stratum keys across aggregates -> identical sampling
        for ti in 0..2 {
            assert_eq!(
                keys(&lowered.per_aggregate[0][ti]),
                keys(&lowered.per_aggregate[1][ti])
            );
        }
    }

    #[test]
    fn resolution_errors_are_clean() {
        // unknown column
        let mut p = plan(vec![], None);
        p.aggregates[0].terms[0] = ColumnRef::qualified("a", "nope");
        assert!(matches!(
            lower_ab(&p),
            Err(JoinError::Runtime(_))
        ));
        // ambiguous bare column (k exists in both)
        let p = plan(
            vec![Predicate {
                column: ColumnRef::bare("k"),
                op: CmpOp::Gt,
                literal: 0.0,
            }],
            None,
        );
        let err = lower_ab(&p).unwrap_err();
        assert!(err.to_string().contains("ambiguous"), "{err}");
        // bare column resolving uniquely works
        let p = plan(
            vec![Predicate {
                column: ColumnRef::bare("x"),
                op: CmpOp::Gt,
                literal: 2.0,
            }],
            None,
        );
        assert!(lower_ab(&p).is_ok());
    }

    #[test]
    fn degenerate_relations_lower_like_datasets() {
        use crate::data::Record;
        let da = Dataset::from_records_unpartitioned(
            "a",
            vec![Record::new(1, 1.0), Record::new(2, 2.0)],
            2,
            64,
        );
        let db = Dataset::from_records_unpartitioned(
            "b",
            vec![Record::new(1, 10.0), Record::new(2, 20.0)],
            2,
            64,
        );
        let p = plan(vec![], None);
        let (ra, rb) = (Relation::from_dataset(&da), Relation::from_dataset(&db));
        let lowered = lower(&p, &[&ra, &rb], 2).unwrap();
        // free column names resolve: a.v -> value column, join attr k -> key
        let a = &lowered.per_aggregate[0][0];
        assert_eq!(a.len(), 2);
        assert!(a.iter().any(|r| r.key == 1 && r.value == 1.0));
    }
}
