//! The typed relational data model: multi-column [`Relation`]s with a
//! [`Schema`], the logical-plan layer above the (key64, f64) join kernel.
//!
//! The paper's case studies (TPC-H Q3-like queries, Netflix ratings,
//! network monitoring, §5) are grouped, filtered aggregations over wide
//! tuples — not `SUM(a.v + b.v)` over two-column records. This module is
//! the front half of that workload:
//!
//! * [`Schema`] / [`ColumnType`] / [`Value`] / [`Row`] — a minimal typed
//!   tuple model (join keys, ints, floats, strings).
//! * [`Relation`] — a named, partitioned multi-column table. A legacy
//!   [`crate::data::Dataset`] is the *degenerate* two-column relation
//!   (`Relation::from_dataset`), so every existing front end keeps
//!   working unchanged.
//! * [`logical`] — the logical plan: `scan → filter(Predicate) →
//!   equi-join(attr) → group_by(column) → aggregate([AggExpr...])`.
//! * [`lowering`] — the lowering pass onto the bit-deterministic join
//!   kernel: predicates are pushed below the join (Bloom sketching sees
//!   post-filter keys only), each input is projected to the kernel's
//!   `(key64, value)` pair per aggregate expression, and GROUP BY keys
//!   are mapped onto the per-stratum machinery via composite
//!   `(join key, group)` stratum ids — the kernel and the strategy inner
//!   loops are untouched.
//! * [`grouped`] — per-group estimates: one `estimate ± CI` per group
//!   from the same stratified CLT / Horvitz-Thompson estimators.

pub mod grouped;
pub mod logical;
pub mod lowering;

pub use grouped::{GroupEstimate, GroupLedger, GroupedAggregate, GroupedApproxResult};
pub use logical::{AggExpr, CmpOp, ColumnRef, LogicalPlan, Predicate};
pub use lowering::{lower, GroupDict, LoweredQuery, LoweringInfo};

use crate::data::Dataset;
use std::cmp::Ordering;
use std::fmt;

/// Column types the relational layer understands. `Key` columns are the
/// only legal equi-join attributes (the kernel joins on u64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColumnType {
    /// 64-bit join key.
    Key,
    /// Signed integer attribute (group keys, dates, categories).
    Int,
    /// f64 measure — what aggregate expressions consume.
    Float,
    /// String attribute (labels; group keys only).
    Str,
}

impl ColumnType {
    pub fn name(&self) -> &'static str {
        match self {
            ColumnType::Key => "KEY",
            ColumnType::Int => "INT",
            ColumnType::Float => "FLOAT",
            ColumnType::Str => "STR",
        }
    }

    /// Wire width used for shuffle byte accounting.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            ColumnType::Key | ColumnType::Int | ColumnType::Float => 8,
            ColumnType::Str => 16,
        }
    }
}

/// One typed cell. Equality and ordering are total (floats order via
/// `total_cmp`), so values can key deterministic BTree maps — the group
/// dictionary depends on that.
#[derive(Clone, Debug)]
pub enum Value {
    Key(u64),
    Int(i64),
    Float(f64),
    Str(String),
}

impl Value {
    fn tag(&self) -> u8 {
        match self {
            Value::Key(_) => 0,
            Value::Int(_) => 1,
            Value::Float(_) => 2,
            Value::Str(_) => 3,
        }
    }

    pub fn type_of(&self) -> ColumnType {
        match self {
            Value::Key(_) => ColumnType::Key,
            Value::Int(_) => ColumnType::Int,
            Value::Float(_) => ColumnType::Float,
            Value::Str(_) => ColumnType::Str,
        }
    }

    /// The u64 join key this value denotes, if it can be one.
    pub fn as_key(&self) -> Option<u64> {
        match self {
            Value::Key(k) => Some(*k),
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    /// Numeric view (predicates and measures); `None` for strings.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Key(k) => Some(*k as f64),
            Value::Int(i) => Some(*i as f64),
            Value::Float(v) => Some(*v),
            Value::Str(_) => None,
        }
    }
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Value {}

impl PartialOrd for Value {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Value {
    fn cmp(&self, other: &Self) -> Ordering {
        match (self, other) {
            (Value::Key(a), Value::Key(b)) => a.cmp(b),
            (Value::Int(a), Value::Int(b)) => a.cmp(b),
            (Value::Float(a), Value::Float(b)) => a.total_cmp(b),
            (Value::Str(a), Value::Str(b)) => a.cmp(b),
            _ => self.tag().cmp(&other.tag()),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Key(k) => write!(f, "{k}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s}"),
        }
    }
}

/// One named, typed column.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Column {
    pub name: String,
    pub ty: ColumnType,
}

/// An ordered set of columns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    pub columns: Vec<Column>,
}

impl Schema {
    pub fn new(columns: Vec<(&str, ColumnType)>) -> Self {
        Self {
            columns: columns
                .into_iter()
                .map(|(name, ty)| Column {
                    name: name.to_string(),
                    ty,
                })
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }

    /// Index of the column with this name (case-insensitive, SQL-style).
    pub fn col(&self, name: &str) -> Option<usize> {
        self.columns
            .iter()
            .position(|c| c.name.eq_ignore_ascii_case(name))
    }

    /// Index of the single `Key` column, if exactly one exists.
    pub fn sole_key_col(&self) -> Option<usize> {
        let mut keys = self
            .columns
            .iter()
            .enumerate()
            .filter(|(_, c)| c.ty == ColumnType::Key);
        match (keys.next(), keys.next()) {
            (Some((i, _)), None) => Some(i),
            _ => None,
        }
    }

    /// Default wire width of one row under this schema.
    pub fn row_bytes(&self) -> u64 {
        self.columns.iter().map(|c| c.ty.wire_bytes()).sum()
    }

    pub fn describe(&self) -> String {
        self.columns
            .iter()
            .map(|c| format!("{}:{}", c.name, c.ty.name()))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// One tuple. Cells are positional against the relation's [`Schema`].
pub type Row = Vec<Value>;

/// A named, partitioned, multi-column table — the generalization of
/// [`Dataset`] the logical plan scans. Rows are stored round-robin across
/// partitions (raw ingestion order); the lowering pass re-partitions by
/// join key exactly as the kernel's shuffle would.
#[derive(Clone, Debug)]
pub struct Relation {
    pub name: String,
    pub schema: Schema,
    pub partitions: Vec<Vec<Row>>,
    /// Serialized width of one row on the wire, for shuffle accounting.
    pub row_bytes: u64,
    /// True when this relation wraps a legacy two-column [`Dataset`]: any
    /// column name resolves (join attribute → key column, everything else
    /// → value column), preserving the old free-name query style.
    pub degenerate: bool,
}

impl Relation {
    /// Build a relation, validating every row against the schema.
    pub fn new(
        name: impl Into<String>,
        schema: Schema,
        rows: Vec<Row>,
        num_partitions: usize,
    ) -> anyhow::Result<Self> {
        assert!(num_partitions > 0);
        let name = name.into();
        for (i, row) in rows.iter().enumerate() {
            if row.len() != schema.len() {
                anyhow::bail!(
                    "relation {name}: row {i} has {} cells, schema has {} columns",
                    row.len(),
                    schema.len()
                );
            }
            for (cell, col) in row.iter().zip(&schema.columns) {
                let ok = match col.ty {
                    // Int cells are accepted in Key columns (non-negative)
                    ColumnType::Key => cell.as_key().is_some(),
                    ColumnType::Int => matches!(cell, Value::Int(_) | Value::Key(_)),
                    ColumnType::Float => cell.as_f64().is_some(),
                    ColumnType::Str => matches!(cell, Value::Str(_)),
                };
                if !ok {
                    anyhow::bail!(
                        "relation {name}: row {i} column {} expects {}, got {cell:?}",
                        col.name,
                        col.ty.name()
                    );
                }
            }
        }
        let mut partitions = vec![Vec::new(); num_partitions];
        for (i, row) in rows.into_iter().enumerate() {
            partitions[i % num_partitions].push(row);
        }
        let row_bytes = schema.row_bytes();
        Ok(Self {
            name,
            schema,
            partitions,
            row_bytes,
            degenerate: false,
        })
    }

    /// Wrap a legacy two-column dataset as the degenerate relation
    /// (`key: KEY, value: FLOAT`). Column references resolve loosely: the
    /// query's join attribute maps to the key column, any other name to
    /// the value column — exactly the old free-name query behavior.
    pub fn from_dataset(dataset: &Dataset) -> Self {
        let schema = Schema::new(vec![("key", ColumnType::Key), ("value", ColumnType::Float)]);
        let partitions = dataset
            .partitions
            .iter()
            .map(|p| {
                p.iter()
                    .map(|r| vec![Value::Key(r.key), Value::Float(r.value)])
                    .collect()
            })
            .collect();
        Self {
            name: dataset.name.clone(),
            schema,
            partitions,
            row_bytes: dataset.record_bytes,
            degenerate: true,
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn len(&self) -> u64 {
        self.partitions.iter().map(|p| p.len() as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.partitions.iter().all(|p| p.is_empty())
    }

    pub fn iter(&self) -> impl Iterator<Item = &Row> {
        self.partitions.iter().flatten()
    }

    /// Resolve a column name against this relation. Degenerate relations
    /// resolve loosely (see [`Relation::from_dataset`]); `join_attr` names
    /// the query's join attribute for that fallback.
    pub fn resolve(&self, column: &str, join_attr: &str) -> Option<usize> {
        if let Some(i) = self.schema.col(column) {
            return Some(i);
        }
        if self.degenerate {
            return if column.eq_ignore_ascii_case(join_attr) {
                self.schema.sole_key_col()
            } else {
                Some(1)
            };
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Record;

    fn schema() -> Schema {
        Schema::new(vec![
            ("k", ColumnType::Key),
            ("g", ColumnType::Int),
            ("v", ColumnType::Float),
        ])
    }

    #[test]
    fn schema_lookup_and_bytes() {
        let s = schema();
        assert_eq!(s.col("k"), Some(0));
        assert_eq!(s.col("G"), Some(1)); // case-insensitive
        assert_eq!(s.col("nope"), None);
        assert_eq!(s.sole_key_col(), Some(0));
        assert_eq!(s.row_bytes(), 24);
    }

    #[test]
    fn relation_validates_rows() {
        let rows = vec![
            vec![Value::Key(1), Value::Int(10), Value::Float(0.5)],
            vec![Value::Key(2), Value::Int(20), Value::Float(1.5)],
        ];
        let r = Relation::new("t", schema(), rows, 2).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.num_partitions(), 2);

        // arity mismatch
        assert!(Relation::new("t", schema(), vec![vec![Value::Key(1)]], 2).is_err());
        // type mismatch: string in a float column
        assert!(Relation::new(
            "t",
            schema(),
            vec![vec![Value::Key(1), Value::Int(1), Value::Str("x".into())]],
            2
        )
        .is_err());
    }

    #[test]
    fn value_total_order_and_key_view() {
        assert!(Value::Float(1.0) < Value::Float(2.0));
        assert_eq!(Value::Float(2.0), Value::Float(2.0));
        assert!(Value::Int(-1) < Value::Int(3));
        assert!(Value::Str("a".into()) < Value::Str("b".into()));
        assert_eq!(Value::Key(7).as_key(), Some(7));
        assert_eq!(Value::Int(7).as_key(), Some(7));
        assert_eq!(Value::Int(-7).as_key(), None);
        assert_eq!(Value::Float(7.0).as_key(), None);
        assert_eq!(Value::Str("7".into()).as_f64(), None);
    }

    #[test]
    fn degenerate_relation_resolves_loosely() {
        let d = Dataset::from_records_unpartitioned(
            "a",
            vec![Record::new(1, 10.0), Record::new(2, 20.0)],
            2,
            100,
        );
        let r = Relation::from_dataset(&d);
        assert!(r.degenerate);
        assert_eq!(r.len(), 2);
        assert_eq!(r.row_bytes, 100);
        // the join attribute resolves to the key column, anything else to
        // the value column — old free-name queries keep working
        assert_eq!(r.resolve("flow", "flow"), Some(0));
        assert_eq!(r.resolve("size", "flow"), Some(1));
        assert_eq!(r.resolve("key", "flow"), Some(0));
        assert_eq!(r.resolve("value", "flow"), Some(1));
    }

    #[test]
    fn typed_relation_resolves_strictly() {
        let r = Relation::new("t", schema(), vec![], 2).unwrap();
        assert_eq!(r.resolve("g", "k"), Some(1));
        assert_eq!(r.resolve("nope", "k"), None);
    }
}
