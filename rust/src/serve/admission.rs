//! Admission control under a global latency SLO.
//!
//! The controller models the serving pool as `lanes` parallel executor
//! lanes with *virtual* finish times — deterministic list scheduling over
//! the predicted cost of every query admitted so far, in arrival order.
//! Decisions therefore depend only on the submission sequence, never on
//! racy completion timing, so a concurrent run admits and degrades exactly
//! like a sequential replay of the same workload.
//!
//! Per query, with `wait` the earliest lane's virtual backlog:
//!
//! 1. **Admit** when `wait + demand <= slo` — the query runs with its own
//!    budget (`demand` is the declared latency budget, else the planner's
//!    [`crate::join::CostEstimate`] prediction).
//! 2. **Degrade** otherwise, while the SLO still leaves slack: the query's
//!    sampling budget is shrunk to `slo - wait` (§3.2's latency/accuracy
//!    dial — answers get wider CIs, not slower). Past zero slack the query
//!    still queues at the floor budget while the backlog stays under the
//!    hard limit.
//! 3. **Reject** with [`crate::join::JoinError::Overloaded`] only when the
//!    predicted wait alone exceeds `hard_limit_secs`.

/// Counters over every decision the controller has made.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    pub admitted: u64,
    pub degraded: u64,
    pub rejected: u64,
}

impl AdmissionStats {
    pub fn total(&self) -> u64 {
        self.admitted + self.degraded + self.rejected
    }

    pub fn rejection_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        self.rejected as f64 / t as f64
    }
}

/// The controller's verdict for one query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionDecision {
    /// Run with the query's own budget.
    Admit,
    /// Run, but cap the sampling latency budget at `budget_secs`.
    Degrade { budget_secs: f64 },
    /// Refuse: predicted wait already past the hard limit.
    Reject { predicted_wait_secs: f64 },
}

/// Deterministic SLO scheduler for the [`crate::serve::Server`].
pub struct AdmissionController {
    slo_secs: f64,
    hard_limit_secs: f64,
    min_budget_secs: f64,
    /// Virtual finish time per executor lane.
    lanes: Vec<f64>,
    stats: AdmissionStats,
}

impl AdmissionController {
    pub fn new(
        slo_secs: f64,
        hard_limit_secs: f64,
        min_budget_secs: f64,
        lanes: usize,
    ) -> Self {
        Self {
            slo_secs,
            hard_limit_secs: hard_limit_secs.max(slo_secs),
            min_budget_secs: min_budget_secs.max(1e-9),
            lanes: vec![0.0; lanes.max(1)],
            stats: AdmissionStats::default(),
        }
    }

    /// Decide one query, in arrival order. `predicted_secs` is the
    /// planner's cost estimate for the chosen strategy;
    /// `declared_budget_secs` the query's own `WITHIN` budget, if any.
    pub fn admit(
        &mut self,
        predicted_secs: f64,
        declared_budget_secs: Option<f64>,
    ) -> AdmissionDecision {
        let lane = self.earliest_lane();
        let wait = self.lanes[lane];
        // a budgeted query occupies its declared budget (the engine sizes
        // the run to finish within it); an unbudgeted one occupies the
        // planner's predicted cost
        let demand = declared_budget_secs.unwrap_or(predicted_secs).max(0.0);

        if wait + demand <= self.slo_secs {
            self.lanes[lane] = wait + demand;
            self.stats.admitted += 1;
            return AdmissionDecision::Admit;
        }
        let slack = (self.slo_secs - wait).max(0.0);
        if slack >= self.min_budget_secs {
            self.lanes[lane] = wait + slack;
            self.stats.degraded += 1;
            return AdmissionDecision::Degrade { budget_secs: slack };
        }
        if wait <= self.hard_limit_secs {
            self.lanes[lane] = wait + self.min_budget_secs;
            self.stats.degraded += 1;
            return AdmissionDecision::Degrade {
                budget_secs: self.min_budget_secs,
            };
        }
        self.stats.rejected += 1;
        AdmissionDecision::Reject {
            predicted_wait_secs: wait,
        }
    }

    fn earliest_lane(&self) -> usize {
        let mut best = 0;
        for (i, &t) in self.lanes.iter().enumerate() {
            if t < self.lanes[best] {
                best = i;
            }
        }
        best
    }

    /// The deepest lane's virtual backlog, in predicted seconds.
    pub fn predicted_backlog(&self) -> f64 {
        self.lanes.iter().copied().fold(0.0, f64::max)
    }

    pub fn stats(&self) -> AdmissionStats {
        self.stats
    }

    pub fn slo_secs(&self) -> f64 {
        self.slo_secs
    }

    pub fn hard_limit_secs(&self) -> f64 {
        self.hard_limit_secs
    }

    /// Drain the virtual queue (burst boundary); counters are kept.
    pub fn reset(&mut self) {
        for l in &mut self.lanes {
            *l = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn under_slo_admits_with_full_budget() {
        let mut c = AdmissionController::new(1.0, 4.0, 1e-3, 2);
        for _ in 0..4 {
            // two lanes of 1.0s each fit four 0.5s queries
            assert_eq!(c.admit(0.5, None), AdmissionDecision::Admit);
        }
        assert_eq!(c.stats().admitted, 4);
        assert_eq!(c.predicted_backlog(), 1.0);
    }

    #[test]
    fn over_slo_degrades_before_rejecting() {
        let mut c = AdmissionController::new(0.1, 0.105, 1e-3, 1);
        let mut seen_degrade = false;
        let mut seen_reject = false;
        let mut decisions = Vec::new();
        for _ in 0..16 {
            let d = c.admit(0.06, Some(0.06));
            match d {
                AdmissionDecision::Admit => {
                    assert!(!seen_degrade && !seen_reject, "admit after degrade")
                }
                AdmissionDecision::Degrade { budget_secs } => {
                    assert!(!seen_reject, "degrade after reject");
                    assert!(budget_secs > 0.0 && budget_secs <= 0.06 + 1e-12);
                    seen_degrade = true;
                }
                AdmissionDecision::Reject {
                    predicted_wait_secs,
                } => {
                    assert!(predicted_wait_secs > 0.105);
                    seen_reject = true;
                }
            }
            decisions.push(d);
        }
        assert!(seen_degrade, "burst must degrade first: {decisions:?}");
        assert!(seen_reject, "burst must eventually reject: {decisions:?}");
        let s = c.stats();
        assert!(s.admitted > 0 && s.degraded > 0 && s.rejected > 0);
        assert_eq!(s.total(), 16);
    }

    #[test]
    fn degraded_budget_shrinks_monotonically_to_the_floor() {
        let mut c = AdmissionController::new(0.1, 10.0, 1e-3, 1);
        assert_eq!(c.admit(0.06, Some(0.06)), AdmissionDecision::Admit);
        let mut last = f64::INFINITY;
        for _ in 0..3 {
            match c.admit(0.06, Some(0.06)) {
                AdmissionDecision::Degrade { budget_secs } => {
                    assert!(budget_secs <= last + 1e-12);
                    last = budget_secs;
                }
                d => panic!("expected degrade, got {d:?}"),
            }
        }
        // slack exhausted: the floor budget keeps queueing under the
        // (generous) hard limit
        match c.admit(0.06, Some(0.06)) {
            AdmissionDecision::Degrade { budget_secs } => {
                assert!((budget_secs - 1e-3).abs() < 1e-12)
            }
            d => panic!("expected floor degrade, got {d:?}"),
        }
    }

    #[test]
    fn reset_drains_the_virtual_queue() {
        let mut c = AdmissionController::new(0.1, 0.2, 1e-3, 1);
        for _ in 0..8 {
            c.admit(0.1, None);
        }
        assert!(c.predicted_backlog() > 0.0);
        c.reset();
        assert_eq!(c.predicted_backlog(), 0.0);
        assert_eq!(c.admit(0.05, None), AdmissionDecision::Admit);
    }

    #[test]
    fn unbudgeted_exact_queries_get_a_budget_when_degraded() {
        // an expensive exact query over SLO is not rejected outright — it
        // is converted to a budgeted approximation first
        let mut c = AdmissionController::new(0.5, 2.0, 1e-3, 1);
        assert_eq!(c.admit(0.4, None), AdmissionDecision::Admit);
        match c.admit(10.0, None) {
            AdmissionDecision::Degrade { budget_secs } => {
                assert!((budget_secs - 0.1).abs() < 1e-12, "{budget_secs}")
            }
            d => panic!("expected degrade, got {d:?}"),
        }
    }
}
