//! Scripted multi-client workloads for the serving layer.
//!
//! A [`Workload`] is a deterministic set of per-client SQL scripts — no
//! randomness, no timestamps — so a concurrent run and a sequential
//! replay see byte-identical query streams (the bit-identity tests and
//! `benches/fig_serving.rs` depend on that). The built-in generators
//! assume two datasets registered as `a` and `b` (schema `key`/`value`
//! when wrapped relationally, which is what
//! [`crate::relation::Relation::from_dataset`] produces).

/// One client's query script, executed in order by its session.
#[derive(Clone, Debug)]
pub struct ClientScript {
    pub name: String,
    pub queries: Vec<String>,
}

/// A fixed multi-client workload.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub clients: Vec<ClientScript>,
}

impl Workload {
    pub fn total_queries(&self) -> usize {
        self.clients.iter().map(|c| c.queries.len()).sum()
    }

    /// The steady-state serving mix: ERROR-budget queries (whose answers
    /// are independent of wall-clock timing, so cache hits can never
    /// change them). Per client, the script cycles through
    ///
    /// 1. a base aggregate — the first client to run it warms the shared
    ///    sketch cache, everyone else gets a cogroup hit;
    /// 2. the same query again — a per-client *result*-cache hit;
    /// 3. a variant: even clients push a predicate (different sketch-cache
    ///    key, exercising the relational path), odd clients tighten the
    ///    error budget (same sketch key — a guaranteed sketch hit — but a
    ///    different result key, so it executes).
    pub fn scripted(clients: usize, per_client: usize) -> Self {
        const BASE: &str = "SELECT SUM(a.value + b.value) FROM a, b \
                            WHERE a.key = b.key ERROR 0.2 CONFIDENCE 95%";
        const PRED: &str = "SELECT SUM(a.value + b.value) FROM a, b \
                            WHERE a.key = b.key AND a.value > 0.25 \
                            ERROR 0.2 CONFIDENCE 95%";
        const TIGHT: &str = "SELECT SUM(a.value + b.value) FROM a, b \
                             WHERE a.key = b.key ERROR 0.1 CONFIDENCE 95%";
        let clients = (0..clients)
            .map(|c| ClientScript {
                name: format!("client{c}"),
                queries: (0..per_client)
                    .map(|i| match i % 3 {
                        0 | 1 => BASE.to_string(),
                        _ if c % 2 == 0 => PRED.to_string(),
                        _ => TIGHT.to_string(),
                    })
                    .collect(),
            })
            .collect();
        Self { clients }
    }

    /// An over-SLO burst: every query declares the same tight `WITHIN`
    /// budget, so a small SLO forces the admission controller through its
    /// whole ladder — admit, then degrade (shrinking budgets), then
    /// reject. WITHIN answers depend on measured wall time, so this
    /// workload is for admission/SLO behavior, not bit-identity checks.
    pub fn burst(clients: usize, per_client: usize) -> Self {
        const Q: &str = "SELECT SUM(a.value + b.value) FROM a, b \
                         WHERE a.key = b.key WITHIN 0.05 SECONDS";
        let clients = (0..clients)
            .map(|c| ClientScript {
                name: format!("client{c}"),
                queries: vec![Q.to_string(); per_client],
            })
            .collect();
        Self { clients }
    }
}

/// A continuous-query workload: the third client kind the server hosts,
/// next to [`Workload::scripted`] and [`Workload::burst`] request/response
/// scripts. Standing queries are registered once on a shared
/// [`crate::continuous::ContinuousEngine`], then a deterministic
/// [`crate::continuous::feed::RowFeed`] pushes micro-batches through it
/// and subscribers receive per-group change notifications
/// ([`crate::serve::Server::run_subscriptions`]).
#[derive(Clone, Debug)]
pub struct SubscriptionWorkload {
    /// Standing queries to register, one subscription each.
    pub queries: Vec<String>,
    /// Micro-batches to push after registration.
    pub batches: usize,
    /// Sliding-window width in batches.
    pub window_batches: usize,
    /// Feed seed: same seed, same batch stream, same notifications.
    pub feed_seed: u64,
    /// Feed shape (must drive the two catalog tables `a` and `b`).
    pub spec: crate::continuous::feed::FeedSpec,
}

impl SubscriptionWorkload {
    /// The bench/demo default: `n` distinct standing queries from the
    /// feed catalog over a 4-batch sliding window.
    pub fn standing(n: usize, batches: usize) -> Self {
        Self {
            queries: crate::continuous::feed::standing_queries(n),
            batches,
            window_batches: 4,
            feed_seed: 7,
            spec: crate::continuous::feed::FeedSpec::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standing_subscriptions_parse_and_are_distinct() {
        let w = SubscriptionWorkload::standing(16, 5);
        assert_eq!(w.queries.len(), 16);
        assert_eq!(w.batches, 5);
        let uniq: std::collections::BTreeSet<&String> = w.queries.iter().collect();
        assert_eq!(uniq.len(), 16);
        for q in &w.queries {
            crate::query::parse(q).unwrap();
        }
    }

    #[test]
    fn scripted_shape_and_determinism() {
        let w = Workload::scripted(4, 5);
        assert_eq!(w.clients.len(), 4);
        assert_eq!(w.total_queries(), 20);
        // deterministic: two builds are identical
        let w2 = Workload::scripted(4, 5);
        for (a, b) in w.clients.iter().zip(&w2.clients) {
            assert_eq!(a.queries, b.queries);
        }
        // q0 == q1 (result-cache repeat); q2 differs by client parity
        let c0 = &w.clients[0].queries;
        assert_eq!(c0[0], c0[1]);
        assert!(c0[2].contains("a.value > 0.25"), "{}", c0[2]);
        let c1 = &w.clients[1].queries;
        assert!(c1[2].contains("ERROR 0.1"), "{}", c1[2]);
        // every query parses
        for c in &w.clients {
            for q in &c.queries {
                crate::query::parse(q).unwrap();
            }
        }
    }

    #[test]
    fn burst_is_uniform_within_queries() {
        let w = Workload::burst(3, 2);
        assert_eq!(w.total_queries(), 6);
        for c in &w.clients {
            for q in &c.queries {
                let parsed = crate::query::parse(q).unwrap();
                assert!(parsed.budget.latency_secs.is_some());
            }
        }
    }
}
