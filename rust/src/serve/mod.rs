//! Multi-tenant serving layer: concurrent budgeted sessions, sketch and
//! result caching, and SLO admission control.
//!
//! A [`Server`] owns the registered data and runs a scripted [`Workload`]
//! of many concurrent clients. Each client gets an isolated
//! [`crate::session::Session`] — its own engine, its own
//! [`crate::cost::FeedbackStore`] scope, its own [`ResultCache`] — while
//! all clients share one [`SketchCache`] of stage-1 artifacts (built
//! [`crate::bloom::JoinFilter`]s and filtered cogroups).
//!
//! Determinism is the design constraint everything here serves:
//!
//! - **Admission** ([`AdmissionController`]) is decided *sequentially at
//!   submission time* over virtual-time lanes, so the admit / degrade /
//!   reject pattern is a pure function of the workload, never of racy
//!   completion timing.
//! - **Sketch sharing** is safe across threads because a cached artifact
//!   is bit-identical to what a rebuild would produce, and a hit sets
//!   `d_dt = 0` deterministically. ERROR-budget and exact queries are
//!   therefore hit/miss-insensitive; only `WITHIN` queries read the
//!   measured `d_dt` (documented on [`Workload::burst`]).
//! - **Execution** fans clients out over
//!   [`crate::runtime::ParallelExecutor::map_dynamic`] work stealing;
//!   responses are merged back in client order, so a concurrent run's
//!   [`ServeReport::signature`] is byte-identical to a sequential one.

mod admission;
mod cache;
mod workload;

pub use admission::{AdmissionController, AdmissionDecision, AdmissionStats};
pub use cache::{CachedAnswer, ResultCache, SketchCache, SketchStats};
pub use workload::{ClientScript, SubscriptionWorkload, Workload};

use crate::cluster::ShuffleLedger;
use crate::continuous::{feed, ContinuousConfig, ContinuousEngine};
use crate::coordinator::{EngineConfig, ExecutionMode};
use crate::cost::CostModel;
use crate::data::Dataset;
use crate::join::JoinError;
use crate::query::Query;
use crate::relation::{Relation, Value};
use crate::runtime::ParallelExecutor;
use crate::session::Session;
use crate::stats::ApproxResult;
use std::fmt::Write as _;
use std::sync::Arc;

/// Serving knobs on top of the per-query [`EngineConfig`]. The latency
/// numbers are in *simulated* cluster seconds — the same unit as
/// `WITHIN` budgets and the planner's predictions — so admission
/// decisions stay deterministic across hosts.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub engine: EngineConfig,
    /// OS threads the server fans clients out over. Never consulted by
    /// admission — decisions must not depend on host concurrency.
    pub serve_threads: usize,
    /// Virtual executor lanes the admission controller schedules over.
    /// Deliberately decoupled from `serve_threads`: the admit / degrade /
    /// reject pattern (and therefore every answer) stays identical when
    /// the same workload runs on a different thread count.
    pub admission_lanes: usize,
    /// Target latency per admission lane (admit while under this).
    pub slo_secs: f64,
    /// Reject only when the predicted queue wait alone exceeds this.
    pub hard_limit_secs: f64,
    /// Floor for degraded sampling budgets.
    pub min_budget_secs: f64,
    /// Result-cache CI widening per logical query of staleness.
    pub result_widening: f64,
    /// Result-cache entries older than this many queries are recomputed.
    pub result_max_age: u64,
    /// Byte budget for the shared [`SketchCache`] (`None` = unbounded).
    /// When set, least-recently-used sketches are evicted once the cache
    /// exceeds it; evictions are counted in [`ServeReport::sketch`].
    pub sketch_cache_bytes: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            engine: EngineConfig::default(),
            serve_threads: crate::runtime::default_parallelism(),
            admission_lanes: 1,
            slo_secs: 1.0,
            hard_limit_secs: 5.0,
            min_budget_secs: 1e-4,
            result_widening: 0.25,
            result_max_age: 8,
            sketch_cache_bytes: None,
        }
    }
}

/// What one executed (or shortcut) query returned.
#[derive(Clone, Debug)]
pub struct ServedOutcome {
    pub result: ApproxResult,
    pub strategy: String,
    pub mode: ExecutionMode,
    /// Answered from this client's [`ResultCache`]; the CI in `result`
    /// is already widened by `staleness_age`.
    pub from_result_cache: bool,
    pub staleness_age: u64,
    /// EXPLAIN text of the executed plan (cache hits carry `None`);
    /// includes the `[sketch cache: ...]` marker on its filter line.
    pub explain: Option<String>,
    /// Shuffle bytes this execution moved (0 for result-cache hits).
    pub ledger_bytes: u64,
    /// Faults injected into this execution and how they were recovered;
    /// `None` when no fault plan is configured (and on result-cache hits,
    /// which replay a previous execution's bits without re-running it).
    pub fault_report: Option<crate::faults::FaultReport>,
}

/// One query's reply, tagged with who asked and where in their script.
#[derive(Debug)]
pub struct QueryResponse {
    pub client: usize,
    pub index: usize,
    pub sql: String,
    /// The admission controller shrank this query's sampling budget to
    /// this many (simulated) seconds.
    pub degraded_to: Option<f64>,
    pub outcome: Result<ServedOutcome, JoinError>,
}

/// Aggregate report of one [`Server::run_workload`] call.
#[derive(Debug)]
pub struct ServeReport {
    /// Every reply, in (client, script index) order.
    pub responses: Vec<QueryResponse>,
    /// Real wall-clock seconds of the concurrent execution phase.
    pub wall_secs: f64,
    /// Queries answered (executions + result-cache hits).
    pub executed: usize,
    pub admission: AdmissionStats,
    /// Sketch-cache counters accumulated by *this* run.
    pub sketch: SketchStats,
    pub result_hits: u64,
    pub result_lookups: u64,
    /// Per-stage shuffle traffic, tagged `client{c}/...`.
    pub ledger: ShuffleLedger,
    pub serve_threads: usize,
    /// Merged fault report over every executed answer — injected /
    /// recovered / degraded counters and the union of dead workers;
    /// `None` when the run was fault-free.
    pub faults: Option<crate::faults::FaultReport>,
}

impl ServeReport {
    /// Answered queries per wall-clock second.
    pub fn qps(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.executed as f64 / self.wall_secs
    }

    pub fn sketch_hit_rate(&self) -> f64 {
        self.sketch.hit_rate()
    }

    pub fn result_hit_rate(&self) -> f64 {
        if self.result_lookups == 0 {
            return 0.0;
        }
        self.result_hits as f64 / self.result_lookups as f64
    }

    pub fn rejection_rate(&self) -> f64 {
        self.admission.rejection_rate()
    }

    /// A deterministic transcript of every answer's bits — two runs of
    /// the same workload (any thread count) must produce equal
    /// signatures. Excludes anything scheduling-dependent: wall time,
    /// shuffle bytes, and which client happened to warm the sketch cache.
    pub fn signature(&self) -> String {
        let mut s = String::new();
        for r in &self.responses {
            let _ = write!(s, "c{}q{}:", r.client, r.index);
            match &r.outcome {
                Ok(o) => {
                    let _ = write!(
                        s,
                        "est={:016x},err={:016x},mode={:?},strat={},rc={},age={}",
                        o.result.estimate.to_bits(),
                        o.result.error_bound.to_bits(),
                        o.mode,
                        o.strategy,
                        o.from_result_cache,
                        o.staleness_age,
                    );
                }
                Err(e) => {
                    let _ = write!(s, "error({e})");
                }
            }
            if let Some(b) = r.degraded_to {
                let _ = write!(s, ",degraded={:016x}", b.to_bits());
            }
            s.push('\n');
        }
        s
    }

    /// Human-readable summary.
    pub fn render(&self) -> String {
        let mut s = format!(
            "served {}/{} queries in {:.3}s on {} threads ({:.1} QPS)\n\
             admission: {} admitted, {} degraded, {} rejected ({:.0}% rejection)\n\
             sketch cache: {} cogroup + {} filter hits / {} lookups ({:.0}% hit rate, {} evicted)\n\
             result cache: {} hits / {} lookups ({:.0}% hit rate)\n\
             shuffle: {} bytes",
            self.executed,
            self.responses.len(),
            self.wall_secs,
            self.serve_threads,
            self.qps(),
            self.admission.admitted,
            self.admission.degraded,
            self.admission.rejected,
            100.0 * self.rejection_rate(),
            self.sketch.cogroup_hits,
            self.sketch.filter_hits,
            self.sketch.lookups(),
            100.0 * self.sketch_hit_rate(),
            self.sketch.evictions,
            self.result_hits,
            self.result_lookups,
            100.0 * self.result_hit_rate(),
            self.ledger.total_bytes(),
        );
        if let Some(f) = &self.faults {
            let _ = write!(
                s,
                "\nfaults: {} injected, {} recovered ({} speculative), {} past budget, \
                 {} retry bytes, {} dead worker(s)",
                f.injected,
                f.recovered,
                f.speculative,
                f.degraded,
                f.retry_bytes,
                f.dead_workers.len(),
            );
        }
        s
    }
}

/// Aggregate report of one [`Server::run_subscriptions`] call.
#[derive(Clone, Debug)]
pub struct SubscriptionReport {
    pub queries: usize,
    pub batches: usize,
    /// Change notices delivered across all batches.
    pub notifications: u64,
    /// Strata the delta path examined because their key changed.
    pub touched_strata: u64,
    /// Strata carried over untouched — the work delta maintenance skipped.
    pub carried_strata: u64,
    /// Arrival + eviction records spliced through the columnar cogroups.
    pub spliced_rows: u64,
    /// Standing queries whose state was lost to injected faults and
    /// rebuilt by window replay (0 without a fault plan).
    pub recovered_queries: u64,
    /// Final per-query (group, results) tables, in registration order.
    pub finals: Vec<Vec<(Value, Vec<ApproxResult>)>>,
    /// Real wall-clock seconds of the push phase.
    pub wall_secs: f64,
    pub serve_threads: usize,
}

impl SubscriptionReport {
    /// A deterministic transcript of the final answers and the
    /// notification/delta counters — two runs of the same workload at any
    /// `serve_threads` must produce equal signatures. Excludes wall time.
    pub fn signature(&self) -> String {
        let mut s = format!(
            "n={},touched={},carried={},spliced={}\n",
            self.notifications, self.touched_strata, self.carried_strata, self.spliced_rows
        );
        for (qi, groups) in self.finals.iter().enumerate() {
            for (gv, rs) in groups {
                let _ = write!(s, "q{qi}:{gv:?}:");
                for r in rs {
                    let _ = write!(
                        s,
                        "est={:016x},err={:016x};",
                        r.estimate.to_bits(),
                        r.error_bound.to_bits()
                    );
                }
                s.push('\n');
            }
        }
        s
    }

    /// Human-readable summary.
    pub fn render(&self) -> String {
        format!(
            "{} standing queries over {} batches in {:.3}s on {} threads\n\
             notifications: {}\n\
             delta maintenance: {} strata touched, {} carried, {} rows spliced",
            self.queries,
            self.batches,
            self.wall_secs,
            self.serve_threads,
            self.notifications,
            self.touched_strata,
            self.carried_strata,
            self.spliced_rows,
        )
    }
}

/// What phase 0 decided for one scripted query.
#[derive(Clone, Debug)]
enum Directive {
    /// Execute; `Some(b)` caps the sampling latency budget at `b`.
    Run { budget: Option<f64> },
    Reject { predicted_wait_secs: f64 },
}

/// Per-client results carried back from the execution phase.
struct ClientRun {
    responses: Vec<QueryResponse>,
    ledger: ShuffleLedger,
    result_hits: u64,
    result_lookups: u64,
}

/// The multi-tenant serving front: registered data + a shared
/// [`SketchCache`] + an [`AdmissionController`] per workload run.
pub struct Server {
    cfg: ServeConfig,
    cost: Option<CostModel>,
    datasets: Vec<(String, Dataset)>,
    tables: Vec<(String, Relation)>,
    sketches: Arc<SketchCache>,
}

impl Server {
    pub fn new(cfg: ServeConfig) -> Self {
        let sketches = Arc::new(SketchCache::with_budget(cfg.sketch_cache_bytes));
        Self {
            cfg,
            cost: None,
            datasets: Vec::new(),
            tables: Vec::new(),
            sketches,
        }
    }

    /// Register (or replace) a dataset server-wide. Re-registration bumps
    /// the sketch cache's epoch for `name`, so no later query can reuse a
    /// sketch built over the old contents.
    pub fn with_data(mut self, name: &str, mut dataset: Dataset) -> Self {
        dataset.name = name.to_string();
        self.datasets.retain(|(n, _)| n != name);
        self.datasets.push((name.to_string(), dataset));
        self.sketches.invalidate(name);
        self
    }

    /// Register (or replace) a typed relation server-wide; invalidates
    /// like [`Server::with_data`].
    pub fn with_table(mut self, name: &str, mut relation: Relation) -> Self {
        relation.name = name.to_string();
        self.tables.retain(|(n, _)| n != name);
        self.tables.push((name.to_string(), relation));
        self.sketches.invalidate(name);
        self
    }

    /// Use a profiled cost model for every client session and the planner.
    pub fn with_cost_model(mut self, cost: CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    /// The shared sketch cache (inspection / tests).
    pub fn sketches(&self) -> &Arc<SketchCache> {
        &self.sketches
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// A fresh isolated session over the server's registered data. The
    /// sketch cache is attached *after* registration (the server already
    /// owns invalidation), and `scope` namespaces the feedback store.
    fn client_session(&self, scope: Option<&str>) -> anyhow::Result<Session> {
        let mut session = Session::without_runtime(self.cfg.engine.clone())?;
        for (name, d) in &self.datasets {
            session = session.with_data(name, d.clone());
        }
        for (name, r) in &self.tables {
            session = session.with_table(name, r.clone());
        }
        if let Some(cost) = &self.cost {
            session = session.with_cost_model(*cost);
        }
        if let Some(scope) = scope {
            session = session
                .with_feedback_scope(scope)
                .with_sketch_cache(self.sketches.clone());
        }
        Ok(session)
    }

    /// The per-client result-cache key: query shape + effective budget +
    /// the registration epoch of every scanned table (a re-registered
    /// table silently orphans old answers).
    fn result_key(&self, query: &Query) -> String {
        let mut key = format!("{}|b={:?}", query.fingerprint(), query.budget);
        for t in &query.tables {
            let _ = write!(key, "|{t}@{}", self.sketches.epoch_of(t));
        }
        key
    }

    /// Run a scripted workload: phase 0 admits every query sequentially
    /// in round-robin arrival order (deterministic virtual-time lanes),
    /// phase 1 executes the per-client scripts concurrently with work
    /// stealing. Replies come back in (client, index) order.
    pub fn run_workload(&self, workload: &Workload) -> anyhow::Result<ServeReport> {
        // ---- phase 0: sequential admission at submission time
        let mut admission = AdmissionController::new(
            self.cfg.slo_secs,
            self.cfg.hard_limit_secs,
            self.cfg.min_budget_secs,
            self.cfg.admission_lanes.max(1),
        );
        let mut planner = self.client_session(None)?;
        let mut directives: Vec<Vec<Directive>> = workload
            .clients
            .iter()
            .map(|c| vec![Directive::Run { budget: None }; c.queries.len()])
            .collect();
        let rounds = workload.clients.iter().map(|c| c.queries.len()).max();
        for qi in 0..rounds.unwrap_or(0) {
            for (ci, client) in workload.clients.iter().enumerate() {
                let Some(sql) = client.queries.get(qi) else {
                    continue;
                };
                // malformed / unplannable queries surface their error at
                // execution time and never occupy an admission lane
                let Ok(parsed) = crate::query::parse(sql) else {
                    continue;
                };
                let Some(predicted) = planner
                    .sql(sql)
                    .ok()
                    .and_then(|b| b.plan().ok())
                    .map(|p| p.predicted_secs())
                else {
                    continue;
                };
                // fault-aware admission: expected retry/straggler overhead
                // consumes lane budget up front, so a chaotic cluster
                // degrades or rejects sooner — the same dial as load. The
                // factor is a pure function of the plan, so decisions stay
                // deterministic.
                let predicted = predicted
                    * self
                        .cfg
                        .engine
                        .faults
                        .map(|p| p.expected_overhead_factor())
                        .unwrap_or(1.0);
                match admission.admit(predicted, parsed.budget.latency_secs) {
                    AdmissionDecision::Admit => {}
                    AdmissionDecision::Degrade { budget_secs } => {
                        directives[ci][qi] = Directive::Run {
                            budget: Some(budget_secs),
                        };
                    }
                    AdmissionDecision::Reject {
                        predicted_wait_secs,
                    } => {
                        directives[ci][qi] = Directive::Reject {
                            predicted_wait_secs,
                        };
                    }
                }
            }
        }

        // ---- phase 1: concurrent execution, one isolated session per
        // client, shared sketch cache, work-stealing over clients
        let sketch_before = self.sketches.stats();
        let exec = ParallelExecutor::new(self.cfg.serve_threads);
        let started = std::time::Instant::now();
        let per_client = exec.map_dynamic(workload.clients.len(), |ci| {
            self.run_client(ci, &workload.clients[ci], &directives[ci])
        });
        let wall_secs = started.elapsed().as_secs_f64();

        let mut responses = Vec::with_capacity(workload.total_queries());
        let mut ledger = ShuffleLedger::default();
        let (mut result_hits, mut result_lookups) = (0u64, 0u64);
        for (ci, run) in per_client.into_iter().enumerate() {
            let run = run?;
            ledger.merge(run.ledger.tagged(&format!("client{ci}")));
            result_hits += run.result_hits;
            result_lookups += run.result_lookups;
            responses.extend(run.responses);
        }
        let executed = responses.iter().filter(|r| r.outcome.is_ok()).count();
        let mut faults: Option<crate::faults::FaultReport> = None;
        for r in &responses {
            if let Ok(out) = &r.outcome {
                if let Some(rep) = &out.fault_report {
                    match faults.as_mut() {
                        Some(acc) => acc.merge(rep),
                        None => faults = Some(rep.clone()),
                    }
                }
            }
        }
        Ok(ServeReport {
            responses,
            wall_secs,
            executed,
            admission: admission.stats(),
            sketch: self.sketches.stats().since(&sketch_before),
            result_hits,
            result_lookups,
            ledger,
            serve_threads: self.cfg.serve_threads,
            faults,
        })
    }

    /// Host a continuous-subscription workload: register every standing
    /// query on one shared [`ContinuousEngine`], push the scripted feed,
    /// and tally the change notifications subscribers would receive. The
    /// engine updates each query from arrival/eviction deltas only, and
    /// its answers are bit-identical at any `serve_threads`, so a
    /// subscription run's [`SubscriptionReport::signature`] is as
    /// thread-count-invariant as the request/response path's.
    pub fn run_subscriptions(
        &self,
        sub: &SubscriptionWorkload,
    ) -> Result<SubscriptionReport, JoinError> {
        assert_eq!(
            sub.spec.tables, 2,
            "subscription feeds drive the two-table catalog (tables a, b)"
        );
        let mut engine = ContinuousEngine::new(ContinuousConfig {
            window_batches: sub.window_batches,
            parallelism: self.cfg.serve_threads.max(1),
            faults: self.cfg.engine.faults,
            ..ContinuousConfig::default()
        })
        .with_table("a", feed::feed_schema())
        .with_table("b", feed::feed_schema());
        for sql in &sub.queries {
            engine.register(sql)?;
        }
        let mut rows = feed::RowFeed::new(sub.feed_seed, sub.spec.clone());
        let started = std::time::Instant::now();
        let (mut notifications, mut touched, mut carried, mut spliced, mut recovered) =
            (0u64, 0u64, 0u64, 0u64, 0u64);
        for _ in 0..sub.batches {
            let up = engine.push_batch(rows.next_batch())?;
            notifications += up.notifications.len() as u64;
            touched += up.touched_strata;
            carried += up.carried_strata;
            spliced += up.spliced_rows;
            recovered += up.recovered_queries;
        }
        let wall_secs = started.elapsed().as_secs_f64();
        let finals = (0..engine.num_queries())
            .map(|qi| {
                engine
                    .results(qi)
                    .map(|m| m.iter().map(|(g, r)| (g.clone(), r.clone())).collect())
                    .unwrap_or_default()
            })
            .collect();
        Ok(SubscriptionReport {
            queries: sub.queries.len(),
            batches: sub.batches,
            notifications,
            touched_strata: touched,
            carried_strata: carried,
            spliced_rows: spliced,
            recovered_queries: recovered,
            finals,
            wall_secs,
            serve_threads: self.cfg.serve_threads,
        })
    }

    fn run_client(
        &self,
        ci: usize,
        script: &ClientScript,
        directives: &[Directive],
    ) -> anyhow::Result<ClientRun> {
        let mut session = self.client_session(Some(&script.name))?;
        let mut results =
            ResultCache::new(self.cfg.result_widening, self.cfg.result_max_age);
        let mut ledger = ShuffleLedger::default();
        let mut responses = Vec::with_capacity(script.queries.len());
        for (qi, sql) in script.queries.iter().enumerate() {
            let (degraded_to, outcome) = match &directives[qi] {
                Directive::Reject {
                    predicted_wait_secs,
                } => (
                    None,
                    Err(JoinError::Overloaded {
                        predicted_wait_secs: *predicted_wait_secs,
                        hard_limit_secs: self.cfg.hard_limit_secs,
                    }),
                ),
                Directive::Run { budget } => (
                    *budget,
                    self.run_one(&mut session, &mut results, &mut ledger, sql, *budget),
                ),
            };
            responses.push(QueryResponse {
                client: ci,
                index: qi,
                sql: sql.clone(),
                degraded_to,
                outcome,
            });
        }
        Ok(ClientRun {
            responses,
            ledger,
            result_hits: results.hits(),
            result_lookups: results.lookups(),
        })
    }

    fn run_one(
        &self,
        session: &mut Session,
        results: &mut ResultCache,
        ledger: &mut ShuffleLedger,
        sql: &str,
        budget: Option<f64>,
    ) -> Result<ServedOutcome, JoinError> {
        results.tick();
        let mut query =
            crate::query::parse(sql).map_err(|e| JoinError::Runtime(format!("{e:#}")))?;
        if let Some(b) = budget {
            // degrade = shrink the sampling budget (§3.2 dial): cap an
            // existing WITHIN, or impose one on unbudgeted/ERROR queries
            query.budget.latency_secs = Some(match query.budget.latency_secs {
                Some(l) => l.min(b),
                None => b,
            });
        }
        let key = self.result_key(&query);
        if let Some(hit) = results.lookup(&key) {
            return Ok(ServedOutcome {
                result: hit.result,
                strategy: hit.strategy,
                mode: hit.mode,
                from_result_cache: true,
                staleness_age: hit.age,
                explain: None,
                ledger_bytes: 0,
                fault_report: None,
            });
        }
        let out = session.query(query).run().map_err(|e| {
            match e.downcast::<JoinError>() {
                Ok(je) => je,
                Err(e) => JoinError::Runtime(format!("{e:#}")),
            }
        })?;
        ledger.merge(out.ledger.clone());
        results.insert(key, out.result, &out.strategy, out.mode);
        Ok(ServedOutcome {
            result: out.result,
            strategy: out.strategy,
            mode: out.mode,
            from_result_cache: false,
            staleness_age: 0,
            explain: out.plan.map(|p| p.explain()),
            ledger_bytes: out.ledger.total_bytes(),
            fault_report: out.fault_report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::{generate_overlapping, SyntheticSpec};

    fn base_cfg() -> ServeConfig {
        ServeConfig {
            engine: EngineConfig {
                workers: 4,
                time_model: TimeModel {
                    bandwidth: 1e6,
                    stage_latency: 0.0,
                    compute_scale: 1.0,
                },
                ..Default::default()
            },
            serve_threads: 2,
            // generous SLO: the steady-state tests exercise caching, not
            // degradation (the burst test tightens these)
            slo_secs: 1e6,
            hard_limit_secs: 1e7,
            ..Default::default()
        }
    }

    fn server_from(cfg: ServeConfig) -> Server {
        let inputs = generate_overlapping(&SyntheticSpec {
            items_per_input: 2_000,
            overlap_fraction: 0.2,
            lambda: 10.0,
            partitions: 4,
            seed: 11,
            ..Default::default()
        });
        Server::new(cfg)
            .with_data("a", inputs[0].clone())
            .with_data("b", inputs[1].clone())
    }

    fn server() -> Server {
        server_from(base_cfg())
    }

    #[test]
    fn scripted_workload_serves_and_hits_both_caches() {
        let s = server();
        let w = Workload::scripted(4, 3);
        let report = s.run_workload(&w).unwrap();
        assert_eq!(report.responses.len(), 12);
        assert_eq!(report.executed, 12, "{}", report.render());
        // q1 repeats q0 per client: four result-cache hits
        assert!(report.result_hits >= 4, "{}", report.render());
        // clients share sketches: at least one cross-client hit
        assert!(
            report.sketch.cogroup_hits + report.sketch.filter_hits >= 1,
            "{}",
            report.render()
        );
        assert!(report.qps() > 0.0);
        // a served (non-cached) execution carries an explain text
        let explained = report
            .responses
            .iter()
            .filter_map(|r| r.outcome.as_ref().ok())
            .filter_map(|o| o.explain.as_deref())
            .collect::<Vec<_>>();
        assert!(!explained.is_empty());
    }

    #[test]
    fn concurrent_signature_matches_sequential() {
        let w = Workload::scripted(4, 3);
        let seq = {
            let mut s = server();
            s.cfg.serve_threads = 1;
            s.run_workload(&w).unwrap()
        };
        let par = {
            let mut s = server();
            s.cfg.serve_threads = 4;
            s.run_workload(&w).unwrap()
        };
        assert_eq!(seq.signature(), par.signature());
    }

    #[test]
    fn sketch_cache_budget_evicts_without_changing_answers() {
        let w = Workload::scripted(4, 3);
        let unbounded = server().run_workload(&w).unwrap();
        let mut cfg = base_cfg();
        // far below any single cogroup entry: every insert evicts
        cfg.sketch_cache_bytes = Some(64);
        let s = server_from(cfg);
        assert_eq!(s.sketches().budget(), Some(64));
        let capped = s.run_workload(&w).unwrap();
        assert!(capped.sketch.evictions > 0, "{}", capped.render());
        assert!(s.sketches().cached_bytes() <= 64);
        // eviction changes only what is cached, never an answer
        assert_eq!(unbounded.signature(), capped.signature());
        assert!(capped.render().contains("evicted"));
    }

    #[test]
    fn subscription_signature_is_thread_count_invariant() {
        let mut w = SubscriptionWorkload::standing(8, 6);
        // sparse feed: each batch touches a minority of the window's keys
        w.spec.keyspace = 512;
        w.spec.rows_per_batch = 64;
        let r1 = {
            let mut cfg = base_cfg();
            cfg.serve_threads = 1;
            Server::new(cfg).run_subscriptions(&w).unwrap()
        };
        let r4 = {
            let mut cfg = base_cfg();
            cfg.serve_threads = 4;
            Server::new(cfg).run_subscriptions(&w).unwrap()
        };
        assert_eq!(r1.signature(), r4.signature());
        assert_eq!(r1.queries, 8);
        assert!(r1.notifications > 0, "{}", r1.render());
        assert!(
            r1.carried_strata > 0,
            "the skewed feed should leave cold strata untouched"
        );
        assert!(r1.finals.iter().any(|g| !g.is_empty()));
    }

    #[test]
    fn rejected_queries_are_typed_overloaded() {
        let mut s = server();
        s.cfg.slo_secs = 1e-7;
        s.cfg.hard_limit_secs = 2e-7;
        s.cfg.min_budget_secs = 1e-7;
        s.cfg.serve_threads = 1;
        let w = Workload::burst(4, 4);
        let report = s.run_workload(&w).unwrap();
        assert!(report.admission.rejected > 0, "{}", report.render());
        assert!(report.admission.degraded > 0, "{}", report.render());
        let overloaded = report
            .responses
            .iter()
            .filter(|r| {
                matches!(r.outcome, Err(JoinError::Overloaded { .. }))
            })
            .count();
        assert_eq!(overloaded as u64, report.admission.rejected);
    }
}
