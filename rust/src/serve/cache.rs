//! Cross-query sketch reuse and per-client result reuse.
//!
//! [`SketchCache`] holds the expensive artifacts of ApproxJoin stage 1 —
//! built [`JoinFilter`]s and filtered columnar [`CogroupColumns`] — keyed
//! by what determines them bit-for-bit: the FROM tables (with a
//! registration epoch each), the pushed-down predicates, the per-aggregate
//! projection, the filter kind + geometry, and the worker count. Because
//! stage 1 is a pure function of those inputs, replaying a cached sketch
//! yields the *same* filtered cogroup the query would have built, so a
//! cache hit changes only the measured traffic (and frees the latency
//! budget for sampling), never the answer. Re-registering a table bumps
//! its epoch, which orphans and prunes every entry built over the old
//! contents.
//!
//! [`ResultCache`] is the layer above: whole `estimate ± CI` answers keyed
//! by fingerprint + budget + table epochs. It is client-session-scoped
//! (never shared across concurrent clients, keeping replies deterministic)
//! and expresses staleness as a *widened* confidence interval: an answer
//! served `age` queries after it was computed carries
//! `error_bound * (1 + widening * age)` until `max_age` evicts it.

use crate::bloom::{JoinFilter, SketchCacheHit};
use crate::cluster::SimCluster;
use crate::coordinator::ExecutionMode;
use crate::data::Dataset;
use crate::join::bloom_join::{
    build_join_filter, probe_and_shuffle, FilterConfig, Filtered, KeyProber,
};
use crate::join::JoinVariant;
use crate::runtime::CogroupColumns;
use crate::stats::ApproxResult;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cumulative lookup counters of a [`SketchCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SketchStats {
    /// Stage 1 skipped entirely: the filtered cogroup was replayed.
    pub cogroup_hits: u64,
    /// The join filter was reused; probe + shuffle still ran.
    pub filter_hits: u64,
    pub misses: u64,
    /// Entries dropped by the byte-budget LRU (never by invalidation).
    pub evictions: u64,
}

impl SketchStats {
    pub fn lookups(&self) -> u64 {
        self.cogroup_hits + self.filter_hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let l = self.lookups();
        if l == 0 {
            return 0.0;
        }
        (self.cogroup_hits + self.filter_hits) as f64 / l as f64
    }

    /// Counters accumulated since `earlier` was snapshotted.
    pub fn since(&self, earlier: &SketchStats) -> SketchStats {
        SketchStats {
            cogroup_hits: self.cogroup_hits - earlier.cogroup_hits,
            filter_hits: self.filter_hits - earlier.filter_hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
        }
    }
}

/// A cached stage-1 output: the filtered per-worker cogroup plus the
/// filter and survivor counts that describe it.
#[derive(Clone)]
struct CachedCogroup {
    per_worker: Arc<Vec<CogroupColumns>>,
    join_filter: JoinFilter,
    survivors: Vec<u64>,
    /// Heap footprint of this entry, fixed at insertion time.
    bytes: u64,
}

#[derive(Default)]
struct Inner {
    /// Registration epoch per table name; bumped by invalidation.
    epochs: HashMap<String, u64>,
    filters: HashMap<String, JoinFilter>,
    cogroups: HashMap<String, CachedCogroup>,
    /// Logical LRU clock; bumped on every hit and insert.
    clock: u64,
    /// Last-use stamp per filter / cogroup entry.
    filter_use: HashMap<String, u64>,
    cogroup_use: HashMap<String, u64>,
    stats: SketchStats,
}

impl Inner {
    fn cached_bytes(&self) -> u64 {
        self.cogroups.values().map(|c| c.bytes).sum::<u64>()
            + self.filters.values().map(|f| f.size_bytes()).sum::<u64>()
    }

    fn touch(clock: &mut u64, uses: &mut HashMap<String, u64>, key: &str) {
        *clock += 1;
        uses.insert(key.to_string(), *clock);
    }

    /// Evict least-recently-used entries until the cache fits `budget`.
    /// Cogroups go first (they dominate the footprint and are cheapest to
    /// rebuild from a retained filter), then filters. Ties on the use
    /// stamp break by key so eviction order is deterministic.
    fn enforce_budget(&mut self, budget: u64) {
        while self.cached_bytes() > budget && !self.cogroups.is_empty() {
            let victim = self
                .cogroups
                .keys()
                .min_by_key(|k| (self.cogroup_use.get(*k).copied().unwrap_or(0), (*k).clone()))
                .expect("non-empty map has a minimum")
                .clone();
            self.cogroups.remove(&victim);
            self.cogroup_use.remove(&victim);
            self.stats.evictions += 1;
        }
        while self.cached_bytes() > budget && !self.filters.is_empty() {
            let victim = self
                .filters
                .keys()
                .min_by_key(|k| (self.filter_use.get(*k).copied().unwrap_or(0), (*k).clone()))
                .expect("non-empty map has a minimum")
                .clone();
            self.filters.remove(&victim);
            self.filter_use.remove(&victim);
            self.stats.evictions += 1;
        }
    }
}

/// Shared, thread-safe sketch cache for the serving layer. One instance
/// is attached to every concurrent [`crate::session::Session`] a
/// [`crate::serve::Server`] spawns; the engine's budgeted execution paths
/// consult it before running stage 1.
///
/// By default the cache is unbounded and only invalidation prunes it.
/// [`SketchCache::with_budget`] caps the total heap footprint: once the
/// cached filters + cogroups exceed the budget, least-recently-used
/// entries are evicted (cogroups before filters) and counted in
/// [`SketchStats::evictions`].
#[derive(Default)]
pub struct SketchCache {
    inner: Mutex<Inner>,
    budget: Option<u64>,
}

impl SketchCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache capped at `budget` bytes of cached sketch state
    /// (`None` = unbounded, same as [`SketchCache::new`]).
    pub fn with_budget(budget: Option<u64>) -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            budget,
        }
    }

    /// The configured byte budget, if any.
    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Current heap footprint of all cached filters + cogroups.
    pub fn cached_bytes(&self) -> u64 {
        self.inner.lock().unwrap().cached_bytes()
    }

    /// The current registration epoch of a table (0 until invalidated).
    pub fn epoch_of(&self, table: &str) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.epochs.get(table).copied().unwrap_or(0)
    }

    /// Bump `table`'s epoch and prune every entry built over it. Called
    /// by `Session::register_table` / `with_data` / `with_table` when a
    /// cache is attached, so re-registration can never serve stale
    /// sketches.
    pub fn invalidate(&self, table: &str) {
        let mut inner = self.inner.lock().unwrap();
        *inner.epochs.entry(table.to_string()).or_insert(0) += 1;
        let needle = format!("|t={table}@");
        inner.filters.retain(|k, _| !k.contains(&needle));
        inner.cogroups.retain(|k, _| !k.contains(&needle));
        inner.filter_use.retain(|k, _| !k.contains(&needle));
        inner.cogroup_use.retain(|k, _| !k.contains(&needle));
    }

    /// Drop every cached sketch (epochs are kept).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.filters.clear();
        inner.cogroups.clear();
        inner.filter_use.clear();
        inner.cogroup_use.clear();
    }

    pub fn stats(&self) -> SketchStats {
        self.inner.lock().unwrap().stats
    }

    /// (cached filters, cached cogroups).
    pub fn entry_counts(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.filters.len(), inner.cogroups.len())
    }

    /// The cache key of the join filter for a query shape. Epochs are
    /// embedded per table, so re-registering a table orphans old entries
    /// even before the prune runs. Per-table components are sorted: the
    /// join filter is an intersection over all inputs' key sets, so two
    /// join orders over the same tables share one filter entry (the
    /// order-sensitive cogroup key adds the executed order on top).
    fn filter_key(
        epochs: &HashMap<String, u64>,
        tables: &[String],
        predicate_tag: &str,
        cfg: FilterConfig,
        workers: usize,
    ) -> String {
        let mut parts: Vec<String> = tables
            .iter()
            .map(|t| {
                let e = epochs.get(t).copied().unwrap_or(0);
                format!("|t={t}@{e}")
            })
            .collect();
        parts.sort();
        let mut key = parts.concat();
        key.push_str(&format!(
            "|p={predicate_tag}|k={}|g={}/{}|w={workers}",
            cfg.kind, cfg.log2_bits, cfg.num_hashes
        ));
        key
    }

    /// The cache key of a filtered cogroup: the filter key plus the
    /// *executed* table order, the per-aggregate projection, and the join
    /// variant. Stage-1 cogroup artifacts are order-sensitive — the
    /// join-order optimizer may permute inputs, and the cogroup built over
    /// `a > b > c` is not the cogroup built over `c > a > b` — so the
    /// order is part of the key even though the filter is shared. The
    /// variant is part of the key because a filtered cogroup answers only
    /// the variant it was built for: an inner cogroup has already dropped
    /// the unmatched keys an outer or anti join must pad, so replaying it
    /// across variants would silently change answers.
    fn cogroup_key(
        fkey: &str,
        tables: &[String],
        projection_tag: &str,
        variant: JoinVariant,
    ) -> String {
        format!(
            "{fkey}|ord={}|proj={projection_tag}|v={}",
            tables.join(">"),
            variant.tag()
        )
    }

    /// Run (or replay) stage 1 for a query over `inputs`, consulting the
    /// cache at both granularities. Returns the [`Filtered`] output plus
    /// how much of it was served from cache:
    ///
    /// - **cogroup hit** — the whole filtered cogroup is replayed;
    ///   `d_dt = 0` (the cost dial sees the filtering as already paid)
    ///   and no cluster stages run.
    /// - **filter hit** — the built join filter is reused; the probe +
    ///   shuffle half runs normally on top of it.
    /// - **miss** — full build, and both artifacts are inserted.
    #[allow(clippy::too_many_arguments)]
    pub fn filtered(
        &self,
        cluster: &mut SimCluster,
        inputs: &[Dataset],
        tables: &[String],
        predicate_tag: &str,
        projection_tag: &str,
        variant: JoinVariant,
        cfg: FilterConfig,
        prober: &mut dyn KeyProber,
    ) -> anyhow::Result<(Filtered, SketchCacheHit)> {
        assert!(
            !cfg.is_auto_sized(),
            "sketch-cache keys need a resolved filter geometry"
        );
        let workers = cluster.k;
        let (fkey, ckey, cached_cogroup, cached_filter) = {
            let mut inner = self.inner.lock().unwrap();
            let fkey =
                Self::filter_key(&inner.epochs, tables, predicate_tag, cfg, workers);
            let ckey = Self::cogroup_key(&fkey, tables, projection_tag, variant);
            let cg = inner.cogroups.get(&ckey).cloned();
            let jf = if cg.is_none() {
                inner.filters.get(&fkey).cloned()
            } else {
                None
            };
            let Inner {
                clock,
                filter_use,
                cogroup_use,
                stats,
                ..
            } = &mut *inner;
            match (&cg, &jf) {
                (Some(_), _) => {
                    stats.cogroup_hits += 1;
                    Inner::touch(clock, cogroup_use, &ckey);
                }
                (None, Some(_)) => {
                    stats.filter_hits += 1;
                    Inner::touch(clock, filter_use, &fkey);
                }
                (None, None) => stats.misses += 1,
            }
            (fkey, ckey, cg, jf)
        };

        if let Some(c) = cached_cogroup {
            // replay: bit-identical to a rebuild over the same inputs, no
            // cluster stages, and the filtering time is already paid
            return Ok((
                Filtered {
                    per_worker: (*c.per_worker).clone(),
                    d_dt: 0.0,
                    join_filter: c.join_filter,
                    survivors: c.survivors,
                },
                SketchCacheHit::Cogroup,
            ));
        }

        let (filtered, hit) = if let Some(jf) = cached_filter {
            // the build + treeReduce + broadcast half is skipped
            let filtered = probe_and_shuffle(cluster, inputs, jf, 0.0, prober)?;
            (filtered, SketchCacheHit::Filter)
        } else {
            let (join_filter, d_dt) = build_join_filter(cluster, inputs, cfg);
            let filtered =
                probe_and_shuffle(cluster, inputs, join_filter, d_dt, prober)?;
            (filtered, SketchCacheHit::None)
        };

        let mut inner = self.inner.lock().unwrap();
        let Inner {
            clock,
            filter_use,
            cogroup_use,
            ..
        } = &mut *inner;
        if hit == SketchCacheHit::None {
            Inner::touch(clock, filter_use, &fkey);
        }
        Inner::touch(clock, cogroup_use, &ckey);
        if hit == SketchCacheHit::None {
            inner
                .filters
                .insert(fkey, filtered.join_filter.clone());
        }
        let bytes = filtered
            .per_worker
            .iter()
            .map(|cg| cg.heap_bytes())
            .sum::<u64>()
            + filtered.join_filter.size_bytes()
            + filtered.survivors.len() as u64 * 8;
        inner.cogroups.insert(
            ckey,
            CachedCogroup {
                per_worker: Arc::new(filtered.per_worker.clone()),
                join_filter: filtered.join_filter.clone(),
                survivors: filtered.survivors.clone(),
                bytes,
            },
        );
        if let Some(budget) = self.budget {
            inner.enforce_budget(budget);
        }
        Ok((filtered, hit))
    }
}

/// A cached whole-query answer with its insertion time (logical, counted
/// in queries the owning client session has since processed).
#[derive(Clone)]
struct CachedResult {
    result: ApproxResult,
    strategy: String,
    mode: ExecutionMode,
    inserted: u64,
}

/// What a [`ResultCache`] lookup returns: the stored answer with its CI
/// widened by age.
#[derive(Clone, Debug)]
pub struct CachedAnswer {
    pub result: ApproxResult,
    pub strategy: String,
    pub mode: ExecutionMode,
    /// Queries processed by this client since the answer was computed.
    pub age: u64,
}

/// Per-client-session result cache. Staleness is not hidden: a hit aged
/// `age` logical queries widens the stored half-width by
/// `1 + widening * age`, so a consumer can always see how much confidence
/// the shortcut cost. Entries older than `max_age` are recomputed.
pub struct ResultCache {
    widening: f64,
    max_age: u64,
    entries: HashMap<String, CachedResult>,
    seq: u64,
    hits: u64,
    lookups: u64,
}

impl ResultCache {
    pub fn new(widening: f64, max_age: u64) -> Self {
        Self {
            widening,
            max_age,
            entries: HashMap::new(),
            seq: 0,
            hits: 0,
            lookups: 0,
        }
    }

    /// Advance the logical clock — one tick per query the owning session
    /// processes (hit or miss), so `age` means "queries since computed".
    pub fn tick(&mut self) {
        self.seq += 1;
    }

    pub fn lookup(&mut self, key: &str) -> Option<CachedAnswer> {
        self.lookups += 1;
        let Some(entry) = self.entries.get(key) else {
            return None;
        };
        let age = self.seq.saturating_sub(entry.inserted);
        if age > self.max_age {
            self.entries.remove(key);
            return None;
        }
        self.hits += 1;
        let mut result = entry.result;
        result.error_bound *= 1.0 + self.widening * age as f64;
        Some(CachedAnswer {
            result,
            strategy: entry.strategy.clone(),
            mode: entry.mode,
            age,
        })
    }

    pub fn insert(
        &mut self,
        key: String,
        result: ApproxResult,
        strategy: &str,
        mode: ExecutionMode,
    ) {
        self.entries.insert(
            key,
            CachedResult {
                result,
                strategy: strategy.to_string(),
                mode,
                inserted: self.seq,
            },
        );
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn lookups(&self) -> u64 {
        self.lookups
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bloom::FilterKind;

    fn cfg() -> FilterConfig {
        FilterConfig {
            log2_bits: 12,
            num_hashes: 4,
            kind: FilterKind::Standard,
        }
    }

    fn tables() -> Vec<String> {
        vec!["a".to_string(), "b".to_string()]
    }

    #[test]
    fn filter_key_changes_with_each_component() {
        let epochs = HashMap::new();
        let base = SketchCache::filter_key(&epochs, &tables(), "", cfg(), 4);
        // predicate
        assert_ne!(base, SketchCache::filter_key(&epochs, &tables(), "a.value>0.5", cfg(), 4));
        // filter kind
        let blocked = FilterConfig {
            kind: FilterKind::Blocked,
            ..cfg()
        };
        assert_ne!(base, SketchCache::filter_key(&epochs, &tables(), "", blocked, 4));
        // geometry
        let bigger = FilterConfig {
            log2_bits: 13,
            ..cfg()
        };
        assert_ne!(base, SketchCache::filter_key(&epochs, &tables(), "", bigger, 4));
        // workers
        assert_ne!(base, SketchCache::filter_key(&epochs, &tables(), "", cfg(), 8));
        // table registration epoch
        let mut bumped = HashMap::new();
        bumped.insert("a".to_string(), 1u64);
        assert_ne!(base, SketchCache::filter_key(&bumped, &tables(), "", cfg(), 4));
    }

    #[test]
    fn permuted_table_order_shares_filter_key_not_cogroup_key() {
        let epochs = HashMap::new();
        let ab = tables();
        let ba = vec!["b".to_string(), "a".to_string()];
        let f1 = SketchCache::filter_key(&epochs, &ab, "", cfg(), 4);
        let f2 = SketchCache::filter_key(&epochs, &ba, "", cfg(), 4);
        // the join filter is order-independent: one entry serves both
        assert_eq!(f1, f2);
        // the filtered cogroup is order-sensitive: distinct entries
        let c1 = SketchCache::cogroup_key(&f1, &ab, "value", JoinVariant::Inner);
        let c2 = SketchCache::cogroup_key(&f2, &ba, "value", JoinVariant::Inner);
        assert_ne!(c1, c2);
        assert!(c1.contains("|ord=a>b|"));
        assert!(c2.contains("|ord=b>a|"));
    }

    #[test]
    fn cogroup_key_separates_join_variants() {
        let epochs = HashMap::new();
        let fkey = SketchCache::filter_key(&epochs, &tables(), "", cfg(), 4);
        let keys: Vec<String> = JoinVariant::ALL
            .iter()
            .map(|&v| SketchCache::cogroup_key(&fkey, &tables(), "value", v))
            .collect();
        for (i, a) in keys.iter().enumerate() {
            for b in &keys[i + 1..] {
                assert_ne!(a, b, "variants must never share a cogroup entry");
            }
        }
        assert!(keys[0].ends_with("|v=inner"));
    }

    fn cg_entry(bytes: u64) -> CachedCogroup {
        CachedCogroup {
            per_worker: Arc::new(Vec::new()),
            join_filter: JoinFilter::new(FilterKind::Standard, 6, 2),
            survivors: Vec::new(),
            bytes,
        }
    }

    #[test]
    fn byte_budget_evicts_lru_cogroups_before_filters() {
        // 3 cogroups x 60 B + one 8 B filter = 188 B against a 70 B budget:
        // the two least-recently-used cogroups go, the filter stays.
        let c = SketchCache::with_budget(Some(70));
        {
            let mut inner = c.inner.lock().unwrap();
            for (key, stamp) in [("c1", 1u64), ("c2", 5), ("c3", 3)] {
                inner.cogroups.insert(key.to_string(), cg_entry(60));
                inner.cogroup_use.insert(key.to_string(), stamp);
            }
            inner
                .filters
                .insert("f1".to_string(), JoinFilter::new(FilterKind::Standard, 6, 2));
            inner.filter_use.insert("f1".to_string(), 2);
            inner.clock = 6;
            inner.enforce_budget(70);
            assert!(inner.cogroups.contains_key("c2"), "newest cogroup survives");
            assert!(inner.filters.contains_key("f1"), "filters evict only after cogroups");
        }
        assert_eq!(c.entry_counts(), (1, 1));
        assert_eq!(c.stats().evictions, 2);
        assert_eq!(c.cached_bytes(), 68);
    }

    #[test]
    fn filters_evict_when_cogroups_alone_cannot_fit_the_budget() {
        let c = SketchCache::with_budget(Some(4));
        {
            let mut inner = c.inner.lock().unwrap();
            for (key, stamp) in [("f-old", 1u64), ("f-new", 2)] {
                inner
                    .filters
                    .insert(key.to_string(), JoinFilter::new(FilterKind::Standard, 6, 2));
                inner.filter_use.insert(key.to_string(), stamp);
            }
            inner.clock = 2;
            inner.enforce_budget(4);
        }
        // 16 B of filters against a 4 B budget: both go.
        assert_eq!(c.entry_counts(), (0, 0));
        assert_eq!(c.stats().evictions, 2);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn touching_an_entry_protects_it_from_eviction() {
        let c = SketchCache::with_budget(Some(60));
        let mut inner = c.inner.lock().unwrap();
        for (key, stamp) in [("c1", 1u64), ("c2", 2)] {
            inner.cogroups.insert(key.to_string(), cg_entry(60));
            inner.cogroup_use.insert(key.to_string(), stamp);
        }
        inner.clock = 2;
        {
            let Inner {
                clock, cogroup_use, ..
            } = &mut *inner;
            // a replay hit re-stamps c1, so c2 becomes the LRU victim
            Inner::touch(clock, cogroup_use, "c1");
        }
        inner.enforce_budget(60);
        assert_eq!(inner.clock, 3);
        assert!(inner.cogroups.contains_key("c1"));
        assert!(!inner.cogroups.contains_key("c2"));
        assert_eq!(inner.stats.evictions, 1);
    }

    #[test]
    fn stats_since_subtracts_evictions() {
        let a = SketchStats {
            cogroup_hits: 2,
            filter_hits: 1,
            misses: 3,
            evictions: 1,
        };
        let b = SketchStats {
            cogroup_hits: 5,
            filter_hits: 1,
            misses: 4,
            evictions: 3,
        };
        let d = b.since(&a);
        assert_eq!(
            d,
            SketchStats {
                cogroup_hits: 3,
                filter_hits: 0,
                misses: 1,
                evictions: 2,
            }
        );
    }

    #[test]
    fn invalidate_bumps_epoch_and_prunes() {
        let c = SketchCache::new();
        assert_eq!(c.epoch_of("a"), 0);
        c.invalidate("a");
        assert_eq!(c.epoch_of("a"), 1);
        assert_eq!(c.epoch_of("b"), 0);
    }

    #[test]
    fn result_cache_widens_with_age_and_expires() {
        let mut rc = ResultCache::new(0.5, 2);
        let r = ApproxResult {
            estimate: 100.0,
            error_bound: 10.0,
            confidence: 0.95,
            degrees_of_freedom: 9.0,
            samples: 10,
        };
        rc.insert("k".into(), r, "approx", ExecutionMode::Exact);
        // same tick: age 0, unwidened
        let a = rc.lookup("k").unwrap();
        assert_eq!(a.age, 0);
        assert_eq!(a.result.error_bound, 10.0);
        // two ticks later: widened by 1 + 0.5*2
        rc.tick();
        rc.tick();
        let a = rc.lookup("k").unwrap();
        assert_eq!(a.age, 2);
        assert!((a.result.error_bound - 20.0).abs() < 1e-12);
        // past max_age: evicted, recompute
        rc.tick();
        assert!(rc.lookup("k").is_none());
        assert_eq!(rc.hits(), 2);
        assert_eq!(rc.lookups(), 3);
    }
}
