//! Bloom-filter substrate (paper §3.1 + Appendix B): the standard filter
//! used by the join-filter construction, plus the three alternative designs
//! the paper analyzes (counting, invertible, scalable) and the shared hash
//! family that keeps Rust and the AOT Pallas kernel bit-compatible.

pub mod counting;
pub mod hashing;
pub mod invertible;
pub mod scalable;
pub mod standard;

pub use counting::CountingBloomFilter;
pub use invertible::InvertibleBloomFilter;
pub use scalable::ScalableBloomFilter;
pub use standard::BloomFilter;
