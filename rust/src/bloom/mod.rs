//! Bloom-filter substrate (paper §3.1 + Appendix B): the standard filter
//! used by the join-filter construction, the three alternative designs
//! the paper analyzes (counting, invertible, scalable), the cache-line
//! [`BlockedBloomFilter`] hot-path variant, and the shared hash family
//! that keeps Rust and the AOT Pallas kernel bit-compatible.
//!
//! [`JoinFilter`] is the kind-dispatched filter the join kernel builds,
//! merges and broadcasts: [`FilterKind::Standard`] is the default
//! bit-compatible-with-the-XLA-artifact layout; [`FilterKind::Blocked`]
//! is the opt-in one-cache-line-per-probe layout (same no-false-negative
//! and OR/AND algebra, slightly higher false-positive rate).

pub mod blocked;
pub mod counting;
pub mod hashing;
pub mod invertible;
pub mod scalable;
pub mod standard;

pub use blocked::BlockedBloomFilter;
pub use counting::CountingBloomFilter;
pub use invertible::InvertibleBloomFilter;
pub use scalable::ScalableBloomFilter;
pub use standard::BloomFilter;

/// Which bit layout the join kernel's filters use — the planner/engine
/// config switch behind the blocked hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FilterKind {
    /// k independent scattered bit positions (the paper's filter; the AOT
    /// `bloom_probe` artifact understands exactly this layout).
    #[default]
    Standard,
    /// All k bits inside one 64-byte block: one memory access per probe,
    /// two hash draws total, at a slightly higher false-positive rate.
    Blocked,
}

impl FilterKind {
    /// The minimum `log2_bits` / `log2_cells` a filter of this kind
    /// supports (blocked filters need at least one 512-bit block).
    pub fn min_log2(&self) -> u32 {
        match self {
            FilterKind::Standard => 5,
            FilterKind::Blocked => blocked::BLOCK_SHIFT,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FilterKind::Standard => "standard",
            FilterKind::Blocked => "blocked",
        }
    }
}

impl std::fmt::Display for FilterKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Either-style iterator unifying the two probe-position sequences.
enum Positions<A, B> {
    Standard(A),
    Blocked(B),
}

impl<A: Iterator<Item = u32>, B: Iterator<Item = u32>> Iterator for Positions<A, B> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        match self {
            Positions::Standard(it) => it.next(),
            Positions::Blocked(it) => it.next(),
        }
    }
}

/// The probe/cell positions of `key` under either addressing scheme —
/// shared by the counting sketch so its cell layout matches the bit
/// filter of the same kind exactly.
#[inline]
pub fn positions_for(
    kind: FilterKind,
    key: u32,
    num_hashes: u32,
    log2_bits: u32,
) -> impl Iterator<Item = u32> {
    match kind {
        FilterKind::Standard => {
            Positions::Standard(hashing::probe_positions(key, num_hashes, log2_bits))
        }
        FilterKind::Blocked => {
            Positions::Blocked(blocked::blocked_probe_positions(key, num_hashes, log2_bits))
        }
    }
}

/// How much of a run's stage-1 work was served from the serving layer's
/// sketch cache ([`crate::serve::SketchCache`]). `None` outside the
/// serving layer; `Filter` means the built join filter was reused (probe
/// and shuffle still ran); `Cogroup` means the whole filtered cogroup was
/// replayed and stage 1 was skipped entirely.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SketchCacheHit {
    #[default]
    None,
    Filter,
    Cogroup,
}

/// What a join run reports about the filter it built — kind, geometry,
/// and the fill-derived false-positive estimate measured *after* the
/// build; `JoinPlan::explain()` renders it next to the predictions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FilterReport {
    pub kind: FilterKind,
    pub log2_bits: u32,
    pub num_hashes: u32,
    /// Expected fp rate at the measured fill (block-aware for blocked
    /// filters: mean over blocks of fill_b^h).
    pub fp_rate: f64,
    pub size_bytes: u64,
    /// Whether this run reused a cached sketch instead of building one.
    pub cached: SketchCacheHit,
}

impl FilterReport {
    pub fn render(&self) -> String {
        let cache_note = match self.cached {
            SketchCacheHit::None => "",
            SketchCacheHit::Filter => " [sketch cache: filter hit]",
            SketchCacheHit::Cogroup => " [sketch cache: cogroup hit]",
        };
        format!(
            "{} filter 2^{} bits h={} ({} B), measured-fill fp {:.4}%{}",
            self.kind,
            self.log2_bits,
            self.num_hashes,
            self.size_bytes,
            self.fp_rate * 100.0,
            cache_note
        )
    }

    /// The same report, marked as served from the sketch cache.
    pub fn with_cache_hit(mut self, hit: SketchCacheHit) -> Self {
        self.cached = hit;
        self
    }
}

/// A join-kernel filter of either kind, with the uniform build / OR / AND
/// / broadcast surface Algorithm 1 needs. The standard arm wraps the
/// exact [`BloomFilter`] the AOT prober understands; the blocked arm is
/// the cache-line hot path.
#[derive(Clone, Debug, PartialEq)]
pub enum JoinFilter {
    Standard(BloomFilter),
    Blocked(BlockedBloomFilter),
}

impl JoinFilter {
    /// An empty filter of the given kind and geometry.
    pub fn new(kind: FilterKind, log2_bits: u32, num_hashes: u32) -> Self {
        match kind {
            FilterKind::Standard => JoinFilter::Standard(BloomFilter::new(log2_bits, num_hashes)),
            FilterKind::Blocked => {
                JoinFilter::Blocked(BlockedBloomFilter::new(log2_bits, num_hashes))
            }
        }
    }

    pub fn kind(&self) -> FilterKind {
        match self {
            JoinFilter::Standard(_) => FilterKind::Standard,
            JoinFilter::Blocked(_) => FilterKind::Blocked,
        }
    }

    /// The wrapped standard filter, when this is one — the XLA prober
    /// only consumes the standard layout.
    pub fn as_standard(&self) -> Option<&BloomFilter> {
        match self {
            JoinFilter::Standard(f) => Some(f),
            JoinFilter::Blocked(_) => None,
        }
    }

    pub fn log2_bits(&self) -> u32 {
        match self {
            JoinFilter::Standard(f) => f.log2_bits(),
            JoinFilter::Blocked(f) => f.log2_bits(),
        }
    }

    pub fn num_hashes(&self) -> u32 {
        match self {
            JoinFilter::Standard(f) => f.num_hashes(),
            JoinFilter::Blocked(f) => f.num_hashes(),
        }
    }

    pub fn size_bytes(&self) -> u64 {
        match self {
            JoinFilter::Standard(f) => f.size_bytes(),
            JoinFilter::Blocked(f) => f.size_bytes(),
        }
    }

    pub fn items(&self) -> u64 {
        match self {
            JoinFilter::Standard(f) => f.items(),
            JoinFilter::Blocked(f) => f.items(),
        }
    }

    pub fn insert(&mut self, key: u32) {
        match self {
            JoinFilter::Standard(f) => f.insert(key),
            JoinFilter::Blocked(f) => f.insert(key),
        }
    }

    pub fn insert_key64(&mut self, key: u64) {
        self.insert(hashing::fold_key(key));
    }

    #[inline]
    pub fn contains(&self, key: u32) -> bool {
        match self {
            JoinFilter::Standard(f) => f.contains(key),
            JoinFilter::Blocked(f) => f.contains(key),
        }
    }

    #[inline]
    pub fn contains_key64(&self, key: u64) -> bool {
        self.contains(hashing::fold_key(key))
    }

    /// OR-merge; both sides must be the same kind and geometry.
    pub fn union_with(&mut self, other: &JoinFilter) {
        match (self, other) {
            (JoinFilter::Standard(a), JoinFilter::Standard(b)) => a.union_with(b),
            (JoinFilter::Blocked(a), JoinFilter::Blocked(b)) => a.union_with(b),
            _ => panic!("filter kind mismatch in union"),
        }
    }

    /// AND-merge; both sides must be the same kind and geometry.
    pub fn intersect_with(&mut self, other: &JoinFilter) {
        match (self, other) {
            (JoinFilter::Standard(a), JoinFilter::Standard(b)) => a.intersect_with(b),
            (JoinFilter::Blocked(a), JoinFilter::Blocked(b)) => a.intersect_with(b),
            _ => panic!("filter kind mismatch in intersection"),
        }
    }

    /// Expected false-positive rate at the current fill (block-aware on
    /// the blocked arm).
    pub fn current_fp_rate(&self) -> f64 {
        match self {
            JoinFilter::Standard(f) => f.current_fp_rate(),
            JoinFilter::Blocked(f) => f.current_fp_rate(),
        }
    }

    pub fn estimate_cardinality(&self) -> f64 {
        match self {
            JoinFilter::Standard(f) => f.estimate_cardinality(),
            JoinFilter::Blocked(f) => f.estimate_cardinality(),
        }
    }

    /// The post-build filter report `explain()` prints.
    pub fn report(&self) -> FilterReport {
        FilterReport {
            kind: self.kind(),
            log2_bits: self.log2_bits(),
            num_hashes: self.num_hashes(),
            fp_rate: self.current_fp_rate(),
            size_bytes: self.size_bytes(),
            cached: SketchCacheHit::None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positions_for_dispatches_to_both_schemes() {
        let std_pos: Vec<u32> = positions_for(FilterKind::Standard, 42, 5, 20).collect();
        assert_eq!(std_pos, vec![650960, 828291, 1005622, 134377, 311708]);
        let blk_pos: Vec<u32> = positions_for(FilterKind::Blocked, 42, 5, 20).collect();
        let block = blk_pos[0] / blocked::BLOCK_BITS;
        assert!(blk_pos.iter().all(|&p| p / blocked::BLOCK_BITS == block));
        assert_eq!(
            blk_pos,
            blocked::blocked_probe_positions(42, 5, 20).collect::<Vec<_>>()
        );
    }

    #[test]
    fn join_filter_uniform_surface_both_kinds() {
        for kind in [FilterKind::Standard, FilterKind::Blocked] {
            let mut a = JoinFilter::new(kind, 16, 5);
            let mut b = JoinFilter::new(kind, 16, 5);
            for k in 0..500u64 {
                a.insert_key64(k);
                b.insert_key64(k + 250);
            }
            let mut u = a.clone();
            u.union_with(&b);
            assert!((0..750u64).all(|k| u.contains_key64(k)), "{kind}");
            a.intersect_with(&b);
            assert!((250..500u64).all(|k| a.contains_key64(k)), "{kind}");
            assert_eq!(a.kind(), kind);
            assert_eq!(a.size_bytes(), (1u64 << 16) / 8);
            let r = a.report();
            assert_eq!(r.kind, kind);
            assert!(r.fp_rate >= 0.0 && r.fp_rate < 1.0);
            assert!(r.render().contains(kind.label()));
        }
    }

    #[test]
    fn as_standard_only_on_standard() {
        assert!(JoinFilter::new(FilterKind::Standard, 12, 4)
            .as_standard()
            .is_some());
        assert!(JoinFilter::new(FilterKind::Blocked, 12, 4)
            .as_standard()
            .is_none());
    }

    #[test]
    #[should_panic(expected = "kind mismatch")]
    fn mixed_kind_merge_panics() {
        let mut a = JoinFilter::new(FilterKind::Standard, 12, 4);
        let b = JoinFilter::new(FilterKind::Blocked, 12, 4);
        a.union_with(&b);
    }

    #[test]
    fn min_log2_per_kind() {
        assert_eq!(FilterKind::Standard.min_log2(), 5);
        assert_eq!(FilterKind::Blocked.min_log2(), 9);
        assert_eq!(FilterKind::default(), FilterKind::Standard);
    }
}
