//! Register-blocked Bloom filter (*Performance-Optimal Filtering*-style,
//! see PAPERS.md): every key maps to exactly one 64-byte cache-line block,
//! and all k bits live inside that block — a probe touches **one** cache
//! line instead of k scattered ones, and the in-block bit positions derive
//! from a single second hash draw, so the whole membership test is two
//! `mix32` calls plus one block's worth of word reads.
//!
//! Trade-off: packing all k bits of a key into 512 bits makes per-block
//! load uneven (blocks are Poisson-loaded), which raises the
//! false-positive rate somewhat above a standard filter of the same size —
//! the classic blocked-filter speed-for-fp trade. No-false-negative and
//! the OR/AND merge algebra are preserved exactly, so the join-filter
//! construction (Algorithm 1) works unchanged; the planner/engine opt into
//! this filter via [`super::FilterKind::Blocked`].

use super::hashing::{mix32, SEED1, SEED2};

/// Bits per block: one 64-byte cache line.
pub const BLOCK_BITS: u32 = 512;
/// u32 words per block.
pub const BLOCK_WORDS: usize = 16;
/// log2(BLOCK_BITS) — the minimum filter log2_bits.
pub const BLOCK_SHIFT: u32 = 9;
const BLOCK_MASK: u32 = BLOCK_BITS - 1;

/// The two hash draws of the blocked scheme: the block index (from h1) and
/// the in-block probe sequence seed `(d1, d2)` (both from h2; d2 is odd so
/// the k offsets `d1 + i·d2 mod 512` are pairwise distinct for k ≤ 512).
#[inline]
fn block_probe(key: u32, log2_bits: u32) -> (usize, u32, u32) {
    let h1 = mix32(key ^ SEED1);
    let h2 = mix32(key ^ SEED2);
    let block = h1 & ((1u32 << (log2_bits - BLOCK_SHIFT)) - 1);
    let d1 = h2 & BLOCK_MASK;
    let d2 = ((h2 >> BLOCK_SHIFT) & BLOCK_MASK) | 1;
    (block as usize * BLOCK_WORDS, d1, d2)
}

/// The i-th global bit positions of `key` — the blocked analogue of
/// [`super::hashing::probe_positions`], shared with the counting sketch so
/// a counting filter with blocked addressing collapses to exactly this
/// filter's bit layout ([`super::CountingBloomFilter::to_join_filter`]).
#[inline]
pub fn blocked_probe_positions(
    key: u32,
    num_hashes: u32,
    log2_bits: u32,
) -> impl Iterator<Item = u32> {
    let (word_base, d1, d2) = block_probe(key, log2_bits);
    let bit_base = word_base as u32 * 32;
    (0..num_hashes).map(move |i| bit_base + (d1.wrapping_add(i.wrapping_mul(d2)) & BLOCK_MASK))
}

/// A cache-line-blocked Bloom filter over pre-folded u32 keys, with the
/// same build / OR / AND / broadcast surface as [`super::BloomFilter`].
#[derive(Clone, Debug, PartialEq)]
pub struct BlockedBloomFilter {
    /// Packed bits, identical word layout to the standard filter
    /// (bit p ⇔ words[p >> 5] & (1 << (p & 31))), but positions are
    /// confined to one block per key.
    words: Vec<u32>,
    log2_bits: u32,
    num_hashes: u32,
    items: u64,
}

impl BlockedBloomFilter {
    /// Filter with 2^log2_bits bits (≥ one block) and `num_hashes` in-block
    /// probes.
    pub fn new(log2_bits: u32, num_hashes: u32) -> Self {
        assert!(
            (BLOCK_SHIFT..=32).contains(&log2_bits),
            "blocked filter needs log2_bits in [{BLOCK_SHIFT}, 32], got {log2_bits}"
        );
        assert!((1..=16).contains(&num_hashes));
        Self {
            words: vec![0; 1usize << (log2_bits - 5)],
            log2_bits,
            num_hashes,
            items: 0,
        }
    }

    /// Geometry from a target capacity + false-positive rate: the standard
    /// eq-27 sizing with bits rounded up to a power of two, floored at one
    /// block. The power-of-two rounding slack absorbs most of the blocked
    /// fp inflation; [`BlockedBloomFilter::current_fp_rate`] reports the
    /// block-aware estimate.
    pub fn with_capacity(items: u64, fp_rate: f64) -> Self {
        let (log2, h) =
            super::hashing::pow2_geometry(items, fp_rate, BLOCK_SHIFT, 30);
        Self::new(log2, h)
    }

    pub fn log2_bits(&self) -> u32 {
        self.log2_bits
    }

    pub fn num_hashes(&self) -> u32 {
        self.num_hashes
    }

    pub fn num_bits(&self) -> u64 {
        1u64 << self.log2_bits
    }

    /// Items inserted so far (approximate after merges: summed).
    pub fn items(&self) -> u64 {
        self.items
    }

    /// Broadcast payload size in bytes — same accounting as the standard
    /// filter (the shuffle ledger prices both identically per bit).
    pub fn size_bytes(&self) -> u64 {
        (self.words.len() * 4) as u64
    }

    pub fn insert(&mut self, key: u32) {
        let (base, mut d1, d2) = block_probe(key, self.log2_bits);
        let block = &mut self.words[base..base + BLOCK_WORDS];
        for _ in 0..self.num_hashes {
            block[(d1 >> 5) as usize] |= 1 << (d1 & 31);
            d1 = (d1 + d2) & BLOCK_MASK;
        }
        self.items += 1;
    }

    pub fn insert_key64(&mut self, key: u64) {
        self.insert(super::hashing::fold_key(key));
    }

    /// One block load, k bit tests — the register-blocked hot probe.
    #[inline]
    pub fn contains(&self, key: u32) -> bool {
        let (base, mut d1, d2) = block_probe(key, self.log2_bits);
        let block = &self.words[base..base + BLOCK_WORDS];
        for _ in 0..self.num_hashes {
            if block[(d1 >> 5) as usize] & (1 << (d1 & 31)) == 0 {
                return false;
            }
            d1 = (d1 + d2) & BLOCK_MASK;
        }
        true
    }

    #[inline]
    pub fn contains_key64(&self, key: u64) -> bool {
        self.contains(super::hashing::fold_key(key))
    }

    /// OR-merge (set union) — Reduce phase of buildInputFilter.
    pub fn union_with(&mut self, other: &BlockedBloomFilter) {
        self.check_geometry(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
        self.items += other.items;
    }

    /// AND-merge (intersection superset) — join-filter construction. Both
    /// filters map any key to the same block and the same in-block bits,
    /// so the word-wise AND preserves every truly-common key, exactly like
    /// the standard filter.
    pub fn intersect_with(&mut self, other: &BlockedBloomFilter) {
        self.check_geometry(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
        self.items = self.items.min(other.items);
    }

    fn check_geometry(&self, other: &BlockedBloomFilter) {
        assert_eq!(self.log2_bits, other.log2_bits, "geometry mismatch");
        assert_eq!(self.num_hashes, other.num_hashes, "geometry mismatch");
    }

    /// Overall fraction of set bits.
    pub fn fill_ratio(&self) -> f64 {
        let set: u64 = self.words.iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / self.num_bits() as f64
    }

    /// Cardinality estimate from the overall fill (Swamidass & Baldi —
    /// the block structure leaves the expectation unchanged).
    pub fn estimate_cardinality(&self) -> f64 {
        let x = self.fill_ratio();
        if x >= 1.0 {
            return f64::INFINITY;
        }
        -(self.num_bits() as f64) / self.num_hashes as f64 * (1.0 - x).ln()
    }

    /// Block-aware expected false-positive rate at the current fill: a
    /// random key lands in a uniform block b and passes ≈ fill_b^h, so the
    /// estimate is the mean of fill_b^h over blocks — *not* the standard
    /// fill^h, which understates blocked filters (Jensen: per-block load
    /// skew raises the mean of the power). This is what `explain()`
    /// reports as the measured fp rate.
    pub fn current_fp_rate(&self) -> f64 {
        let n_blocks = self.words.len() / BLOCK_WORDS;
        let mut acc = 0.0;
        for b in 0..n_blocks {
            let set: u32 = self.words[b * BLOCK_WORDS..(b + 1) * BLOCK_WORDS]
                .iter()
                .map(|w| w.count_ones())
                .sum();
            acc += (set as f64 / BLOCK_BITS as f64).powi(self.num_hashes as i32);
        }
        acc / n_blocks as f64
    }

    /// The packed word array (same bit-addressing contract as the standard
    /// filter, positions block-confined).
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    pub fn from_words(words: Vec<u32>, log2_bits: u32, num_hashes: u32) -> Self {
        assert_eq!(words.len(), 1usize << (log2_bits - 5));
        assert!(log2_bits >= BLOCK_SHIFT);
        Self {
            words,
            log2_bits,
            num_hashes,
            items: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn no_false_negatives() {
        let mut r = Rng::new(1);
        let mut f = BlockedBloomFilter::new(16, 5);
        let keys: Vec<u32> = (0..2000).map(|_| r.next_u32()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(keys.iter().all(|&k| f.contains(k)));
    }

    #[test]
    fn positions_stay_inside_one_block() {
        for key in [0u32, 1, 42, 0xDEAD_BEEF, 123_456_789] {
            for log2 in [9u32, 16, 20] {
                let pos: Vec<u32> = blocked_probe_positions(key, 8, log2).collect();
                let block = pos[0] / BLOCK_BITS;
                assert!(pos.iter().all(|&p| p / BLOCK_BITS == block), "{key} {log2}");
                assert!(pos.iter().all(|&p| p < (1 << log2)));
                // d2 odd ⇒ all 8 offsets distinct
                let mut uniq = pos.clone();
                uniq.sort_unstable();
                uniq.dedup();
                assert_eq!(uniq.len(), 8, "{key} {log2}");
            }
        }
    }

    #[test]
    fn insert_matches_position_iterator() {
        // the filter's fast in-block walk and the shared position iterator
        // (used by the blocked counting sketch) must set the same bits
        let mut f = BlockedBloomFilter::new(14, 6);
        f.insert(777);
        let set: u32 = f.words().iter().map(|w| w.count_ones()).sum();
        assert_eq!(set, 6);
        for p in blocked_probe_positions(777, 6, 14) {
            assert_ne!(f.words()[(p >> 5) as usize] & (1 << (p & 31)), 0, "bit {p}");
        }
    }

    #[test]
    fn union_and_intersection_preserve_members() {
        let mut r = Rng::new(3);
        let mut a = BlockedBloomFilter::new(16, 5);
        let mut b = BlockedBloomFilter::new(16, 5);
        let common: Vec<u32> = (0..500).map(|_| r.next_u32()).collect();
        for &k in &common {
            a.insert(k);
            b.insert(k);
        }
        for _ in 0..2000 {
            a.insert(r.next_u32());
            b.insert(r.next_u32());
        }
        let mut u = a.clone();
        u.union_with(&b);
        a.intersect_with(&b);
        assert!(common.iter().all(|&k| a.contains(k)), "AND lost a common key");
        assert!(common.iter().all(|&k| u.contains(k)));
    }

    #[test]
    fn intersection_drops_most_noncommon() {
        let mut r = Rng::new(4);
        let mut a = BlockedBloomFilter::new(18, 5);
        let mut b = BlockedBloomFilter::new(18, 5);
        let only_a: Vec<u32> = (0..3000).map(|_| r.next_u32()).collect();
        for &k in &only_a {
            a.insert(k);
        }
        for _ in 0..3000 {
            b.insert(r.next_u32());
        }
        a.intersect_with(&b);
        let survivors = only_a.iter().filter(|&&k| a.contains(k)).count();
        assert!(survivors < 80, "survivors={survivors}");
    }

    #[test]
    fn fp_rate_estimate_tracks_measurement() {
        let mut r = Rng::new(5);
        let n = 20_000u64;
        let mut f = BlockedBloomFilter::with_capacity(n, 0.01);
        for _ in 0..n {
            f.insert(r.next_u32());
        }
        let probes = 50_000;
        let fps = (0..probes).filter(|_| f.contains(r.next_u32())).count();
        let measured = fps as f64 / probes as f64;
        let estimated = f.current_fp_rate();
        assert!(
            (measured - estimated).abs() < estimated * 0.5 + 0.003,
            "measured {measured} vs block-aware estimate {estimated}"
        );
        // sized for 1%: the blocked penalty must stay within 2x the target
        assert!(measured < 0.02, "measured fp {measured}");
    }

    #[test]
    #[should_panic(expected = "geometry")]
    fn merge_rejects_mismatched_geometry() {
        let mut a = BlockedBloomFilter::new(14, 4);
        let b = BlockedBloomFilter::new(15, 4);
        a.union_with(&b);
    }

    #[test]
    #[should_panic(expected = "log2_bits")]
    fn rejects_sub_block_geometry() {
        let _ = BlockedBloomFilter::new(8, 4);
    }

    #[test]
    fn key64_folding_no_false_negatives() {
        let mut f = BlockedBloomFilter::new(16, 5);
        let keys: Vec<u64> = (0..1000).map(|i| (i as u64) << 33 | i as u64).collect();
        for &k in &keys {
            f.insert_key64(k);
        }
        assert!(keys.iter().all(|&k| f.contains_key64(k)));
    }

    #[test]
    fn cardinality_estimate_close() {
        let mut r = Rng::new(6);
        let n = 5_000;
        let mut f = BlockedBloomFilter::new(17, 5);
        for _ in 0..n {
            f.insert(r.next_u32());
        }
        let est = f.estimate_cardinality();
        assert!((est - n as f64).abs() / (n as f64) < 0.06, "est={est} n={n}");
    }
}
