//! Scalable Bloom filter (paper Appendix B III, after Almeida et al.):
//! a series of standard filters of geometrically growing size and
//! geometrically tightening error probability, for when the input
//! cardinality is unknown in advance. Includes the *union* operation the
//! paper contributed upstream as a pull request — implemented here by
//! slice-wise union of the underlying standard filters.

use super::standard::BloomFilter;

/// Growth factor for successive slices (Almeida et al. recommend 2-4).
const GROWTH: u32 = 1; // log2 increment: each slice doubles
/// Error-probability tightening ratio r.
const TIGHTEN: f64 = 0.5;

#[derive(Clone, Debug)]
pub struct ScalableBloomFilter {
    slices: Vec<BloomFilter>,
    slice_capacity: Vec<u64>,
    initial_log2: u32,
    fp0: f64,
    items: u64,
}

impl ScalableBloomFilter {
    /// Start with 2^initial_log2 bits targeting `fp0` overall error.
    pub fn new(initial_log2: u32, fp0: f64) -> Self {
        assert!(fp0 > 0.0 && fp0 < 1.0);
        let mut s = Self {
            slices: Vec::new(),
            slice_capacity: Vec::new(),
            initial_log2,
            fp0,
            items: 0,
        };
        s.grow();
        s
    }

    fn slice_fp(&self, i: usize) -> f64 {
        self.fp0 * TIGHTEN.powi(i as i32)
    }

    fn grow(&mut self) {
        let i = self.slices.len();
        let log2 = self.initial_log2 + GROWTH * i as u32;
        let fp = self.slice_fp(i);
        // capacity such that the slice stays within its fp budget:
        // n = m (ln2)^2 / -ln p   (inverse of eq 27)
        let m = (1u64 << log2) as f64;
        let cap = (m * std::f64::consts::LN_2.powi(2) / -fp.ln()).floor() as u64;
        let h = (-(fp.log2())).ceil().max(1.0) as u32; // k = log2(1/p)
        self.slices.push(BloomFilter::new(log2, h.clamp(1, 16)));
        self.slice_capacity.push(cap.max(1));
    }

    pub fn insert(&mut self, key: u32) {
        let last = self.slices.len() - 1;
        if self.slices[last].items() >= self.slice_capacity[last] {
            self.grow();
        }
        let last = self.slices.len() - 1;
        self.slices[last].insert(key);
        self.items += 1;
    }

    pub fn contains(&self, key: u32) -> bool {
        self.slices.iter().any(|s| s.contains(key))
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    pub fn num_slices(&self) -> usize {
        self.slices.len()
    }

    pub fn size_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.size_bytes()).sum()
    }

    /// Union of two SBFs — the merge the treeReduce stage needs. Aligns
    /// slice-by-slice (same initial geometry required) and unions the
    /// underlying standard filters; the taller filter's extra slices are
    /// cloned in. This is the operation the paper submitted upstream
    /// (python-bloomfilter PR #11).
    pub fn union_with(&mut self, other: &ScalableBloomFilter) {
        assert_eq!(self.initial_log2, other.initial_log2, "geometry mismatch");
        assert_eq!(self.fp0, other.fp0, "geometry mismatch");
        while self.slices.len() < other.slices.len() {
            self.grow();
        }
        for (i, os) in other.slices.iter().enumerate() {
            self.slices[i].union_with(os);
        }
        self.items += other.items;
    }

    /// Overall false-positive upper bound: 1 − Π(1 − p_i) ≤ fp0 / (1 − r).
    pub fn fp_bound(&self) -> f64 {
        let mut keep = 1.0;
        for i in 0..self.slices.len() {
            keep *= 1.0 - self.slice_fp(i);
        }
        1.0 - keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn grows_past_initial_capacity() {
        let mut r = Rng::new(12);
        let mut f = ScalableBloomFilter::new(10, 0.01); // tiny initial slice
        let keys: Vec<u32> = (0..5000).map(|_| r.next_u32()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(f.num_slices() > 1, "should have grown");
        assert!(keys.iter().all(|&k| f.contains(k)), "no false negatives");
    }

    #[test]
    fn fp_rate_within_bound() {
        let mut r = Rng::new(13);
        let mut f = ScalableBloomFilter::new(12, 0.01);
        for _ in 0..20_000 {
            f.insert(r.next_u32());
        }
        let probes = 100_000;
        let fps = (0..probes).filter(|_| f.contains(r.next_u32())).count();
        let measured = fps as f64 / probes as f64;
        // overall bound is fp0/(1-r) = 0.02; allow noise
        assert!(measured < 0.03, "fp={measured}");
    }

    #[test]
    fn union_contains_both() {
        let mut r = Rng::new(14);
        let mut a = ScalableBloomFilter::new(10, 0.01);
        let mut b = ScalableBloomFilter::new(10, 0.01);
        let ka: Vec<u32> = (0..3000).map(|_| r.next_u32()).collect();
        let kb: Vec<u32> = (0..100).map(|_| r.next_u32()).collect();
        for &k in &ka {
            a.insert(k);
        }
        for &k in &kb {
            b.insert(k);
        }
        // union taller into shorter and vice versa
        let mut u1 = b.clone();
        u1.union_with(&a);
        assert!(ka.iter().all(|&k| u1.contains(k)));
        assert!(kb.iter().all(|&k| u1.contains(k)));
        let mut u2 = a;
        u2.union_with(&b);
        assert!(ka.iter().all(|&k| u2.contains(k)));
        assert!(kb.iter().all(|&k| u2.contains(k)));
    }

    #[test]
    #[should_panic(expected = "geometry")]
    fn union_rejects_mismatched() {
        let mut a = ScalableBloomFilter::new(10, 0.01);
        let b = ScalableBloomFilter::new(11, 0.01);
        a.union_with(&b);
    }

    #[test]
    fn size_grows_sublinearly_in_slices() {
        let mut r = Rng::new(15);
        let mut f = ScalableBloomFilter::new(10, 0.01);
        let s0 = f.size_bytes();
        for _ in 0..50_000 {
            f.insert(r.next_u32());
        }
        assert!(f.size_bytes() > s0);
        // later slices dominate: total < 2.5x the last slice
        assert!(f.num_slices() >= 2);
    }

    #[test]
    fn fp_bound_formula() {
        let f = ScalableBloomFilter::new(10, 0.01);
        assert!(f.fp_bound() < 0.02 + 1e-9);
    }
}
