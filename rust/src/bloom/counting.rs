//! Counting Bloom filter (paper Appendix B II): each cell is a small
//! counter instead of a bit, which buys a remove/subtract operation at a
//! 4-bit-per-cell (here: 8-bit, the common implementation) size cost —
//! exactly the trade-off Figure 15 plots.

use super::hashing::probe_positions;

/// Counting Bloom filter with u8 saturating cells.
#[derive(Clone, Debug)]
pub struct CountingBloomFilter {
    cells: Vec<u8>,
    log2_cells: u32,
    num_hashes: u32,
    items: u64,
}

impl CountingBloomFilter {
    pub fn new(log2_cells: u32, num_hashes: u32) -> Self {
        assert!((5..=30).contains(&log2_cells));
        Self {
            cells: vec![0; 1usize << log2_cells],
            log2_cells,
            num_hashes,
            items: 0,
        }
    }

    pub fn insert(&mut self, key: u32) {
        for p in probe_positions(key, self.num_hashes, self.log2_cells) {
            let c = &mut self.cells[p as usize];
            *c = c.saturating_add(1);
        }
        self.items += 1;
    }

    pub fn contains(&self, key: u32) -> bool {
        probe_positions(key, self.num_hashes, self.log2_cells).all(|p| self.cells[p as usize] > 0)
    }

    /// Remove a key. Saturated cells (255) are left untouched to avoid
    /// introducing false negatives; this is the standard CBF compromise.
    pub fn remove(&mut self, key: u32) {
        if !self.contains(key) {
            return;
        }
        for p in probe_positions(key, self.num_hashes, self.log2_cells) {
            let c = &mut self.cells[p as usize];
            if *c > 0 && *c < u8::MAX {
                *c -= 1;
            }
        }
        self.items = self.items.saturating_sub(1);
    }

    /// Cell-wise sum (multiset union).
    pub fn union_with(&mut self, other: &CountingBloomFilter) {
        assert_eq!(self.log2_cells, other.log2_cells, "geometry mismatch");
        assert_eq!(self.num_hashes, other.num_hashes, "geometry mismatch");
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            *a = a.saturating_add(*b);
        }
        self.items += other.items;
    }

    /// Cell-wise min — the CBF analogue of the AND join-filter merge.
    pub fn intersect_with(&mut self, other: &CountingBloomFilter) {
        assert_eq!(self.log2_cells, other.log2_cells, "geometry mismatch");
        assert_eq!(self.num_hashes, other.num_hashes, "geometry mismatch");
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            *a = (*a).min(*b);
        }
        self.items = self.items.min(other.items);
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    /// One byte per cell — 8x a standard filter of equal cell count
    /// (Figure 15's CBF >> BF gap).
    pub fn size_bytes(&self) -> u64 {
        self.cells.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn insert_contains_remove() {
        let mut f = CountingBloomFilter::new(14, 4);
        f.insert(10);
        f.insert(20);
        assert!(f.contains(10) && f.contains(20));
        f.remove(10);
        assert!(!f.contains(10) || f.contains(20)); // 10 may collide w/ 20
        assert!(f.contains(20), "removal must not break other keys");
    }

    #[test]
    fn remove_of_duplicate_inserts() {
        let mut f = CountingBloomFilter::new(14, 4);
        f.insert(7);
        f.insert(7);
        f.remove(7);
        assert!(f.contains(7), "one copy should remain");
        f.remove(7);
        assert!(!f.contains(7));
    }

    #[test]
    fn remove_absent_is_noop() {
        let mut f = CountingBloomFilter::new(14, 4);
        f.insert(1);
        f.remove(999);
        assert!(f.contains(1));
        assert_eq!(f.items(), 1);
    }

    #[test]
    fn no_false_negatives_bulk() {
        let mut r = Rng::new(8);
        let mut f = CountingBloomFilter::new(16, 5);
        let keys: Vec<u32> = (0..3000).map(|_| r.next_u32()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(keys.iter().all(|&k| f.contains(k)));
    }

    #[test]
    fn union_and_intersection() {
        let mut a = CountingBloomFilter::new(14, 4);
        let mut b = CountingBloomFilter::new(14, 4);
        a.insert(1);
        b.insert(2);
        let mut u = a.clone();
        u.union_with(&b);
        assert!(u.contains(1) && u.contains(2));
        a.insert(3);
        b.insert(3);
        a.intersect_with(&b);
        assert!(a.contains(3));
        assert!(!a.contains(1) || !a.contains(2));
    }

    #[test]
    fn size_is_8x_standard() {
        let f = CountingBloomFilter::new(14, 4);
        let s = super::super::standard::BloomFilter::new(14, 4);
        assert_eq!(f.size_bytes(), 8 * s.size_bytes());
    }

    #[test]
    fn saturation_does_not_false_negative() {
        let mut f = CountingBloomFilter::new(8, 2);
        // force counters to saturate
        for i in 0..100_000u32 {
            f.insert(i);
        }
        for i in 0..100u32 {
            assert!(f.contains(i));
        }
        // removes on saturated cells must not create false negatives
        for i in 0..100u32 {
            f.remove(i);
        }
        // keys inserted many times over saturated cells still present
        assert!(f.contains(100_001u32.wrapping_mul(3) % 100_000));
    }
}
