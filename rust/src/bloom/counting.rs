//! Counting Bloom filter (paper Appendix B II): each cell is a small
//! counter instead of a bit, which buys a remove/subtract operation at a
//! 4-bit-per-cell (here: 8-bit, the common implementation) size cost —
//! exactly the trade-off Figure 15 plots.

use super::hashing::{self, fold_key};
use super::standard::BloomFilter;
use super::{positions_for, FilterKind, JoinFilter};

/// Counting Bloom filter with u8 saturating cells. Cells are addressed by
/// the same position family as the bit filters — standard scattered
/// positions by default, or cache-line-blocked positions
/// ([`FilterKind::Blocked`]) so the sketch's bit view collapses to exactly
/// a [`super::BlockedBloomFilter`] layout.
#[derive(Clone, Debug)]
pub struct CountingBloomFilter {
    cells: Vec<u8>,
    log2_cells: u32,
    num_hashes: u32,
    items: u64,
    kind: FilterKind,
}

impl CountingBloomFilter {
    pub fn new(log2_cells: u32, num_hashes: u32) -> Self {
        Self::new_kind(log2_cells, num_hashes, FilterKind::Standard)
    }

    /// A counting filter whose cells follow `kind`'s addressing scheme.
    pub fn new_kind(log2_cells: u32, num_hashes: u32, kind: FilterKind) -> Self {
        assert!((kind.min_log2().max(5)..=30).contains(&log2_cells));
        Self {
            cells: vec![0; 1usize << log2_cells],
            log2_cells,
            num_hashes,
            items: 0,
            kind,
        }
    }

    /// Geometry from a target capacity + false-positive rate (eq 27 applied
    /// to the cell count), cells rounded up to a power of two, with the
    /// optimal hash count. NOTE: the streaming window sketch
    /// (`stream::SketchConfig::for_capacity`) shares the cell sizing but
    /// caps the hash count at 6 — size a filter meant to merge with a
    /// window sketch from that config, not from here, or the geometries
    /// can mismatch.
    pub fn with_capacity(items: u64, fp_rate: f64) -> Self {
        Self::with_capacity_kind(items, fp_rate, FilterKind::Standard)
    }

    /// Capacity-sized filter with `kind` cell addressing (blocked kinds
    /// floor the cell count at one 512-cell block).
    pub fn with_capacity_kind(items: u64, fp_rate: f64, kind: FilterKind) -> Self {
        let (log2, h) = hashing::pow2_geometry(items, fp_rate, kind.min_log2().max(6), 26);
        Self::new_kind(log2, h, kind)
    }

    pub fn log2_cells(&self) -> u32 {
        self.log2_cells
    }

    pub fn num_hashes(&self) -> u32 {
        self.num_hashes
    }

    pub fn kind(&self) -> FilterKind {
        self.kind
    }

    pub fn insert(&mut self, key: u32) {
        for p in positions_for(self.kind, key, self.num_hashes, self.log2_cells) {
            let c = &mut self.cells[p as usize];
            *c = c.saturating_add(1);
        }
        self.items += 1;
    }

    pub fn contains(&self, key: u32) -> bool {
        positions_for(self.kind, key, self.num_hashes, self.log2_cells)
            .all(|p| self.cells[p as usize] > 0)
    }

    /// Remove a key. Saturated cells (255) are left untouched to avoid
    /// introducing false negatives; this is the standard CBF compromise.
    pub fn remove(&mut self, key: u32) {
        if !self.contains(key) {
            return;
        }
        for p in positions_for(self.kind, key, self.num_hashes, self.log2_cells) {
            let c = &mut self.cells[p as usize];
            if *c > 0 && *c < u8::MAX {
                *c -= 1;
            }
        }
        self.items = self.items.saturating_sub(1);
    }

    fn check_geometry(&self, other: &CountingBloomFilter) {
        assert_eq!(self.log2_cells, other.log2_cells, "geometry mismatch");
        assert_eq!(self.num_hashes, other.num_hashes, "geometry mismatch");
        assert_eq!(self.kind, other.kind, "filter kind mismatch");
    }

    /// Cell-wise sum (multiset union).
    pub fn union_with(&mut self, other: &CountingBloomFilter) {
        self.check_geometry(other);
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            *a = a.saturating_add(*b);
        }
        self.items += other.items;
    }

    /// Cell-wise min — the CBF analogue of the AND join-filter merge.
    pub fn intersect_with(&mut self, other: &CountingBloomFilter) {
        self.check_geometry(other);
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            *a = (*a).min(*b);
        }
        self.items = self.items.min(other.items);
    }

    pub fn insert_key64(&mut self, key: u64) {
        self.insert(fold_key(key));
    }

    pub fn remove_key64(&mut self, key: u64) {
        self.remove(fold_key(key));
    }

    #[inline]
    pub fn contains_key64(&self, key: u64) -> bool {
        self.contains(fold_key(key))
    }

    /// Collapse to the standard bit filter of the same geometry (cell > 0 ⇔
    /// bit set): membership answers are identical, at 1/8 the bytes. This is
    /// what the streaming runtime broadcasts as the per-window join filter —
    /// the counters stay at the workers, only the bit view travels.
    /// Standard-addressed filters only; blocked sketches collapse through
    /// [`CountingBloomFilter::to_join_filter`].
    pub fn to_bit_filter(&self) -> BloomFilter {
        assert_eq!(
            self.kind,
            FilterKind::Standard,
            "blocked sketches collapse via to_join_filter"
        );
        match self.to_join_filter() {
            JoinFilter::Standard(f) => f,
            JoinFilter::Blocked(_) => unreachable!("kind checked above"),
        }
    }

    /// Collapse to the bit filter of the same geometry *and kind* (cell > 0
    /// ⇔ bit set). Because cells and bits share one position family per
    /// kind, membership answers are identical to the counters' at 1/8 the
    /// bytes — for blocked sketches the view is a genuine
    /// [`super::BlockedBloomFilter`], probeable in one cache line.
    pub fn to_join_filter(&self) -> JoinFilter {
        let mut words = vec![0u32; self.cells.len() / 32];
        for (p, &c) in self.cells.iter().enumerate() {
            if c > 0 {
                words[p >> 5] |= 1 << (p & 31);
            }
        }
        match self.kind {
            FilterKind::Standard => JoinFilter::Standard(BloomFilter::from_words(
                words,
                self.log2_cells,
                self.num_hashes,
            )),
            FilterKind::Blocked => JoinFilter::Blocked(super::BlockedBloomFilter::from_words(
                words,
                self.log2_cells,
                self.num_hashes,
            )),
        }
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    /// One byte per cell — 8x a standard filter of equal cell count
    /// (Figure 15's CBF >> BF gap).
    pub fn size_bytes(&self) -> u64 {
        self.cells.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn insert_contains_remove() {
        let mut f = CountingBloomFilter::new(14, 4);
        f.insert(10);
        f.insert(20);
        assert!(f.contains(10) && f.contains(20));
        f.remove(10);
        assert!(!f.contains(10) || f.contains(20)); // 10 may collide w/ 20
        assert!(f.contains(20), "removal must not break other keys");
    }

    #[test]
    fn remove_of_duplicate_inserts() {
        let mut f = CountingBloomFilter::new(14, 4);
        f.insert(7);
        f.insert(7);
        f.remove(7);
        assert!(f.contains(7), "one copy should remain");
        f.remove(7);
        assert!(!f.contains(7));
    }

    #[test]
    fn remove_absent_is_noop() {
        let mut f = CountingBloomFilter::new(14, 4);
        f.insert(1);
        f.remove(999);
        assert!(f.contains(1));
        assert_eq!(f.items(), 1);
    }

    #[test]
    fn no_false_negatives_bulk() {
        let mut r = Rng::new(8);
        let mut f = CountingBloomFilter::new(16, 5);
        let keys: Vec<u32> = (0..3000).map(|_| r.next_u32()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(keys.iter().all(|&k| f.contains(k)));
    }

    #[test]
    fn union_and_intersection() {
        let mut a = CountingBloomFilter::new(14, 4);
        let mut b = CountingBloomFilter::new(14, 4);
        a.insert(1);
        b.insert(2);
        let mut u = a.clone();
        u.union_with(&b);
        assert!(u.contains(1) && u.contains(2));
        a.insert(3);
        b.insert(3);
        a.intersect_with(&b);
        assert!(a.contains(3));
        assert!(!a.contains(1) || !a.contains(2));
    }

    #[test]
    fn size_is_8x_standard() {
        let f = CountingBloomFilter::new(14, 4);
        let s = super::super::standard::BloomFilter::new(14, 4);
        assert_eq!(f.size_bytes(), 8 * s.size_bytes());
    }

    #[test]
    fn key64_insert_remove_roundtrip() {
        let mut f = CountingBloomFilter::new(16, 5);
        let keys: Vec<u64> = (0..500u64).map(|i| (i << 33) | i).collect();
        for &k in &keys {
            f.insert_key64(k);
        }
        assert!(keys.iter().all(|&k| f.contains_key64(k)));
        for &k in &keys[..250] {
            f.remove_key64(k);
        }
        assert!(
            keys[250..].iter().all(|&k| f.contains_key64(k)),
            "removal must not break the remaining keys"
        );
    }

    #[test]
    fn with_capacity_hits_target_fp() {
        let mut r = Rng::new(21);
        let n = 10_000u64;
        let mut f = CountingBloomFilter::with_capacity(n, 0.01);
        for _ in 0..n {
            f.insert(r.next_u32());
        }
        let probes = 50_000;
        let fps = (0..probes).filter(|_| f.contains(r.next_u32())).count();
        assert!(
            (fps as f64 / probes as f64) < 0.05,
            "fp rate {}",
            fps as f64 / probes as f64
        );
    }

    #[test]
    fn bit_filter_view_agrees_on_membership() {
        let mut r = Rng::new(22);
        let mut f = CountingBloomFilter::new(14, 4);
        let keys: Vec<u64> = (0..2000).map(|_| r.next_u64()).collect();
        for &k in &keys {
            f.insert_key64(k);
        }
        for &k in &keys[..1000] {
            f.remove_key64(k);
        }
        let bits = f.to_bit_filter();
        assert_eq!(bits.size_bytes() * 8, f.size_bytes());
        // the bit view answers exactly like the counters, present or not
        for &k in &keys {
            assert_eq!(bits.contains_key64(k), f.contains_key64(k), "key {k}");
        }
        for _ in 0..5000 {
            let k = r.next_u64();
            assert_eq!(bits.contains_key64(k), f.contains_key64(k), "probe {k}");
        }
    }

    #[test]
    fn blocked_kind_churn_and_bit_view() {
        use crate::bloom::{FilterKind, JoinFilter};
        let mut r = Rng::new(33);
        let mut f = CountingBloomFilter::new_kind(14, 5, FilterKind::Blocked);
        let keys: Vec<u64> = (0..1500).map(|_| r.next_u64()).collect();
        for &k in &keys {
            f.insert_key64(k);
        }
        for &k in &keys[..700] {
            f.remove_key64(k);
        }
        assert!(
            keys[700..].iter().all(|&k| f.contains_key64(k)),
            "blocked removal must not break remaining keys"
        );
        let view = f.to_join_filter();
        assert!(matches!(view, JoinFilter::Blocked(_)));
        for &k in &keys {
            assert_eq!(view.contains_key64(k), f.contains_key64(k), "key {k}");
        }
        for _ in 0..5000 {
            let k = r.next_u64();
            assert_eq!(view.contains_key64(k), f.contains_key64(k), "probe {k}");
        }
    }

    #[test]
    #[should_panic(expected = "to_join_filter")]
    fn blocked_kind_rejects_standard_bit_view() {
        let f = CountingBloomFilter::new_kind(14, 4, crate::bloom::FilterKind::Blocked);
        let _ = f.to_bit_filter();
    }

    #[test]
    fn saturation_does_not_false_negative() {
        let mut f = CountingBloomFilter::new(8, 2);
        // force counters to saturate
        for i in 0..100_000u32 {
            f.insert(i);
        }
        for i in 0..100u32 {
            assert!(f.contains(i));
        }
        // removes on saturated cells must not create false negatives
        for i in 0..100u32 {
            f.remove(i);
        }
        // keys inserted many times over saturated cells still present
        assert!(f.contains(100_001u32.wrapping_mul(3) % 100_000));
    }
}
