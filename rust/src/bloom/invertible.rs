//! Invertible Bloom filter (paper Appendix B I, after Goodrich &
//! Mitzenmacher's IBLT): cells carry (count, keySum, hashSum) so the filter
//! supports *subtraction* and *listing* of its contents — at a 12-24x size
//! premium over a plain bit vector (Figure 15), and with a "not found"
//! failure mode the paper calls out: peeling can fail even though the key
//! is present.

use super::hashing::{mix32, probe_positions};

const CHECK_SEED: u32 = 0x5BD1_E995;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
struct Cell {
    count: i64,
    key_sum: u64,
    hash_sum: u64,
}

impl Cell {
    /// If this cell holds exactly one (possibly negated) key, return it.
    /// A count of −1 stores the *negated* key_sum, so recover accordingly.
    fn pure_entry(&self) -> Option<(u32, i64)> {
        let sign = match self.count {
            1 => 1,
            -1 => -1,
            _ => return None,
        };
        let key = if sign == 1 {
            self.key_sum as u32
        } else {
            self.key_sum.wrapping_neg() as u32
        };
        (self.key_sum == if sign == 1 { key as u64 } else { (key as u64).wrapping_neg() }
            && self.hash_sum == mix32(key ^ CHECK_SEED) as u64)
            .then_some((key, sign))
    }
}

/// Invertible Bloom filter over u32 keys.
#[derive(Clone, Debug)]
pub struct InvertibleBloomFilter {
    cells: Vec<Cell>,
    log2_cells: u32,
    num_hashes: u32,
}

impl InvertibleBloomFilter {
    pub fn new(log2_cells: u32, num_hashes: u32) -> Self {
        assert!((3..=28).contains(&log2_cells));
        assert!((2..=8).contains(&num_hashes), "IBF wants 2..8 hashes");
        Self {
            cells: vec![Cell::default(); 1usize << log2_cells],
            log2_cells,
            num_hashes,
        }
    }

    fn apply(&mut self, key: u32, sign: i64) {
        let check = mix32(key ^ CHECK_SEED) as u64;
        for p in probe_positions(key, self.num_hashes, self.log2_cells) {
            let c = &mut self.cells[p as usize];
            c.count += sign;
            c.key_sum = if sign > 0 {
                c.key_sum.wrapping_add(key as u64)
            } else {
                c.key_sum.wrapping_sub(key as u64)
            };
            c.hash_sum ^= check;
        }
    }

    pub fn insert(&mut self, key: u32) {
        self.apply(key, 1);
    }

    pub fn remove(&mut self, key: u32) {
        self.apply(key, -1);
    }

    /// Subtract another IBF cell-wise: the result encodes the symmetric
    /// difference of the two key multisets — how the paper obtains the
    /// participating join items via IBF subtraction.
    pub fn subtract(&mut self, other: &InvertibleBloomFilter) {
        assert_eq!(self.log2_cells, other.log2_cells, "geometry mismatch");
        assert_eq!(self.num_hashes, other.num_hashes, "geometry mismatch");
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            a.count -= b.count;
            a.key_sum = a.key_sum.wrapping_sub(b.key_sum);
            a.hash_sum ^= b.hash_sum;
        }
    }

    /// Peel the filter, listing recoverable entries as (key, sign) where
    /// sign +1 means "present in self minus other" after a subtract.
    /// Returns (entries, fully_decoded) — `false` mirrors the paper's
    /// "not found although present" caveat.
    pub fn list_entries(mut self) -> (Vec<(u32, i64)>, bool) {
        let mut out = Vec::new();
        loop {
            let Some((key, sign)) = self.cells.iter().find_map(|c| c.pure_entry()) else {
                break;
            };
            out.push((key, sign));
            self.apply(key, -sign);
        }
        let decoded = self.cells.iter().all(|c| *c == Cell::default());
        (out, decoded)
    }

    /// 20 bytes per cell (8 count is stored as i64 here: 8 + 8 + 4-rounded)
    /// — the Figure 15 premium over a 1-bit cell.
    pub fn size_bytes(&self) -> u64 {
        (self.cells.len() * std::mem::size_of::<Cell>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn list_small_set() {
        let mut f = InvertibleBloomFilter::new(8, 3);
        let keys = [5u32, 99, 1234, 777];
        for &k in &keys {
            f.insert(k);
        }
        let (entries, decoded) = f.list_entries();
        assert!(decoded);
        let mut got: Vec<u32> = entries.iter().map(|&(k, _)| k).collect();
        got.sort_unstable();
        let mut want = keys.to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(entries.iter().all(|&(_, s)| s == 1));
    }

    #[test]
    fn subtract_yields_symmetric_difference() {
        let mut a = InvertibleBloomFilter::new(9, 3);
        let mut b = InvertibleBloomFilter::new(9, 3);
        for k in [1u32, 2, 3, 4, 5] {
            a.insert(k);
        }
        for k in [4u32, 5, 6, 7] {
            b.insert(k);
        }
        a.subtract(&b);
        let (entries, decoded) = a.list_entries();
        assert!(decoded);
        let mut only_a: Vec<u32> = entries
            .iter()
            .filter(|&&(_, s)| s == 1)
            .map(|&(k, _)| k)
            .collect();
        let mut only_b: Vec<u32> = entries
            .iter()
            .filter(|&&(_, s)| s == -1)
            .map(|&(k, _)| k)
            .collect();
        only_a.sort_unstable();
        only_b.sort_unstable();
        assert_eq!(only_a, vec![1, 2, 3]);
        assert_eq!(only_b, vec![6, 7]);
    }

    #[test]
    fn insert_remove_cancels() {
        let mut f = InvertibleBloomFilter::new(8, 3);
        let mut r = Rng::new(9);
        let keys: Vec<u32> = (0..50).map(|_| r.next_u32()).collect();
        for &k in &keys {
            f.insert(k);
        }
        for &k in &keys {
            f.remove(k);
        }
        let (entries, decoded) = f.list_entries();
        assert!(decoded);
        assert!(entries.is_empty());
    }

    #[test]
    fn overload_fails_to_decode() {
        // cells << keys: peeling must report failure, not loop forever
        let mut f = InvertibleBloomFilter::new(4, 3); // 16 cells
        let mut r = Rng::new(10);
        for _ in 0..200 {
            f.insert(r.next_u32());
        }
        let (_, decoded) = f.list_entries();
        assert!(!decoded);
    }

    #[test]
    fn capacity_rule_of_thumb() {
        // IBFs decode reliably below ~0.8 load with 3+ hashes at 1.5x cells
        let mut r = Rng::new(11);
        let mut ok = 0;
        for rep in 0..20 {
            let mut f = InvertibleBloomFilter::new(7, 4); // 128 cells
            let keys: Vec<u32> = (0..60).map(|_| r.next_u32() ^ rep).collect();
            for &k in &keys {
                f.insert(k);
            }
            let (entries, decoded) = f.list_entries();
            if decoded && entries.len() == keys.len() {
                ok += 1;
            }
        }
        assert!(ok >= 18, "decode success {ok}/20");
    }

    #[test]
    fn size_premium_over_standard() {
        let ibf = InvertibleBloomFilter::new(14, 4);
        let bf = super::super::standard::BloomFilter::new(14, 4);
        assert!(ibf.size_bytes() >= 12 * bf.size_bytes());
    }
}
