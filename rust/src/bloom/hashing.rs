//! The hash family shared by every filter variant — and, crucially, by the
//! AOT `bloom_probe` Pallas kernel: `python/compile/kernels/ref.py::mix32 /
//! bloom_hashes` implements the *same* constants and wrapping u32
//! arithmetic. Golden values are pinned on both sides (see tests below and
//! python/tests/test_kernels.py) so Rust-built filters are probeable by the
//! XLA artifact bit-for-bit.

/// Seeds for the double-hash family (mirrored in kernels/ref.py).
pub const SEED1: u32 = 0x9E37_79B9;
pub const SEED2: u32 = 0x85EB_CA77;

/// murmur3 32-bit finalizer.
#[inline]
pub fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EB_CA6B);
    x ^= x >> 13;
    x = x.wrapping_mul(0xC2B2_AE35);
    x ^= x >> 16;
    x
}

/// Fold a 64-bit join key into the 32-bit hash domain. The kernels operate
/// on u32 keys; 64-bit keys are pre-folded with this before either side
/// hashes them, so both sides agree.
#[inline]
pub fn fold_key(key: u64) -> u32 {
    // xor-fold then mix once so high bits influence the result
    mix32((key as u32) ^ ((key >> 32) as u32).wrapping_mul(0x9E37_79B9))
}

/// Kirsch-Mitzenmacher double hashing: the i-th probe position of `key` in
/// a table of 2^log2_bits bits.
#[inline]
pub fn probe_positions(key: u32, num_hashes: u32, log2_bits: u32) -> impl Iterator<Item = u32> {
    let mask = (1u32 << log2_bits) - 1;
    let h1 = mix32(key ^ SEED1);
    let h2 = mix32(key ^ SEED2) | 1;
    (0..num_hashes).map(move |i| h1.wrapping_add(i.wrapping_mul(h2)) & mask)
}

/// Optimal number of hash functions for a given bits-per-item ratio
/// (paper appendix A.1: h = |BF|/N · ln 2).
pub fn optimal_num_hashes(bits: u64, items: u64) -> u32 {
    if items == 0 {
        return 1;
    }
    let h = (bits as f64 / items as f64 * std::f64::consts::LN_2).round();
    (h as u32).clamp(1, 16)
}

/// Power-of-two filter geometry for a capacity + false-positive target
/// (eq 27): `(log2 cells, optimal hash count)`, the bit/cell count rounded
/// up to a power of two within `[2^min_log2, 2^max_log2]`. Shared cell
/// sizing for [`super::counting::CountingBloomFilter::with_capacity`] and
/// the streaming window sketch (which additionally caps the returned hash
/// count at 6 to bound per-window delta traffic — see
/// `stream::SketchConfig::for_capacity`).
pub fn pow2_geometry(items: u64, fp_rate: f64, min_log2: u32, max_log2: u32) -> (u32, u32) {
    let bits = bits_for_fp_rate(items.max(1), fp_rate).max(64);
    let log2 =
        (64 - (bits - 1).leading_zeros() as u64).clamp(min_log2 as u64, max_log2 as u64) as u32;
    (log2, optimal_num_hashes(1 << log2, items.max(1)))
}

/// Filter size for a target false-positive rate (paper eq 27):
/// |BF| = −N ln p / (ln 2)².
pub fn bits_for_fp_rate(items: u64, fp_rate: f64) -> u64 {
    assert!(fp_rate > 0.0 && fp_rate < 1.0);
    let ln2sq = std::f64::consts::LN_2 * std::f64::consts::LN_2;
    ((-(items.max(1) as f64) * fp_rate.ln()) / ln2sq).ceil() as u64
}

/// Theoretical false-positive rate p ≈ (1 − e^{−hN/|BF|})^h.
pub fn theoretical_fp_rate(bits: u64, items: u64, num_hashes: u32) -> f64 {
    if bits == 0 {
        return 1.0;
    }
    let exp = -(num_hashes as f64) * items as f64 / bits as f64;
    (1.0 - exp.exp()).powi(num_hashes as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values shared with python/tests/test_kernels.py — if either
    /// implementation drifts, its twin test fails too.
    #[test]
    fn mix32_golden() {
        assert_eq!(mix32(0), 0x0);
        assert_eq!(mix32(1), 0x514E28B7);
        assert_eq!(mix32(42), 0x087FCD5C);
        assert_eq!(mix32(0xDEADBEEF), 0x0DE5C6A9);
        assert_eq!(mix32(123456789), 0xBA60D89A);
    }

    #[test]
    fn probe_positions_golden() {
        let pos: Vec<u32> = probe_positions(42, 5, 20).collect();
        assert_eq!(pos, vec![650960, 828291, 1005622, 134377, 311708]);
        let pos: Vec<u32> = probe_positions(0, 5, 20).collect();
        assert_eq!(pos, vec![667406, 868387, 20792, 221773, 422754]);
    }

    #[test]
    fn probe_positions_in_range() {
        for key in [0u32, 1, 0xFFFF_FFFF, 123456] {
            for log2 in [10u32, 16, 20] {
                for p in probe_positions(key, 8, log2) {
                    assert!(p < (1 << log2));
                }
            }
        }
    }

    #[test]
    fn fold_key_distributes_high_bits() {
        // keys differing only in high 32 bits must fold differently
        assert_ne!(fold_key(5), fold_key(5 | (1 << 40)));
        assert_ne!(fold_key(0), fold_key(u64::MAX));
    }

    #[test]
    fn optimal_h_matches_formula() {
        // 10 bits/item -> h = 10 ln2 ~ 6.93 -> 7
        assert_eq!(optimal_num_hashes(1000, 100), 7);
        assert_eq!(optimal_num_hashes(0, 0), 1);
        assert_eq!(optimal_num_hashes(u64::MAX, 1), 16); // clamped
    }

    #[test]
    fn bits_for_fp_rate_matches_eq27() {
        // N=1e6, p=0.01 -> |BF| = 1e6 * ln(100)/(ln2)^2 ~ 9_585_059
        let bits = bits_for_fp_rate(1_000_000, 0.01);
        assert!((9_585_000..9_586_000).contains(&bits), "{bits}");
    }

    #[test]
    fn theoretical_fp_monotonic() {
        let a = theoretical_fp_rate(1 << 20, 10_000, 5);
        let b = theoretical_fp_rate(1 << 20, 100_000, 5);
        let c = theoretical_fp_rate(1 << 20, 1_000_000, 5);
        assert!(a < b && b < c);
        assert!(a > 0.0 && c <= 1.0);
    }
}
