//! Standard bit-vector Bloom filter (paper §3.1): the *partition filter* /
//! *dataset filter* / *join filter* substrate. Supports the two merge
//! operations Algorithm 1 needs — OR (union of partition filters into a
//! dataset filter, Reduce phase) and AND (intersection of dataset filters
//! into the join filter) — plus serialization into the packed u32 word
//! layout the AOT `bloom_probe` kernel consumes.

use super::hashing::{self, probe_positions};

/// A fixed-geometry Bloom filter over pre-folded u32 keys.
#[derive(Clone, Debug, PartialEq)]
pub struct BloomFilter {
    /// Packed bits: bit p lives at words[p >> 5] bit (p & 31) — identical
    /// layout to the kernel side.
    words: Vec<u32>,
    log2_bits: u32,
    num_hashes: u32,
    items: u64,
}

impl BloomFilter {
    /// Filter with 2^log2_bits bits and `num_hashes` probes.
    pub fn new(log2_bits: u32, num_hashes: u32) -> Self {
        assert!((5..=32).contains(&log2_bits), "log2_bits={log2_bits}");
        assert!((1..=16).contains(&num_hashes));
        Self {
            words: vec![0; 1usize << (log2_bits - 5)],
            log2_bits,
            num_hashes,
            items: 0,
        }
    }

    /// Geometry from a target capacity + false-positive rate (paper eq 27),
    /// rounding bits up to a power of two so AND/OR merges stay aligned.
    pub fn with_capacity(items: u64, fp_rate: f64) -> Self {
        let bits = hashing::bits_for_fp_rate(items, fp_rate).max(64);
        let log2 = (64 - (bits - 1).leading_zeros() as u64).clamp(6, 30) as u32;
        let h = hashing::optimal_num_hashes(1 << log2, items.max(1));
        Self::new(log2, h)
    }

    pub fn log2_bits(&self) -> u32 {
        self.log2_bits
    }

    pub fn num_hashes(&self) -> u32 {
        self.num_hashes
    }

    pub fn num_bits(&self) -> u64 {
        1u64 << self.log2_bits
    }

    /// Items inserted so far (approximate after merges: summed).
    pub fn items(&self) -> u64 {
        self.items
    }

    /// Size of the filter payload in bytes — what a broadcast of this
    /// filter costs on the network (paper §A.1 |BF| terms).
    pub fn size_bytes(&self) -> u64 {
        (self.words.len() * 4) as u64
    }

    pub fn insert(&mut self, key: u32) {
        for p in probe_positions(key, self.num_hashes, self.log2_bits) {
            self.words[(p >> 5) as usize] |= 1 << (p & 31);
        }
        self.items += 1;
    }

    pub fn insert_key64(&mut self, key: u64) {
        self.insert(hashing::fold_key(key));
    }

    #[inline]
    pub fn contains(&self, key: u32) -> bool {
        probe_positions(key, self.num_hashes, self.log2_bits)
            .all(|p| self.words[(p >> 5) as usize] & (1 << (p & 31)) != 0)
    }

    #[inline]
    pub fn contains_key64(&self, key: u64) -> bool {
        self.contains(hashing::fold_key(key))
    }

    /// OR-merge (set union): Reduce phase of buildInputFilter (Alg 1 l.24).
    pub fn union_with(&mut self, other: &BloomFilter) {
        self.check_geometry(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
        self.items += other.items;
    }

    /// AND-merge (set intersection superset): join-filter construction
    /// (Alg 1 l.9). The result may contain false positives of the
    /// intersection but never misses a truly common key.
    pub fn intersect_with(&mut self, other: &BloomFilter) {
        self.check_geometry(other);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
        self.items = self.items.min(other.items);
    }

    fn check_geometry(&self, other: &BloomFilter) {
        assert_eq!(self.log2_bits, other.log2_bits, "geometry mismatch");
        assert_eq!(self.num_hashes, other.num_hashes, "geometry mismatch");
    }

    /// Fraction of set bits — used to estimate cardinality and fp rate.
    pub fn fill_ratio(&self) -> f64 {
        let set: u64 = self.words.iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / self.num_bits() as f64
    }

    /// Cardinality estimate from the fill ratio (Swamidass & Baldi):
    /// n̂ = −m/h · ln(1 − X/m).
    pub fn estimate_cardinality(&self) -> f64 {
        let x = self.fill_ratio();
        if x >= 1.0 {
            return f64::INFINITY;
        }
        -(self.num_bits() as f64) / self.num_hashes as f64 * (1.0 - x).ln()
    }

    /// Expected false-positive rate at the current fill.
    pub fn current_fp_rate(&self) -> f64 {
        self.fill_ratio().powi(self.num_hashes as i32)
    }

    /// The packed word array — the exact tensor the `bloom_probe` AOT
    /// artifact takes as its first argument.
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    pub fn from_words(words: Vec<u32>, log2_bits: u32, num_hashes: u32) -> Self {
        assert_eq!(words.len(), 1usize << (log2_bits - 5));
        Self {
            words,
            log2_bits,
            num_hashes,
            items: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn no_false_negatives() {
        let mut r = Rng::new(1);
        let mut f = BloomFilter::new(16, 5);
        let keys: Vec<u32> = (0..2000).map(|_| r.next_u32()).collect();
        for &k in &keys {
            f.insert(k);
        }
        assert!(keys.iter().all(|&k| f.contains(k)));
    }

    #[test]
    fn fp_rate_near_theory() {
        let mut r = Rng::new(2);
        let n = 10_000u64;
        let mut f = BloomFilter::new(17, 5); // 131072 bits, ~13 bits/item
        for _ in 0..n {
            f.insert(r.next_u32());
        }
        let probes = 50_000;
        let fps = (0..probes).filter(|_| f.contains(r.next_u32())).count();
        let measured = fps as f64 / probes as f64;
        let theory = hashing::theoretical_fp_rate(f.num_bits(), n, 5);
        assert!(
            (measured - theory).abs() < theory * 0.5 + 0.002,
            "measured {measured} theory {theory}"
        );
    }

    #[test]
    fn union_contains_both_sides() {
        let mut a = BloomFilter::new(14, 4);
        let mut b = BloomFilter::new(14, 4);
        a.insert(1);
        a.insert(2);
        b.insert(3);
        a.union_with(&b);
        assert!(a.contains(1) && a.contains(2) && a.contains(3));
        assert_eq!(a.items(), 3);
    }

    #[test]
    fn intersection_never_misses_common_keys() {
        let mut r = Rng::new(3);
        let mut a = BloomFilter::new(16, 5);
        let mut b = BloomFilter::new(16, 5);
        let common: Vec<u32> = (0..500).map(|_| r.next_u32()).collect();
        for &k in &common {
            a.insert(k);
            b.insert(k);
        }
        for _ in 0..2000 {
            a.insert(r.next_u32());
            b.insert(r.next_u32());
        }
        a.intersect_with(&b);
        assert!(common.iter().all(|&k| a.contains(k)));
    }

    #[test]
    fn intersection_drops_most_noncommon() {
        let mut r = Rng::new(4);
        let mut a = BloomFilter::new(18, 5);
        let mut b = BloomFilter::new(18, 5);
        let only_a: Vec<u32> = (0..3000).map(|_| r.next_u32()).collect();
        for &k in &only_a {
            a.insert(k);
        }
        for _ in 0..3000 {
            b.insert(r.next_u32());
        }
        a.intersect_with(&b);
        let survivors = only_a.iter().filter(|&&k| a.contains(k)).count();
        assert!(survivors < 50, "survivors={survivors}");
    }

    #[test]
    #[should_panic(expected = "geometry")]
    fn merge_rejects_mismatched_geometry() {
        let mut a = BloomFilter::new(14, 4);
        let b = BloomFilter::new(15, 4);
        a.union_with(&b);
    }

    #[test]
    fn with_capacity_hits_target_fp() {
        let mut r = Rng::new(5);
        let n = 20_000u64;
        let mut f = BloomFilter::with_capacity(n, 0.01);
        for _ in 0..n {
            f.insert(r.next_u32());
        }
        assert!(f.current_fp_rate() < 0.05, "fp={}", f.current_fp_rate());
    }

    #[test]
    fn cardinality_estimate_close() {
        let mut r = Rng::new(6);
        let n = 5_000;
        let mut f = BloomFilter::new(17, 5);
        for _ in 0..n {
            f.insert(r.next_u32());
        }
        let est = f.estimate_cardinality();
        assert!(
            (est - n as f64).abs() / (n as f64) < 0.05,
            "est={est} n={n}"
        );
    }

    #[test]
    fn words_layout_matches_kernel_contract() {
        // bit p -> words[p>>5] & (1 << (p&31)); insert key 42 and verify
        // against the golden probe positions.
        let mut f = BloomFilter::new(20, 5);
        f.insert(42);
        for p in [650960u32, 828291, 1005622, 134377, 311708] {
            assert_ne!(f.words()[(p >> 5) as usize] & (1 << (p & 31)), 0);
        }
        let set: u32 = f.words().iter().map(|w| w.count_ones()).sum();
        assert_eq!(set, 5);
    }

    #[test]
    fn key64_folding_no_false_negatives() {
        let mut f = BloomFilter::new(16, 5);
        let keys: Vec<u64> = (0..1000).map(|i| (i as u64) << 33 | i as u64).collect();
        for &k in &keys {
            f.insert_key64(k);
        }
        assert!(keys.iter().all(|&k| f.contains_key64(k)));
    }
}
