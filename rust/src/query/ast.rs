//! Query AST: aggregate(s)-over-equi-join with selection predicates, an
//! optional GROUP BY, and a query execution budget.

use crate::join::{CombineOp, JoinVariant};
use crate::relation::{AggExpr, ColumnRef, Predicate};

/// Algebraic aggregation functions the paper supports (§2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFunc {
    Sum,
    Avg,
    Count,
    Stdev,
}

impl AggFunc {
    pub fn name(&self) -> &'static str {
        match self {
            AggFunc::Sum => "SUM",
            AggFunc::Avg => "AVG",
            AggFunc::Count => "COUNT",
            AggFunc::Stdev => "STDEV",
        }
    }
}

/// The error half of a query budget: bound ± at a confidence level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrorBudget {
    /// err_desired — absolute half-width of the confidence interval for
    /// AVG-like aggregates, relative for SUM (the paper's example 0.01).
    pub bound: f64,
    /// Confidence level in (0,1), e.g. 0.95.
    pub confidence: f64,
}

/// Query execution budget: desired latency, desired error bound, or both
/// ("WITHIN ... OR ERROR ..." picks whichever the planner can satisfy).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Budget {
    pub latency_secs: Option<f64>,
    pub error: Option<ErrorBudget>,
}

impl Budget {
    pub fn unbounded() -> Self {
        Self::default()
    }

    pub fn is_unbounded(&self) -> bool {
        self.latency_secs.is_none() && self.error.is_none()
    }
}

/// A parsed aggregation-over-join query.
///
/// `agg` / `combine` mirror the *first* aggregate expression — the legacy
/// single-aggregate view every pre-relational caller consumes. The full
/// relational shape lives in `aggregates`, `predicates` and `group_by`.
#[derive(Clone, Debug)]
pub struct Query {
    pub agg: AggFunc,
    /// How the per-input values combine inside the (first) aggregate.
    pub combine: CombineOp,
    /// Input dataset names, in join order (R1, R2, ..., Rn).
    pub tables: Vec<String>,
    /// The join attribute name (the paper's A; single-attribute equi-join).
    pub join_attr: String,
    /// The AND-ed equi-join chains as written (`a.k = b.k = c.k AND
    /// c.k = d.k` → `[[a,b,c],[c,d]]`) — the join-order optimizer builds
    /// its [`crate::join::JoinGraph`] from these. Programmatic
    /// (non-parsed) queries default to one chain in FROM order.
    /// Not part of [`Query::fingerprint`]: the chains are derivable from
    /// the query text and legacy fingerprints must stay byte-stable.
    pub join_clauses: Vec<Vec<String>>,
    pub budget: Budget,
    /// Every aggregate of the SELECT list (first mirrors `agg`/`combine`).
    pub aggregates: Vec<AggExpr>,
    /// WHERE predicates over non-join columns, pushed below the join.
    pub predicates: Vec<Predicate>,
    /// GROUP BY column, if any.
    pub group_by: Option<ColumnRef>,
    /// Join variant. `Inner` for comma-FROM and plain `JOIN` queries; the
    /// non-inner variants are binary and come from the explicit
    /// `LEFT/RIGHT/FULL OUTER | SEMI | ANTI JOIN` grammar.
    pub variant: JoinVariant,
}

impl Query {
    /// A legacy-shaped query: one aggregate, no predicates, no grouping.
    pub fn simple(
        agg: AggFunc,
        combine: CombineOp,
        tables: Vec<String>,
        join_attr: impl Into<String>,
        budget: Budget,
    ) -> Self {
        let join_clauses = vec![tables.clone()];
        Self {
            agg,
            combine,
            tables,
            join_attr: join_attr.into(),
            join_clauses,
            budget,
            aggregates: vec![AggExpr {
                func: agg,
                combine,
                terms: Vec::new(),
                alias: None,
            }],
            predicates: Vec::new(),
            group_by: None,
            variant: JoinVariant::Inner,
        }
    }

    /// Builder: set the join variant (binary joins only for non-inner).
    pub fn with_variant(mut self, variant: JoinVariant) -> Self {
        self.variant = variant;
        self
    }

    /// Whether this query needs the relational front end: predicates,
    /// grouping, multiple aggregates, or an aliased aggregate (the alias
    /// only surfaces through `QueryOutcome::grouped`). Plain
    /// single-aggregate queries keep the legacy scalar path.
    pub fn has_relational_features(&self) -> bool {
        self.group_by.is_some()
            || !self.predicates.is_empty()
            || self.aggregates.len() > 1
            || self.aggregates.iter().any(|a| a.alias.is_some())
    }

    /// Stable fingerprint for the feedback store: identifies the query
    /// shape (aggregates + predicates + grouping + tables + attribute),
    /// not its budget. Single-aggregate queries without relational
    /// features keep the exact pre-relational fingerprint, so persisted
    /// feedback sigmas stay valid across this API generation (the
    /// relational execution path additionally suffixes a per-aggregate
    /// `#SUM(...)` rendering when recording, which captures the
    /// expression columns).
    pub fn fingerprint(&self) -> String {
        let mut fp = format!(
            "{}:{:?}:{}:{}",
            self.agg.name(),
            self.combine,
            self.tables.join(","),
            self.join_attr
        );
        for p in &self.predicates {
            fp.push_str(&format!(";p={p}"));
        }
        if let Some(g) = &self.group_by {
            fp.push_str(&format!(";g={g}"));
        }
        if self.aggregates.len() > 1 {
            for a in &self.aggregates {
                fp.push_str(&format!(";a={}", a.render()));
            }
        }
        // inner joins keep the exact pre-variant fingerprint byte-stable
        if !self.variant.is_inner() {
            fp.push_str(&format!(";v={}", self.variant.tag()));
        }
        fp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relation::CmpOp;

    fn base() -> Query {
        Query::simple(
            AggFunc::Sum,
            CombineOp::Sum,
            vec!["a".into(), "b".into()],
            "k",
            Budget::unbounded(),
        )
    }

    #[test]
    fn fingerprint_ignores_budget() {
        let mut q1 = base();
        q1.budget = Budget {
            latency_secs: Some(10.0),
            error: None,
        };
        let mut q2 = q1.clone();
        q2.budget = Budget::unbounded();
        assert_eq!(q1.fingerprint(), q2.fingerprint());
    }

    #[test]
    fn fingerprint_distinguishes_shape() {
        let base = base();
        let mut other = base.clone();
        other.tables.push("c".into());
        assert_ne!(base.fingerprint(), other.fingerprint());
        let mut other = base.clone();
        other.agg = AggFunc::Avg;
        assert_ne!(base.fingerprint(), other.fingerprint());
    }

    #[test]
    fn fingerprint_covers_relational_shape() {
        let plain = base();
        let mut filtered = plain.clone();
        filtered.predicates.push(Predicate {
            column: ColumnRef::qualified("a", "x"),
            op: CmpOp::Gt,
            literal: 5.0,
        });
        assert_ne!(plain.fingerprint(), filtered.fingerprint());

        let mut grouped = plain.clone();
        grouped.group_by = Some(ColumnRef::qualified("a", "g"));
        assert_ne!(plain.fingerprint(), grouped.fingerprint());
        assert_ne!(filtered.fingerprint(), grouped.fingerprint());

        let mut multi = plain.clone();
        multi.aggregates.push(AggExpr {
            func: AggFunc::Avg,
            combine: CombineOp::Left,
            terms: vec![ColumnRef::qualified("a", "v")],
            alias: Some("m".into()),
        });
        assert_ne!(plain.fingerprint(), multi.fingerprint());

        // two different predicate constants differ too
        let mut filtered2 = filtered.clone();
        filtered2.predicates[0].literal = 6.0;
        assert_ne!(filtered.fingerprint(), filtered2.fingerprint());
    }

    #[test]
    fn fingerprint_covers_variant_but_inner_stays_legacy() {
        let plain = base();
        assert_eq!(plain.fingerprint(), "SUM:Sum:a,b:k");
        let semi = plain.clone().with_variant(JoinVariant::Semi);
        assert!(semi.fingerprint().ends_with(";v=semi"));
        let louter = plain.clone().with_variant(JoinVariant::LeftOuter);
        assert_ne!(semi.fingerprint(), louter.fingerprint());
    }

    #[test]
    fn relational_feature_detection() {
        assert!(!base().has_relational_features());
        let mut q = base();
        q.group_by = Some(ColumnRef::bare("g"));
        assert!(q.has_relational_features());
    }

    #[test]
    fn budget_unbounded() {
        assert!(Budget::unbounded().is_unbounded());
        assert!(!Budget {
            latency_secs: Some(1.0),
            error: None
        }
        .is_unbounded());
    }
}
