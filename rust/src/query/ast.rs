//! Query AST: aggregate-over-equi-join with a query execution budget.

use crate::join::CombineOp;

/// Algebraic aggregation functions the paper supports (§2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFunc {
    Sum,
    Avg,
    Count,
    Stdev,
}

impl AggFunc {
    pub fn name(&self) -> &'static str {
        match self {
            AggFunc::Sum => "SUM",
            AggFunc::Avg => "AVG",
            AggFunc::Count => "COUNT",
            AggFunc::Stdev => "STDEV",
        }
    }
}

/// The error half of a query budget: bound ± at a confidence level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrorBudget {
    /// err_desired — absolute half-width of the confidence interval for
    /// AVG-like aggregates, relative for SUM (the paper's example 0.01).
    pub bound: f64,
    /// Confidence level in (0,1), e.g. 0.95.
    pub confidence: f64,
}

/// Query execution budget: desired latency, desired error bound, or both
/// ("WITHIN ... OR ERROR ..." picks whichever the planner can satisfy).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Budget {
    pub latency_secs: Option<f64>,
    pub error: Option<ErrorBudget>,
}

impl Budget {
    pub fn unbounded() -> Self {
        Self::default()
    }

    pub fn is_unbounded(&self) -> bool {
        self.latency_secs.is_none() && self.error.is_none()
    }
}

/// A parsed aggregation-over-join query.
#[derive(Clone, Debug)]
pub struct Query {
    pub agg: AggFunc,
    /// How the per-input values combine inside the aggregate.
    pub combine: CombineOp,
    /// Input dataset names, in join order (R1, R2, ..., Rn).
    pub tables: Vec<String>,
    /// The join attribute name (the paper's A; single-attribute equi-join).
    pub join_attr: String,
    pub budget: Budget,
}

impl Query {
    /// Stable fingerprint for the feedback store: identifies the query
    /// shape (aggregate + combine + tables + attribute), not its budget.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}:{:?}:{}:{}",
            self.agg.name(),
            self.combine,
            self.tables.join(","),
            self.join_attr
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_ignores_budget() {
        let q1 = Query {
            agg: AggFunc::Sum,
            combine: CombineOp::Sum,
            tables: vec!["a".into(), "b".into()],
            join_attr: "k".into(),
            budget: Budget {
                latency_secs: Some(10.0),
                error: None,
            },
        };
        let mut q2 = q1.clone();
        q2.budget = Budget::unbounded();
        assert_eq!(q1.fingerprint(), q2.fingerprint());
    }

    #[test]
    fn fingerprint_distinguishes_shape() {
        let base = Query {
            agg: AggFunc::Sum,
            combine: CombineOp::Sum,
            tables: vec!["a".into(), "b".into()],
            join_attr: "k".into(),
            budget: Budget::unbounded(),
        };
        let mut other = base.clone();
        other.tables.push("c".into());
        assert_ne!(base.fingerprint(), other.fingerprint());
        let mut other = base.clone();
        other.agg = AggFunc::Avg;
        assert_ne!(base.fingerprint(), other.fingerprint());
    }

    #[test]
    fn budget_unbounded() {
        assert!(Budget::unbounded().is_unbounded());
        assert!(!Budget {
            latency_secs: Some(1.0),
            error: None
        }
        .is_unbounded());
    }
}
