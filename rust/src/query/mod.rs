//! Budget-query front end (paper §2 "Query interface"): the SQL-ish
//! language in which users submit an aggregation-over-join with a latency
//! or error budget:
//!
//! ```sql
//! SELECT SUM(R1.V + R2.V) FROM R1, R2
//! WHERE R1.A = R2.A
//! WITHIN 120 SECONDS
//! OR ERROR 0.01 CONFIDENCE 95%
//! ```

pub mod ast;
pub mod parser;

pub use ast::{AggFunc, Budget, ErrorBudget, Query};
pub use parser::parse;
