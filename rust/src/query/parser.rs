//! Recursive-descent parser for the budget-query language (§2).
//!
//! Grammar (case-insensitive keywords):
//!
//! ```text
//! query    := SELECT agg '(' expr ')' FROM tables WHERE chain budget?
//! agg      := SUM | AVG | COUNT | STDEV
//! expr     := term (('+' | '*') term)* | '*'
//! term     := ident '.' ident
//! tables   := ident (',' ident)*
//! chain    := term ('=' term)+
//! budget   := within | error | within OR error
//! within   := WITHIN number SECONDS
//! error    := ERROR number CONFIDENCE number '%'
//! ```

use super::ast::{AggFunc, Budget, ErrorBudget, Query};
use crate::join::CombineOp;
use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Num(f64),
    Sym(char),
}

fn tokenize(s: &str) -> Result<Vec<Tok>> {
    let mut out = Vec::new();
    let b: Vec<char> = s.chars().collect();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c.is_whitespace() {
            i += 1;
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.push(Tok::Ident(b[start..i].iter().collect()));
        } else if c.is_ascii_digit() || (c == '.' && i + 1 < b.len() && b[i + 1].is_ascii_digit())
        {
            let start = i;
            while i < b.len() && (b[i].is_ascii_digit() || b[i] == '.') {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            out.push(Tok::Num(text.parse().map_err(|_| anyhow!("bad number {text}"))?));
        } else if "()+*,.=%".contains(c) {
            out.push(Tok::Sym(c));
            i += 1;
        } else {
            bail!("unexpected character '{c}' at {i}");
        }
    }
    Ok(out)
}

struct P {
    toks: Vec<Tok>,
    i: usize,
}

impl P {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self
            .toks
            .get(self.i)
            .cloned()
            .ok_or_else(|| anyhow!("unexpected end of query"))?;
        self.i += 1;
        Ok(t)
    }

    fn keyword(&mut self, kw: &str) -> Result<()> {
        match self.next()? {
            Tok::Ident(s) if s.eq_ignore_ascii_case(kw) => Ok(()),
            t => bail!("expected {kw}, got {t:?}"),
        }
    }

    fn try_keyword(&mut self, kw: &str) -> bool {
        if let Some(Tok::Ident(s)) = self.peek() {
            if s.eq_ignore_ascii_case(kw) {
                self.i += 1;
                return true;
            }
        }
        false
    }

    fn sym(&mut self, c: char) -> Result<()> {
        match self.next()? {
            Tok::Sym(s) if s == c => Ok(()),
            t => bail!("expected '{c}', got {t:?}"),
        }
    }

    fn try_sym(&mut self, c: char) -> bool {
        if self.peek() == Some(&Tok::Sym(c)) {
            self.i += 1;
            return true;
        }
        false
    }

    fn ident(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            t => bail!("expected identifier, got {t:?}"),
        }
    }

    fn num(&mut self) -> Result<f64> {
        match self.next()? {
            Tok::Num(v) => Ok(v),
            t => bail!("expected number, got {t:?}"),
        }
    }

    /// `table '.' column` → (table, column)
    fn qualified(&mut self) -> Result<(String, String)> {
        let t = self.ident()?;
        self.sym('.')?;
        let c = self.ident()?;
        Ok((t, c))
    }
}

/// Parse a budget query.
pub fn parse(text: &str) -> Result<Query> {
    let mut p = P {
        toks: tokenize(text)?,
        i: 0,
    };
    p.keyword("SELECT")?;
    let agg_name = p.ident()?;
    let agg = match agg_name.to_ascii_uppercase().as_str() {
        "SUM" => AggFunc::Sum,
        "AVG" => AggFunc::Avg,
        "COUNT" => AggFunc::Count,
        "STDEV" => AggFunc::Stdev,
        other => bail!("unsupported aggregate {other}"),
    };
    p.sym('(')?;
    // expression: '*' | term ((+|*) term)*
    let mut expr_tables = Vec::new();
    let combine;
    if p.try_sym('*') {
        combine = CombineOp::Left;
    } else {
        let (t, _col) = p.qualified()?;
        expr_tables.push(t);
        let mut op: Option<CombineOp> = None;
        loop {
            if p.try_sym('+') {
                if op == Some(CombineOp::Product) {
                    bail!("mixed +/* combine expressions are not supported");
                }
                op = Some(CombineOp::Sum);
            } else if p.try_sym('*') {
                if op == Some(CombineOp::Sum) {
                    bail!("mixed +/* combine expressions are not supported");
                }
                op = Some(CombineOp::Product);
            } else {
                break;
            }
            let (t, _col) = p.qualified()?;
            expr_tables.push(t);
        }
        combine = op.unwrap_or(CombineOp::Left);
    }
    p.sym(')')?;

    p.keyword("FROM")?;
    let mut tables = vec![p.ident()?];
    while p.try_sym(',') {
        tables.push(p.ident()?);
    }
    if tables.len() < 2 {
        bail!("a join needs at least two tables");
    }

    p.keyword("WHERE")?;
    let (t0, attr) = p.qualified()?;
    let mut chain_tables = vec![t0];
    while p.try_sym('=') {
        let (t, a) = p.qualified()?;
        if !a.eq_ignore_ascii_case(&attr) {
            bail!("join attributes differ: {attr} vs {a} (single-attribute equi-join only)");
        }
        chain_tables.push(t);
    }
    if chain_tables.len() != tables.len() {
        bail!(
            "WHERE chain covers {} tables but FROM lists {}",
            chain_tables.len(),
            tables.len()
        );
    }
    for t in &chain_tables {
        if !tables.iter().any(|x| x.eq_ignore_ascii_case(t)) {
            bail!("WHERE references unknown table {t}");
        }
    }
    for t in &expr_tables {
        if !tables.iter().any(|x| x.eq_ignore_ascii_case(t)) {
            bail!("SELECT references unknown table {t}");
        }
    }

    // budget clauses
    let mut budget = Budget::unbounded();
    loop {
        if p.try_keyword("WITHIN") {
            let v = p.num()?;
            p.keyword("SECONDS")
                .or_else(|_| -> Result<()> { bail!("WITHIN needs SECONDS") })?;
            budget.latency_secs = Some(v);
        } else if p.try_keyword("ERROR") {
            let bound = p.num()?;
            p.keyword("CONFIDENCE")?;
            let conf = p.num()?;
            p.sym('%')?;
            budget.error = Some(ErrorBudget {
                bound,
                confidence: conf / 100.0,
            });
        } else if p.try_keyword("OR") {
            continue;
        } else {
            break;
        }
    }
    if p.peek().is_some() {
        bail!("trailing tokens after query: {:?}", p.peek());
    }

    Ok(Query {
        agg,
        combine,
        tables,
        join_attr: attr,
        budget,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_full() {
        let q = parse(
            "SELECT SUM(R1.V + R2.V + R3.V) FROM R1, R2, R3 \
             WHERE R1.A = R2.A = R3.A \
             WITHIN 120 SECONDS OR ERROR 0.01 CONFIDENCE 95%",
        )
        .unwrap();
        assert_eq!(q.agg, AggFunc::Sum);
        assert_eq!(q.combine, CombineOp::Sum);
        assert_eq!(q.tables, vec!["R1", "R2", "R3"]);
        assert_eq!(q.join_attr, "A");
        assert_eq!(q.budget.latency_secs, Some(120.0));
        let e = q.budget.error.unwrap();
        assert_eq!(e.bound, 0.01);
        assert!((e.confidence - 0.95).abs() < 1e-12);
    }

    #[test]
    fn latency_only_and_error_only() {
        let q = parse("SELECT AVG(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 30 SECONDS")
            .unwrap();
        assert_eq!(q.budget.latency_secs, Some(30.0));
        assert!(q.budget.error.is_none());
        let q = parse("SELECT SUM(a.v * b.v) FROM a, b WHERE a.k = b.k ERROR 0.05 CONFIDENCE 99%")
            .unwrap();
        assert_eq!(q.combine, CombineOp::Product);
        assert!(q.budget.latency_secs.is_none());
        assert_eq!(q.budget.error.unwrap().confidence, 0.99);
    }

    #[test]
    fn count_star_and_unbudgeted() {
        let q = parse("SELECT COUNT(*) FROM tcp, udp, icmp WHERE tcp.flow = udp.flow = icmp.flow")
            .unwrap();
        assert_eq!(q.agg, AggFunc::Count);
        assert_eq!(q.combine, CombineOp::Left);
        assert!(q.budget.is_unbounded());
    }

    #[test]
    fn single_table_expr() {
        let q = parse("SELECT SUM(tcp.size) FROM tcp, udp WHERE tcp.f = udp.f").unwrap();
        assert_eq!(q.combine, CombineOp::Left);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("SELECT NOPE(a.v) FROM a, b WHERE a.k = b.k").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a WHERE a.k = a.k").is_err());
        assert!(parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.j").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k EXTRA").is_err());
        assert!(parse("SELECT SUM(a.v * b.v + c.v) FROM a, b, c WHERE a.k = b.k = c.k").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = c.k").is_err());
        // WHERE chain must cover all FROM tables
        assert!(parse("SELECT SUM(a.v) FROM a, b, c WHERE a.k = b.k").is_err());
    }

    #[test]
    fn case_insensitive_keywords() {
        let q = parse("select sum(a.v + b.v) from a, b where a.k = b.k within 5 seconds").unwrap();
        assert_eq!(q.budget.latency_secs, Some(5.0));
    }
}
