//! Recursive-descent parser for the budget-query language (§2), extended
//! with the relational front end's grammar: WHERE selection predicates
//! over non-join columns, GROUP BY, multiple aggregates and aliases.
//!
//! Grammar (case-insensitive keywords):
//!
//! ```text
//! query    := SELECT selects FROM from (WHERE conj)? group? budget?
//! selects  := item (',' item)*
//! item     := agg '(' expr ')' (AS ident)? | colref
//! agg      := SUM | AVG | COUNT | STDEV
//! expr     := colref (('+' | '*') colref)* | '*'
//! colref   := ident ('.' ident)?
//! from     := ident (',' ident)*            -- comma list: inner join
//!           | ident joined+                 -- explicit JOIN clauses
//! joined   := variant ident (ON colref '=' colref)?
//! variant  := JOIN | INNER JOIN
//!           | LEFT  OUTER? JOIN
//!           | RIGHT OUTER? JOIN
//!           | FULL  OUTER? JOIN
//!           | SEMI JOIN | ANTI JOIN
//! conj     := cond (AND cond)*
//! cond     := colref ('=' colref)+          -- join chain
//!           | colref cmp number             -- selection predicate
//! cmp      := '>' | '<' | '>=' | '<=' | '=' | '!='
//! group    := GROUP BY colref
//! budget   := within | error | within OR error
//! within   := WITHIN number SECONDS
//! error    := ERROR number CONFIDENCE number '%'
//! ```
//!
//! A bare (unqualified) column reference resolves against the registered
//! schemas at lowering time. Bare items in the SELECT list must name the
//! GROUP BY column (the echoed group key). WHERE may be omitted only when
//! every JOIN clause carries an ON condition. The non-inner variants
//! (outer/semi/anti) are binary joins: exactly two tables, one unaliased
//! aggregate, no predicates or GROUP BY; SEMI/ANTI aggregates may only
//! reference the left table (the output has no right-side columns).

use super::ast::{AggFunc, Budget, ErrorBudget, Query};
use crate::join::{CombineOp, JoinVariant};
use crate::relation::{AggExpr, CmpOp, ColumnRef, Predicate};
use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Num(f64),
    Sym(char),
}

fn tokenize(s: &str) -> Result<Vec<Tok>> {
    let mut out = Vec::new();
    let b: Vec<char> = s.chars().collect();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c.is_whitespace() {
            i += 1;
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.push(Tok::Ident(b[start..i].iter().collect()));
        } else if c.is_ascii_digit() || (c == '.' && i + 1 < b.len() && b[i + 1].is_ascii_digit())
        {
            let start = i;
            while i < b.len() && (b[i].is_ascii_digit() || b[i] == '.') {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            out.push(Tok::Num(text.parse().map_err(|_| anyhow!("bad number {text}"))?));
        } else if "()+*,.=%<>!-".contains(c) {
            out.push(Tok::Sym(c));
            i += 1;
        } else {
            bail!("unexpected character '{c}' at {i}");
        }
    }
    Ok(out)
}

struct P {
    toks: Vec<Tok>,
    i: usize,
}

impl P {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn peek_at(&self, ahead: usize) -> Option<&Tok> {
        self.toks.get(self.i + ahead)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self
            .toks
            .get(self.i)
            .cloned()
            .ok_or_else(|| anyhow!("unexpected end of query"))?;
        self.i += 1;
        Ok(t)
    }

    fn keyword(&mut self, kw: &str) -> Result<()> {
        match self.next()? {
            Tok::Ident(s) if s.eq_ignore_ascii_case(kw) => Ok(()),
            t => bail!("expected {kw}, got {t:?}"),
        }
    }

    fn try_keyword(&mut self, kw: &str) -> bool {
        if let Some(Tok::Ident(s)) = self.peek() {
            if s.eq_ignore_ascii_case(kw) {
                self.i += 1;
                return true;
            }
        }
        false
    }

    fn sym(&mut self, c: char) -> Result<()> {
        match self.next()? {
            Tok::Sym(s) if s == c => Ok(()),
            t => bail!("expected '{c}', got {t:?}"),
        }
    }

    fn try_sym(&mut self, c: char) -> bool {
        if self.peek() == Some(&Tok::Sym(c)) {
            self.i += 1;
            return true;
        }
        false
    }

    fn ident(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            t => bail!("expected identifier, got {t:?}"),
        }
    }

    fn num(&mut self) -> Result<f64> {
        match self.next()? {
            Tok::Num(v) => Ok(v),
            t => bail!("expected number, got {t:?}"),
        }
    }

    /// A possibly-negative numeric literal (predicate right-hand sides;
    /// budget clauses use [`P::num`] so negative budgets stay rejected).
    fn literal(&mut self) -> Result<f64> {
        if self.try_sym('-') {
            Ok(-self.num()?)
        } else {
            self.num()
        }
    }

    /// `table '.' column` or bare `column`.
    fn colref(&mut self) -> Result<ColumnRef> {
        let first = self.ident()?;
        if self.try_sym('.') {
            let c = self.ident()?;
            Ok(ColumnRef::qualified(first, c))
        } else {
            Ok(ColumnRef::bare(first))
        }
    }

    /// A comparison operator, if the next token(s) form one.
    fn try_cmp(&mut self) -> Result<Option<CmpOp>> {
        match self.peek() {
            Some(Tok::Sym('>')) => {
                self.i += 1;
                Ok(Some(if self.try_sym('=') { CmpOp::Ge } else { CmpOp::Gt }))
            }
            Some(Tok::Sym('<')) => {
                self.i += 1;
                Ok(Some(if self.try_sym('=') { CmpOp::Le } else { CmpOp::Lt }))
            }
            Some(Tok::Sym('!')) => {
                self.i += 1;
                if self.try_sym('=') {
                    Ok(Some(CmpOp::Ne))
                } else {
                    bail!("'!' must be followed by '=' (the != operator)")
                }
            }
            _ => Ok(None),
        }
    }
}

fn agg_func(name: &str) -> Option<AggFunc> {
    match name.to_ascii_uppercase().as_str() {
        "SUM" => Some(AggFunc::Sum),
        "AVG" => Some(AggFunc::Avg),
        "COUNT" => Some(AggFunc::Count),
        "STDEV" => Some(AggFunc::Stdev),
        _ => None,
    }
}

/// If the next tokens start a JOIN clause, consume through the `JOIN`
/// keyword and return the variant; `None` leaves the cursor untouched.
/// `LEFT SEMI JOIN` / `LEFT ANTI JOIN` (the Spark spellings) are rejected
/// with a pointed error rather than mis-parsing.
fn try_join_variant(p: &mut P) -> Result<Option<JoinVariant>> {
    let word = |p: &P| match p.peek() {
        Some(Tok::Ident(s)) => Some(s.to_ascii_uppercase()),
        _ => None,
    };
    let v = match word(p).as_deref() {
        Some("JOIN") => {
            p.keyword("JOIN")?;
            JoinVariant::Inner
        }
        Some("INNER") => {
            p.keyword("INNER")?;
            p.keyword("JOIN")?;
            JoinVariant::Inner
        }
        Some(side @ ("LEFT" | "RIGHT" | "FULL")) => {
            let variant = match side {
                "LEFT" => JoinVariant::LeftOuter,
                "RIGHT" => JoinVariant::RightOuter,
                _ => JoinVariant::FullOuter,
            };
            let side = side.to_string();
            p.next()?;
            if let Some(w @ ("SEMI" | "ANTI")) = word(p).as_deref() {
                bail!("{side} {w} JOIN is not supported: write {w} JOIN");
            }
            p.try_keyword("OUTER");
            p.keyword("JOIN")?;
            variant
        }
        Some("SEMI") => {
            p.keyword("SEMI")?;
            p.keyword("JOIN")?;
            JoinVariant::Semi
        }
        Some("ANTI") => {
            p.keyword("ANTI")?;
            p.keyword("JOIN")?;
            JoinVariant::Anti
        }
        _ => return Ok(None),
    };
    Ok(Some(v))
}

/// Parse one `FUNC '(' expr ')' (AS ident)?` call.
fn agg_call(p: &mut P) -> Result<AggExpr> {
    let name = p.ident()?;
    let func = agg_func(&name).ok_or_else(|| anyhow!("unsupported aggregate {name}"))?;
    p.sym('(')?;
    let mut terms = Vec::new();
    let combine;
    if p.try_sym('*') {
        combine = CombineOp::Left;
    } else {
        terms.push(p.colref()?);
        let mut op: Option<CombineOp> = None;
        loop {
            if p.try_sym('+') {
                if op == Some(CombineOp::Product) {
                    bail!("mixed +/* combine expressions are not supported");
                }
                op = Some(CombineOp::Sum);
            } else if p.try_sym('*') {
                if op == Some(CombineOp::Sum) {
                    bail!("mixed +/* combine expressions are not supported");
                }
                op = Some(CombineOp::Product);
            } else {
                break;
            }
            terms.push(p.colref()?);
        }
        combine = op.unwrap_or(CombineOp::Left);
    }
    p.sym(')')?;
    let alias = if p.try_keyword("AS") {
        Some(p.ident()?)
    } else {
        None
    };
    Ok(AggExpr {
        func,
        combine,
        terms,
        alias,
    })
}

/// Parse a budget query.
pub fn parse(text: &str) -> Result<Query> {
    let mut p = P {
        toks: tokenize(text)?,
        i: 0,
    };
    p.keyword("SELECT")?;

    // ---- SELECT list: aggregate calls and (for grouped queries) the
    // echoed group-key column
    let mut aggregates: Vec<AggExpr> = Vec::new();
    let mut echoed: Vec<ColumnRef> = Vec::new();
    loop {
        // an identifier followed by '(' is an aggregate call
        let is_call = matches!(p.peek(), Some(Tok::Ident(_)))
            && p.peek_at(1) == Some(&Tok::Sym('('));
        if is_call {
            aggregates.push(agg_call(&mut p)?);
        } else {
            echoed.push(p.colref()?);
        }
        if !p.try_sym(',') {
            break;
        }
    }
    if aggregates.is_empty() {
        bail!("SELECT needs at least one aggregate (SUM/AVG/COUNT/STDEV)");
    }

    p.keyword("FROM")?;
    let mut tables = vec![p.ident()?];
    let mut variant = JoinVariant::Inner;
    let mut join_attr: Option<String> = None;
    let mut chains: Vec<Vec<String>> = Vec::new();
    if p.peek() == Some(&Tok::Sym(',')) {
        // legacy comma list: an inner join, chained in WHERE
        while p.try_sym(',') {
            tables.push(p.ident()?);
        }
    } else {
        // explicit JOIN clauses, optionally with ON conditions
        while let Some(v) = try_join_variant(&mut p)? {
            if !v.is_inner() {
                if !variant.is_inner() {
                    bail!(
                        "at most one non-inner join variant per query \
                         ({} then {})",
                        variant.sql(),
                        v.sql()
                    );
                }
                variant = v;
            }
            tables.push(p.ident()?);
            if p.try_keyword("ON") {
                let l = p.colref()?;
                p.sym('=')?;
                let r = p.colref()?;
                let (Some(lt), Some(rt)) = (l.table.clone(), r.table.clone()) else {
                    bail!("ON clause needs table-qualified columns, got {l} = {r}");
                };
                if !l.column.eq_ignore_ascii_case(&r.column) {
                    bail!(
                        "join attributes differ: {} vs {} \
                         (single-attribute equi-join only)",
                        l.column,
                        r.column
                    );
                }
                match &join_attr {
                    Some(a) if !a.eq_ignore_ascii_case(&l.column) => {
                        bail!(
                            "join attributes differ: {a} vs {} \
                             (single-attribute equi-join only)",
                            l.column
                        );
                    }
                    Some(_) => {}
                    None => join_attr = Some(l.column.clone()),
                }
                chains.push(vec![lt, rt]);
            } else if !v.is_inner() {
                // a non-inner JOIN's chain cannot be recovered from WHERE
                // order-insensitively — require ON
                bail!("{} requires an ON condition", v.sql());
            }
            // plain JOIN without ON: the chain comes from WHERE
        }
    }
    if tables.len() < 2 {
        bail!("a join needs at least two tables");
    }
    let known = |t: &str| tables.iter().any(|x| x.eq_ignore_ascii_case(t));

    // ---- WHERE: a conjunction of join chains and selection predicates
    let mut predicates: Vec<Predicate> = Vec::new();
    if p.try_keyword("WHERE") {
        loop {
            let first = p.colref()?;
            if let Some(op) = p.try_cmp()? {
                // comparison predicate: colref cmp number
                let lit = p.literal()?;
                predicates.push(Predicate {
                    column: first,
                    op,
                    literal: lit,
                });
            } else if p.peek() == Some(&Tok::Sym('=')) {
                // '=' starts either a join chain (RHS is a column) or an
                // equality predicate (RHS is a number, possibly negative)
                let rhs_is_num = matches!(p.peek_at(1), Some(Tok::Num(_)))
                    || (p.peek_at(1) == Some(&Tok::Sym('-'))
                        && matches!(p.peek_at(2), Some(Tok::Num(_))));
                if rhs_is_num {
                    p.sym('=')?;
                    let lit = p.literal()?;
                    predicates.push(Predicate {
                        column: first,
                        op: CmpOp::Eq,
                        literal: lit,
                    });
                } else {
                    let Some(t0) = first.table.clone() else {
                        bail!("join clause needs table-qualified columns, got {first}");
                    };
                    let attr = first.column.clone();
                    match &join_attr {
                        Some(a) if !a.eq_ignore_ascii_case(&attr) => {
                            bail!(
                                "join attributes differ: {a} vs {attr} \
                                 (single-attribute equi-join only)"
                            );
                        }
                        Some(_) => {}
                        None => join_attr = Some(attr.clone()),
                    }
                    let mut this_chain = vec![t0];
                    while p.try_sym('=') {
                        let next = p.colref()?;
                        let Some(t) = next.table.clone() else {
                            bail!("join clause needs table-qualified columns, got {next}");
                        };
                        if !next.column.eq_ignore_ascii_case(&attr) {
                            bail!(
                                "join attributes differ: {attr} vs {} \
                                 (single-attribute equi-join only)",
                                next.column
                            );
                        }
                        this_chain.push(t);
                    }
                    chains.push(this_chain);
                }
            } else {
                bail!("expected a comparison or join clause after {first}");
            }
            if !p.try_keyword("AND") {
                break;
            }
        }
    }
    let Some(attr) = join_attr else {
        bail!(
            "query needs an equi-join clause \
             (t1.attr = t2.attr in WHERE, or JOIN ... ON)"
        );
    };
    // AND-ed chains must form ONE connected equi-join class — the engine
    // runs a single transitive n-way equi-join, so disconnected chains
    // would silently change the query's meaning. Connectivity is decided
    // after all chains are collected (clause order must not matter) by
    // the shared join-graph implementation — the same absorption the
    // join-order optimizer builds its adjacency from, so the parser and
    // the optimizer can never disagree about well-formedness.
    let chain_tables = crate::join::join_graph::connected_component(&chains)
        .map_err(|e| anyhow!(e))?;
    // dedup within a chain happened above, so every distinct FROM table
    // must appear (duplicate FROM entries — self-joins — count once)
    let mut from_distinct: Vec<&String> = Vec::new();
    for t in &tables {
        if !from_distinct.iter().any(|x| x.eq_ignore_ascii_case(t)) {
            from_distinct.push(t);
        }
    }
    if chain_tables.len() != from_distinct.len() {
        bail!(
            "WHERE chain covers {} tables but FROM lists {}",
            chain_tables.len(),
            from_distinct.len()
        );
    }
    for t in &chain_tables {
        if !known(t) {
            bail!("WHERE references unknown table {t}");
        }
    }
    for pred in &predicates {
        if let Some(t) = &pred.column.table {
            if !known(t) {
                bail!("WHERE references unknown table {t}");
            }
        }
    }
    for a in &aggregates {
        for term in &a.terms {
            if let Some(t) = &term.table {
                if !known(t) {
                    bail!("SELECT references unknown table {t}");
                }
            }
        }
    }

    // ---- GROUP BY
    let mut group_by: Option<ColumnRef> = None;
    if p.try_keyword("GROUP") {
        p.keyword("BY")?;
        let g = p.colref()?;
        if let Some(t) = &g.table {
            if !known(t) {
                bail!("GROUP BY references unknown table {t}");
            }
        }
        group_by = Some(g);
    }
    // bare SELECT items must echo the group key
    match &group_by {
        Some(g) => {
            for e in &echoed {
                let same_col = e.column.eq_ignore_ascii_case(&g.column);
                let same_table = match (&e.table, &g.table) {
                    (Some(a), Some(b)) => a.eq_ignore_ascii_case(b),
                    _ => true,
                };
                if !same_col || !same_table {
                    bail!("SELECT column {e} is not the GROUP BY column {g}");
                }
            }
        }
        None => {
            if let Some(e) = echoed.first() {
                bail!("SELECT column {e} without GROUP BY");
            }
        }
    }

    // ---- non-inner variants are binary scalar joins
    if !variant.is_inner() {
        let vsql = variant.sql();
        if tables.len() != 2 {
            bail!("{vsql} is binary: FROM must join exactly two tables");
        }
        if group_by.is_some() {
            bail!("GROUP BY is not supported with {vsql}");
        }
        if !predicates.is_empty() {
            bail!("selection predicates are not supported with {vsql}");
        }
        if aggregates.len() > 1 || aggregates[0].alias.is_some() {
            bail!("{vsql} supports a single unaliased aggregate");
        }
        // semi/anti output only has left-side columns (self-joins excepted:
        // the two names are indistinguishable)
        if variant.membership_only() && !tables[0].eq_ignore_ascii_case(&tables[1]) {
            for term in &aggregates[0].terms {
                if let Some(t) = &term.table {
                    if t.eq_ignore_ascii_case(&tables[1]) {
                        bail!(
                            "{vsql} output has no columns of {t}: \
                             the aggregate may only reference {}",
                            tables[0]
                        );
                    }
                }
            }
        }
    }

    // ---- budget clauses
    let mut budget = Budget::unbounded();
    loop {
        if p.try_keyword("WITHIN") {
            let v = p.num()?;
            p.keyword("SECONDS")
                .or_else(|_| -> Result<()> { bail!("WITHIN needs SECONDS") })?;
            budget.latency_secs = Some(v);
        } else if p.try_keyword("ERROR") {
            let bound = p.num()?;
            p.keyword("CONFIDENCE")?;
            let conf = p.num()?;
            p.sym('%')?;
            budget.error = Some(ErrorBudget {
                bound,
                confidence: conf / 100.0,
            });
        } else if p.try_keyword("OR") {
            continue;
        } else {
            break;
        }
    }
    if p.peek().is_some() {
        bail!("trailing tokens after query: {:?}", p.peek());
    }

    let first = aggregates[0].clone();
    Ok(Query {
        agg: first.func,
        combine: first.combine,
        tables,
        join_attr: attr,
        join_clauses: chains,
        budget,
        aggregates,
        predicates,
        group_by,
        variant,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_full() {
        let q = parse(
            "SELECT SUM(R1.V + R2.V + R3.V) FROM R1, R2, R3 \
             WHERE R1.A = R2.A = R3.A \
             WITHIN 120 SECONDS OR ERROR 0.01 CONFIDENCE 95%",
        )
        .unwrap();
        assert_eq!(q.agg, AggFunc::Sum);
        assert_eq!(q.combine, CombineOp::Sum);
        assert_eq!(q.tables, vec!["R1", "R2", "R3"]);
        assert_eq!(q.join_attr, "A");
        assert_eq!(q.budget.latency_secs, Some(120.0));
        let e = q.budget.error.unwrap();
        assert_eq!(e.bound, 0.01);
        assert!((e.confidence - 0.95).abs() < 1e-12);
        assert_eq!(q.aggregates.len(), 1);
        assert!(q.predicates.is_empty());
        assert!(q.group_by.is_none());
    }

    #[test]
    fn latency_only_and_error_only() {
        let q = parse("SELECT AVG(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 30 SECONDS")
            .unwrap();
        assert_eq!(q.budget.latency_secs, Some(30.0));
        assert!(q.budget.error.is_none());
        let q = parse("SELECT SUM(a.v * b.v) FROM a, b WHERE a.k = b.k ERROR 0.05 CONFIDENCE 99%")
            .unwrap();
        assert_eq!(q.combine, CombineOp::Product);
        assert!(q.budget.latency_secs.is_none());
        assert_eq!(q.budget.error.unwrap().confidence, 0.99);
    }

    #[test]
    fn count_star_and_unbudgeted() {
        let q = parse("SELECT COUNT(*) FROM tcp, udp, icmp WHERE tcp.flow = udp.flow = icmp.flow")
            .unwrap();
        assert_eq!(q.agg, AggFunc::Count);
        assert_eq!(q.combine, CombineOp::Left);
        assert!(q.budget.is_unbounded());
        assert!(q.aggregates[0].terms.is_empty());
    }

    #[test]
    fn single_table_expr() {
        let q = parse("SELECT SUM(tcp.size) FROM tcp, udp WHERE tcp.f = udp.f").unwrap();
        assert_eq!(q.combine, CombineOp::Left);
        assert_eq!(q.aggregates[0].terms.len(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("SELECT NOPE(a.v) FROM a, b WHERE a.k = b.k").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a WHERE a.k = a.k").is_err());
        assert!(parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.j").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k EXTRA").is_err());
        assert!(parse("SELECT SUM(a.v * b.v + c.v) FROM a, b, c WHERE a.k = b.k = c.k").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = c.k").is_err());
        // WHERE chain must cover all FROM tables
        assert!(parse("SELECT SUM(a.v) FROM a, b, c WHERE a.k = b.k").is_err());
    }

    #[test]
    fn case_insensitive_keywords() {
        let q = parse("select sum(a.v + b.v) from a, b where a.k = b.k within 5 seconds").unwrap();
        assert_eq!(q.budget.latency_secs, Some(5.0));
    }

    // ---- relational grammar ------------------------------------------

    #[test]
    fn where_predicates_parse_and_push() {
        let q = parse(
            "SELECT SUM(a.v + b.v) FROM a, b \
             WHERE a.k = b.k AND a.x > 5 AND b.y <= 0.25 AND a.z != 3 AND a.w = 7",
        )
        .unwrap();
        assert_eq!(q.join_attr, "k");
        assert_eq!(q.predicates.len(), 4);
        assert_eq!(q.predicates[0].to_string(), "a.x > 5");
        assert_eq!(q.predicates[1].to_string(), "b.y <= 0.25");
        assert_eq!(q.predicates[2].to_string(), "a.z != 3");
        assert_eq!(q.predicates[3].to_string(), "a.w = 7");
        assert!(q.has_relational_features());
    }

    #[test]
    fn negative_predicate_literals() {
        let q = parse(
            "SELECT SUM(a.v + b.v) FROM a, b \
             WHERE a.k = b.k AND a.x < -100 AND a.y = -2.5",
        )
        .unwrap();
        assert_eq!(q.predicates[0].literal, -100.0);
        assert_eq!(q.predicates[1].literal, -2.5);
        // negative budgets remain rejected
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k WITHIN -5 SECONDS").is_err());
        // stray '-' elsewhere still errors
        assert!(parse("SELECT SUM(a.v - b.v) FROM a, b WHERE a.k = b.k").is_err());
    }

    #[test]
    fn group_by_and_echoed_key() {
        let q = parse(
            "SELECT a.g, SUM(a.v + b.v) FROM a, b WHERE a.k = b.k GROUP BY a.g \
             WITHIN 10 SECONDS",
        )
        .unwrap();
        assert_eq!(q.group_by.as_ref().unwrap().to_string(), "a.g");
        assert_eq!(q.budget.latency_secs, Some(10.0));

        // unqualified group key (the acceptance-criteria shape)
        let q = parse("SELECT g, SUM(a.v + b.v) FROM a, b WHERE a.k = b.k AND a.x > 2 GROUP BY g")
            .unwrap();
        assert_eq!(q.group_by.as_ref().unwrap().to_string(), "g");
        assert_eq!(q.predicates.len(), 1);
    }

    #[test]
    fn multiple_aggregates_and_aliases() {
        let q = parse(
            "SELECT SUM(a.v + b.v) AS total, AVG(a.v) AS mean_v, COUNT(*) \
             FROM a, b WHERE a.k = b.k",
        )
        .unwrap();
        assert_eq!(q.aggregates.len(), 3);
        assert_eq!(q.aggregates[0].alias.as_deref(), Some("total"));
        assert_eq!(q.aggregates[1].alias.as_deref(), Some("mean_v"));
        assert_eq!(q.aggregates[1].label(), "mean_v");
        assert_eq!(q.aggregates[2].label(), "COUNT(*)");
        // the legacy mirror is the first aggregate
        assert_eq!(q.agg, AggFunc::Sum);
        assert_eq!(q.combine, CombineOp::Sum);
    }

    #[test]
    fn split_join_chains_with_and() {
        let q = parse(
            "SELECT SUM(a.v + b.v + c.v) FROM a, b, c \
             WHERE a.k = b.k AND b.k = c.k",
        )
        .unwrap();
        assert_eq!(q.tables, vec!["a", "b", "c"]);
        assert_eq!(q.join_attr, "k");
        // the raw chains survive on the query for the join-order optimizer
        assert_eq!(
            q.join_clauses,
            vec![vec!["a", "b"], vec!["b", "c"]]
        );

        // chains that share no table would change the query's meaning
        // (this engine runs one transitive equi-join class) — rejected
        let err = parse(
            "SELECT SUM(a.v + b.v + c.v + d.v) FROM a, b, c, d \
             WHERE a.k = b.k AND c.k = d.k",
        )
        .unwrap_err();
        assert!(err.to_string().contains("disconnected"), "{err:#}");

        // ...but connectivity must not depend on clause order: a later
        // clause may supply the link
        let q = parse(
            "SELECT SUM(a.v + b.v + c.v + d.v) FROM a, b, c, d \
             WHERE a.k = b.k AND c.k = d.k AND b.k = c.k",
        )
        .unwrap();
        assert_eq!(q.tables.len(), 4);
    }

    #[test]
    fn legacy_fingerprints_are_stable() {
        // pre-relational queries must keep their exact fingerprint so
        // persisted feedback sigmas stay valid
        let q = parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k").unwrap();
        assert_eq!(q.fingerprint(), "SUM:Sum:a,b:k");
        let q = parse("SELECT COUNT(*) FROM a, b WHERE a.k = b.k").unwrap();
        assert_eq!(q.fingerprint(), "COUNT:Left:a,b:k");
    }

    #[test]
    fn self_join_duplicate_from_entries() {
        // FROM a, a joins a dataset with itself; the chain covers the one
        // distinct table
        let q = parse("SELECT SUM(a.v + a.v) FROM a, a WHERE a.k = a.k").unwrap();
        assert_eq!(q.tables, vec!["a", "a"]);
        assert_eq!(q.join_attr, "k");
    }

    // ---- join-variant grammar ----------------------------------------

    #[test]
    fn explicit_join_clauses_parse() {
        // inner JOIN ... ON is the comma form with the chain inlined
        let q = parse("SELECT SUM(a.v + b.v) FROM a JOIN b ON a.k = b.k").unwrap();
        assert_eq!(q.variant, JoinVariant::Inner);
        assert_eq!(q.tables, vec!["a", "b"]);
        assert_eq!(q.join_attr, "k");
        assert_eq!(q.join_clauses, vec![vec!["a", "b"]]);
        // inner fingerprint unchanged by the JOIN spelling
        assert_eq!(q.fingerprint(), "SUM:Sum:a,b:k");

        // chained inner JOINs
        let q = parse(
            "SELECT SUM(a.v + b.v + c.v) FROM a JOIN b ON a.k = b.k \
             JOIN c ON b.k = c.k",
        )
        .unwrap();
        assert_eq!(q.tables, vec!["a", "b", "c"]);
        assert_eq!(q.join_clauses.len(), 2);

        // JOIN without ON falls back to the WHERE chain
        let q = parse("SELECT SUM(a.v + b.v) FROM a JOIN b WHERE a.k = b.k").unwrap();
        assert_eq!(q.variant, JoinVariant::Inner);
        assert_eq!(q.join_attr, "k");
    }

    #[test]
    fn variant_grammar_parses() {
        for (sql, want) in [
            ("LEFT OUTER JOIN", JoinVariant::LeftOuter),
            ("LEFT JOIN", JoinVariant::LeftOuter),
            ("RIGHT OUTER JOIN", JoinVariant::RightOuter),
            ("RIGHT JOIN", JoinVariant::RightOuter),
            ("FULL OUTER JOIN", JoinVariant::FullOuter),
            ("FULL JOIN", JoinVariant::FullOuter),
            ("INNER JOIN", JoinVariant::Inner),
        ] {
            let q = parse(&format!(
                "SELECT SUM(a.v + b.v) FROM a {sql} b ON a.k = b.k"
            ))
            .unwrap_or_else(|e| panic!("{sql}: {e:#}"));
            assert_eq!(q.variant, want, "{sql}");
        }
        // semi/anti aggregates reference the left side only
        for (sql, want) in [("SEMI JOIN", JoinVariant::Semi), ("ANTI JOIN", JoinVariant::Anti)] {
            let q = parse(&format!(
                "SELECT SUM(a.v) FROM a {sql} b ON a.k = b.k WITHIN 10 SECONDS"
            ))
            .unwrap_or_else(|e| panic!("{sql}: {e:#}"));
            assert_eq!(q.variant, want, "{sql}");
            assert_eq!(q.budget.latency_secs, Some(10.0));
            assert!(q.fingerprint().ends_with(&format!(";v={}", want.tag())));
        }
        // COUNT(*) works for every variant
        let q = parse("SELECT COUNT(*) FROM a ANTI JOIN b ON a.k = b.k").unwrap();
        assert_eq!(q.agg, AggFunc::Count);
    }

    #[test]
    fn rejects_malformed_variants() {
        // the Spark LEFT SEMI spelling gets a pointed error
        let e = parse("SELECT SUM(a.v) FROM a LEFT SEMI JOIN b ON a.k = b.k").unwrap_err();
        assert!(e.to_string().contains("SEMI JOIN"), "{e:#}");
        assert!(parse("SELECT SUM(a.v) FROM a LEFT ANTI JOIN b ON a.k = b.k").is_err());
        // non-inner variants are binary
        assert!(parse(
            "SELECT SUM(a.v) FROM a SEMI JOIN b ON a.k = b.k JOIN c ON b.k = c.k"
        )
        .is_err());
        assert!(parse(
            "SELECT SUM(a.v) FROM a JOIN b ON a.k = b.k ANTI JOIN c ON b.k = c.k"
        )
        .is_err());
        // at most one non-inner variant
        assert!(parse(
            "SELECT SUM(a.v) FROM a SEMI JOIN b ON a.k = b.k LEFT JOIN c ON b.k = c.k"
        )
        .is_err());
        // non-inner joins need ON
        assert!(parse("SELECT SUM(a.v) FROM a SEMI JOIN b WHERE a.k = b.k").is_err());
        // GROUP BY / predicates / aliases are inner-only
        assert!(parse(
            "SELECT SUM(a.v) FROM a SEMI JOIN b ON a.k = b.k GROUP BY a.g"
        )
        .is_err());
        assert!(parse(
            "SELECT SUM(a.v) FROM a LEFT JOIN b ON a.k = b.k AND a.x > 1"
        )
        .is_err());
        assert!(parse(
            "SELECT SUM(a.v) AS s FROM a ANTI JOIN b ON a.k = b.k"
        )
        .is_err());
        // semi/anti aggregates must not touch the right table
        assert!(parse("SELECT SUM(a.v + b.v) FROM a SEMI JOIN b ON a.k = b.k").is_err());
        // mixing comma-FROM with JOIN clauses is rejected
        assert!(parse("SELECT SUM(a.v) FROM a, b JOIN c ON b.k = c.k WHERE a.k = b.k").is_err());
        // dangling variant keywords
        assert!(parse("SELECT SUM(a.v) FROM a LEFT OUTER b ON a.k = b.k").is_err());
        assert!(parse("SELECT SUM(a.v) FROM a SEMI b ON a.k = b.k").is_err());
    }

    #[test]
    fn rejects_malformed_relational() {
        // bare SELECT column without GROUP BY
        assert!(parse("SELECT g, SUM(a.v) FROM a, b WHERE a.k = b.k").is_err());
        // SELECT column that is not the group key
        assert!(
            parse("SELECT h, SUM(a.v) FROM a, b WHERE a.k = b.k GROUP BY g").is_err()
        );
        // GROUP BY on an unknown table
        assert!(
            parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k GROUP BY z.g").is_err()
        );
        // predicate on an unknown table
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k AND z.x > 1").is_err());
        // split chains with different attributes
        assert!(parse("SELECT SUM(a.v) FROM a, b, c WHERE a.k = b.k AND b.j = c.j").is_err());
        // predicate-only WHERE (no join clause)
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.x > 1").is_err());
        // bare columns in a join clause
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE k = b.k").is_err());
        // dangling comparison
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k AND a.x >").is_err());
        // '!' without '='
        assert!(parse("SELECT SUM(a.v) FROM a, b WHERE a.k = b.k AND a.x ! 3").is_err());
    }
}
