//! The ApproxJoin engine: the public entry point tying together the query
//! front end, the filtering stage, the cost-function planner, the sampling
//! stage, the AOT/XLA executors and the error estimators.
//!
//! Pipeline per query (paper Fig 2):
//!   parse → stage 1 filtering (§3.1) → cost function (§3.2) decides
//!   exact vs approximate → cross product or sampling-during-join (§3.3)
//!   → error estimation (§3.4) → `result ± error_bound`, feedback σ stored.

pub mod baselines;
pub mod config;

pub use config::EngineConfig;

use crate::cluster::{JoinMetrics, ShuffleLedger, SimCluster};
use crate::cost::{CostModel, FeedbackStore};
use crate::data::Dataset;
use crate::join::approx::{
    sample_stage, ApproxConfig, BatchAggregator, NativeAggregator, SamplingParams,
};
use crate::join::bloom_join::{
    cross_product_stage, filter_and_shuffle, FilterConfig, KeyProber, NativeProber,
};
use crate::query::{AggFunc, Query};
use crate::runtime::{BloomProbeExecutor, JoinAggExecutor, PjrtRuntime};
use crate::stats::{ApproxResult, EstimatorKind, StratumAgg};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// How the engine decided to execute a query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ExecutionMode {
    /// Exact cross product — the overlap fit the budget (or no budget).
    Exact,
    /// Sampled during the join at the given fraction (latency-driven) or
    /// with per-stratum error-driven sizes (fraction = NaN then).
    Sampled { fraction: f64 },
}

/// The engine's answer to a query.
#[derive(Clone, Debug)]
pub struct QueryOutcome {
    pub result: ApproxResult,
    pub metrics: JoinMetrics,
    /// Measured per-stage / per-worker shuffle traffic of the run.
    pub ledger: ShuffleLedger,
    pub mode: ExecutionMode,
    /// Simulated seconds the whole query took on the modeled cluster.
    pub sim_secs: f64,
    /// d_dt: filtering + shuffle portion (eq 1).
    pub d_dt: f64,
    /// Σ B_i after filtering — the exact join-output cardinality.
    pub output_cardinality: f64,
    /// Registry name of the strategy that produced the result.
    pub strategy: String,
    /// The cost-based plan, when the query went through the
    /// [`crate::session::Session`] planner (the engine's own §3.2
    /// exact-vs-sampled decision does not produce one).
    pub plan: Option<crate::join::JoinPlan>,
    /// Per-group estimates (one `estimate ± CI` per group per aggregate)
    /// when the query went through the relational front end; `None` on
    /// the legacy scalar path.
    pub grouped: Option<crate::relation::GroupedApproxResult>,
    /// The join filter the run built (kind, geometry, measured-fill fp
    /// rate); `None` when the executed strategy does not filter.
    pub filter_report: Option<crate::bloom::FilterReport>,
    /// The join-order optimizer's decision for this run (chosen order,
    /// DP vs greedy, per-step predicted vs *measured* cardinality);
    /// `None` when ordering was skipped — two-way join, disabled by
    /// `EngineConfig::reorder_joins`, or a non-commutative combine op.
    pub join_order: Option<crate::join::JoinOrderReport>,
    /// What the fault injector did to this run and how the engine
    /// recovered (retries, speculative copies, dropped strata, widened
    /// CI). `None` when no [`crate::faults::FaultPlan`] was configured.
    pub fault_report: Option<crate::faults::FaultReport>,
}

/// The ApproxJoin coordinator engine.
pub struct ApproxJoinEngine {
    pub cfg: EngineConfig,
    pub cost: CostModel,
    pub feedback: FeedbackStore,
    /// Shared sketch cache (the serving layer attaches one per
    /// [`crate::serve::Server`]); `None` means stage 1 always rebuilds.
    pub sketches: Option<std::sync::Arc<crate::serve::SketchCache>>,
    runtime: Option<PjrtRuntime>,
    join_agg: Option<JoinAggExecutor>,
    prober: Option<BloomProbeExecutor>,
    native_agg: NativeAggregator,
}

impl ApproxJoinEngine {
    /// Build an engine; compiles the AOT artifacts when available. When the
    /// artifacts directory exists but the PJRT runtime cannot start (e.g.
    /// the crate was built against the vendored XLA stub), the engine warns
    /// and falls back to pure-Rust execution instead of failing.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let runtime = match &cfg.artifacts_dir {
            Some(dir) => match PjrtRuntime::open(dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!(
                        "warning: XLA runtime unavailable ({e:#}); \
                         falling back to native execution"
                    );
                    None
                }
            },
            None => None,
        };
        let (join_agg, prober) = match &runtime {
            Some(rt) => (Some(rt.join_agg()?), Some(rt.bloom_probe()?)),
            None => (None, None),
        };
        Ok(Self {
            cfg,
            cost: CostModel::default(),
            feedback: FeedbackStore::in_memory(),
            sketches: None,
            runtime,
            join_agg,
            prober,
            native_agg: NativeAggregator::default(),
        })
    }

    /// Pure-Rust engine (no artifacts) — tests, quick starts.
    pub fn without_runtime(mut cfg: EngineConfig) -> Result<Self> {
        cfg.artifacts_dir = None;
        Self::new(cfg)
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// Use a profiled cost model (β_compute from this host / cluster).
    pub fn with_cost_model(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    pub fn with_feedback(mut self, feedback: FeedbackStore) -> Self {
        self.feedback = feedback;
        self
    }

    /// Attach a shared [`crate::serve::SketchCache`]: stage 1 consults it
    /// before building filters/cogroups, and inserts what it builds.
    pub fn with_sketches(mut self, sketches: std::sync::Arc<crate::serve::SketchCache>) -> Self {
        self.sketches = Some(sketches);
        self
    }

    fn cluster(&self) -> SimCluster {
        SimCluster::new(self.cfg.workers, self.cfg.time_model)
            .with_parallelism(self.cfg.parallelism)
            .with_faults(self.cfg.faults)
    }

    fn filter_config(&self, inputs: &[Dataset]) -> FilterConfig {
        if self.cfg.pin_artifact_filter_geometry {
            if let Some(rt) = &self.runtime {
                // the AOT artifact only understands the standard layout —
                // pinning its geometry overrides a blocked filter_kind,
                // and silently would hide the downgrade from the user
                if self.cfg.filter_kind != crate::bloom::FilterKind::Standard {
                    eprintln!(
                        "warning: pin_artifact_filter_geometry forces the \
                         standard filter layout; filter_kind={} is ignored",
                        self.cfg.filter_kind
                    );
                }
                return FilterConfig {
                    log2_bits: rt.geometry.log2_bits,
                    num_hashes: rt.geometry.num_hashes,
                    kind: crate::bloom::FilterKind::Standard,
                };
            }
        }
        FilterConfig::for_inputs_kind(inputs, self.cfg.fp_rate, self.cfg.filter_kind)
    }

    /// Execute a parsed query against named datasets (names must match the
    /// query's FROM list).
    pub fn execute(
        &mut self,
        query: &Query,
        datasets: &HashMap<String, Dataset>,
    ) -> Result<QueryOutcome> {
        let mut inputs = Vec::with_capacity(query.tables.len());
        for t in &query.tables {
            let Some(d) = datasets.get(t) else {
                bail!("dataset {t} not registered");
            };
            inputs.push(d.clone());
        }
        self.execute_on(query, &inputs)
    }

    /// Execute a parsed query on inputs given in FROM order.
    pub fn execute_on(&mut self, query: &Query, inputs: &[Dataset]) -> Result<QueryOutcome> {
        if inputs.len() != query.tables.len() {
            bail!(
                "query joins {} tables but {} datasets were given",
                query.tables.len(),
                inputs.len()
            );
        }
        // the engine's §3.2 budget loop sizes stage-2 sampling for the
        // inner cross product; non-inner variants run through the session's
        // strategy dispatch (semi/anti never reach stage 2 at all)
        if !query.variant.is_inner() {
            return Err(crate::join::JoinError::Unsupported {
                strategy: "engine".to_string(),
                reason: format!(
                    "the budgeted engine path is inner-join only; run {} \
                     through the session strategy dispatch",
                    query.variant.tag()
                ),
            }
            .into());
        }

        // ---- stage 0: join-order optimization. The engine owns ordering
        // on this path (the session front end passes inputs in FROM order
        // and copies the report out of the outcome). Planning reads only
        // (query, per-table stats, feedback snapshot), so it is
        // deterministic and thread-count independent; query.tables is
        // never permuted — fingerprints must stay byte-stable.
        let commutative = matches!(
            query.combine,
            crate::join::CombineOp::Sum | crate::join::CombineOp::Product
        );
        let order_ctx = crate::join::order::OrderContext {
            feedback: Some(&self.feedback),
            predicate_tag: String::new(),
            beta_compute: self.cost.beta_compute,
            workers: self.cfg.workers,
            bandwidth: self.cfg.time_model.bandwidth,
            enabled: self.cfg.reorder_joins,
        };
        let table_stats = crate::join::TableStats::collect(inputs, &query.tables);
        let mut join_order = crate::join::order::plan_query_order(
            &query.tables,
            &query.join_clauses,
            commutative,
            &table_stats,
            &order_ctx,
        );
        let (exec_inputs, exec_tables): (Vec<Dataset>, Vec<String>) = match &join_order {
            Some(r) if r.reordered => {
                (crate::join::order::permute(inputs, &r.order), r.tables.clone())
            }
            _ => (inputs.to_vec(), query.tables.clone()),
        };
        let inputs: &[Dataset] = &exec_inputs;

        let mut cluster = self.cluster();
        let filter_cfg = self.filter_config(inputs);
        let sketches = self.sketches.clone();

        // ---- stage 1: filtering (§3.1), via the sketch cache when one is
        // attached (cache hits replay bit-identical artifacts, so the
        // answer never depends on who warmed the cache)
        let mut native_prober = NativeProber;
        let prober: &mut dyn KeyProber = match &mut self.prober {
            Some(p) => p,
            None => &mut native_prober,
        };
        let (filtered, cache_hit) = match &sketches {
            Some(cache) => {
                // the scalar path's cogroup depends only on the inputs and
                // the filter geometry, so predicate/projection tags are
                // empty and every scalar query over the same tables shares
                cache.filtered(
                    &mut cluster,
                    inputs,
                    &exec_tables,
                    "",
                    "",
                    query.variant,
                    filter_cfg,
                    prober,
                )?
            }
            None => (
                filter_and_shuffle(&mut cluster, inputs, filter_cfg, prober)?,
                crate::bloom::SketchCacheHit::None,
            ),
        };
        let d_dt = filtered.d_dt;

        // exact output cardinality Σ B_i (known after filtering), summed
        // over the columnar directories in ascending key order
        let total_pairs: f64 = filtered.total_pairs();
        let filter_report = filtered.join_filter.report().with_cache_hit(cache_hit);

        // ---- stage 2.1: cost function decides the plan (§3.2)
        let confidence = query.budget.error.map(|e| e.confidence).unwrap_or(0.95);
        let mode = self.plan(query, d_dt, total_pairs);

        // ---- stage 2.2: execute
        let fingerprint = query.fingerprint();
        let (mut strata, mut draws, sampled) = match mode {
            ExecutionMode::Exact => {
                let strata = cross_product_stage(&mut cluster, &filtered, query.combine);
                (strata, HashMap::new(), false)
            }
            ExecutionMode::Sampled { fraction } => {
                let params = if fraction.is_nan() {
                    let err = query.budget.error.expect("error-driven plan needs budget");
                    SamplingParams::ErrorBound {
                        err_desired: err.bound,
                        confidence: err.confidence,
                        sigmas: self.feedback.sigmas(&fingerprint),
                        default_sigma: self.feedback.default_sigma(&fingerprint),
                    }
                } else {
                    SamplingParams::Fraction(fraction)
                };
                let acfg = ApproxConfig {
                    params,
                    estimator: self.cfg.estimator,
                    seed: self.cfg.seed,
                };
                let agg: &mut dyn BatchAggregator = match &mut self.join_agg {
                    Some(x) => x,
                    None => &mut self.native_agg,
                };
                let (strata, draws) =
                    sample_stage(&mut cluster, &filtered, query.combine, &acfg, agg)?;
                (strata, draws, true)
            }
        };

        // ---- fault harvest: accuracy-preserving degradation happens
        // BEFORE estimation, so unrecoverable strata are dropped,
        // survivors re-weighted and the CI widened rather than erroring
        let mut fault_report = cluster.take_fault_report();
        if let Some(rep) = fault_report.as_mut() {
            crate::faults::degrade_strata(
                rep,
                &mut strata,
                &mut draws,
                self.cfg.workers,
                sampled,
            )?;
        }

        // ---- stage 2.3: error estimation (§3.4)
        let result = estimate_result(
            query.agg,
            sampled,
            self.cfg.estimator,
            &strata,
            &draws,
            confidence,
        );

        // feedback: store per-stratum σ for subsequent runs (§3.2 II)
        self.feedback.record(&fingerprint, &strata);

        let metrics = cluster.take_metrics();
        let ledger = cluster.take_ledger();

        // close the calibration loop: per-step measured cardinalities into
        // the report, exact pair selectivities + the measured/predicted
        // byte ratio into the feedback store for the next plan
        if let Some(r) = join_order.as_mut() {
            r.set_measured(&crate::join::order::measure_step_cardinalities(
                &exec_inputs,
            ));
            crate::join::order::calibrate(
                &mut self.feedback,
                "",
                &exec_tables,
                &exec_inputs,
                r.cost.shuffle_bytes,
                ledger.total_bytes() as f64,
            );
        }

        Ok(QueryOutcome {
            sim_secs: metrics.total_sim_secs(),
            result,
            metrics,
            ledger,
            mode,
            d_dt,
            output_cardinality: strata.values().map(|s| s.population).sum(),
            // the engine's exact path is stage-1 filtering + cross product,
            // i.e. the bloom strategy; its sampled path is the full approx
            strategy: match mode {
                ExecutionMode::Exact => "bloom".to_string(),
                ExecutionMode::Sampled { .. } => "approx".to_string(),
            },
            plan: None,
            grouped: None,
            filter_report: Some(filter_report),
            join_order,
            fault_report,
        })
    }

    /// The §3.2 fraction planner: exact when affordable, else sampled.
    /// (Strategy *selection* across join algorithms is the job of the
    /// cost-based [`crate::join::Planner`] driving the session API; this
    /// decides only how much of the filtered join output to enumerate.)
    fn plan(&self, query: &Query, d_dt: f64, total_pairs: f64) -> ExecutionMode {
        if let Some(d_desired) = query.budget.latency_secs {
            let s = self
                .cost
                .fraction_for_latency(d_desired, d_dt, total_pairs)
                .max(1e-6);
            if s >= 1.0 {
                return ExecutionMode::Exact; // §3.1.1: no approximation needed
            }
            return ExecutionMode::Sampled { fraction: s };
        }
        if query.budget.error.is_some() {
            return ExecutionMode::Sampled {
                fraction: f64::NAN, // error-driven per-stratum sizes
            };
        }
        ExecutionMode::Exact
    }
}

/// §3.4 error estimation shared by the engine, the session front end and
/// the relational layer: pick the estimator for the (aggregate, sampled?,
/// kind) combination and close the approximation loop over per-stratum
/// aggregates.
pub(crate) fn estimate_result(
    agg: AggFunc,
    sampled: bool,
    estimator: EstimatorKind,
    strata: &HashMap<u64, StratumAgg>,
    draws: &HashMap<u64, f64>,
    confidence: f64,
) -> ApproxResult {
    // ascending key order: f64 accumulation in the estimators must not
    // depend on HashMap iteration order, or identical runs would differ
    // in low-order bits
    let mut order: Vec<u64> = strata.keys().copied().collect();
    order.sort_unstable();
    let strata_vec: Vec<StratumAgg> = order.iter().map(|k| strata[k]).collect();
    // only the Horvitz-Thompson SUM arm consumes per-stratum draw counts
    let ht_sum = sampled
        && estimator == EstimatorKind::HorvitzThompson
        && matches!(agg, AggFunc::Sum);
    let d: Vec<f64> = if ht_sum {
        order
            .iter()
            .map(|k| draws.get(k).copied().unwrap_or(0.0))
            .collect()
    } else {
        Vec::new()
    };
    crate::relation::grouped::estimate_slice(agg, sampled, estimator, &strata_vec, &d, confidence)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_overlapping, SyntheticSpec};
    use crate::query::parse;

    fn engine() -> ApproxJoinEngine {
        ApproxJoinEngine::without_runtime(EngineConfig {
            workers: 4,
            ..Default::default()
        })
        .unwrap()
    }

    fn small_inputs() -> Vec<Dataset> {
        generate_overlapping(&SyntheticSpec {
            items_per_input: 5_000,
            overlap_fraction: 0.05,
            lambda: 40.0,
            partitions: 4,
            seed: 3,
            ..Default::default()
        })
    }

    #[test]
    fn unbudgeted_query_is_exact() {
        let mut e = engine();
        let q = parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k").unwrap();
        let inputs = small_inputs();
        let out = e.execute_on(&q, &inputs).unwrap();
        assert_eq!(out.mode, ExecutionMode::Exact);
        assert_eq!(out.result.error_bound, 0.0);
        assert!(out.result.estimate != 0.0);
        assert!(out.output_cardinality > 0.0);
        // the measured ledger always agrees with the metrics totals
        assert_eq!(out.ledger.total_bytes(), out.metrics.total_shuffled_bytes());
        assert!(!out.ledger.stages.is_empty());
    }

    #[test]
    fn tight_latency_budget_samples() {
        let mut e = engine();
        // absurdly tight budget forces sampling
        let q = parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 0.000001 SECONDS")
            .unwrap();
        let inputs = small_inputs();
        let out = e.execute_on(&q, &inputs).unwrap();
        match out.mode {
            ExecutionMode::Sampled { fraction } => assert!(fraction < 1.0),
            m => panic!("expected sampled, got {m:?}"),
        }
        assert!(out.result.error_bound > 0.0);
    }

    #[test]
    fn loose_latency_budget_exact() {
        let mut e = engine();
        let q =
            parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 10000 SECONDS").unwrap();
        let inputs = small_inputs();
        let out = e.execute_on(&q, &inputs).unwrap();
        assert_eq!(out.mode, ExecutionMode::Exact);
    }

    #[test]
    fn sampled_estimate_tracks_exact() {
        let mut e = engine();
        let inputs = small_inputs();
        let exact = e
            .execute_on(
                &parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k").unwrap(),
                &inputs,
            )
            .unwrap();
        let approx = e
            .execute_on(
                // budget that lands at a mid fraction
                &parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k WITHIN 0.05 SECONDS")
                    .unwrap(),
                &inputs,
            )
            .unwrap();
        let rel =
            (approx.result.estimate - exact.result.estimate).abs() / exact.result.estimate.abs();
        assert!(rel < 0.2, "rel {rel}");
    }

    #[test]
    fn error_budget_uses_feedback_and_tightens() {
        let mut e = engine();
        let inputs = small_inputs();
        let q = parse(
            "SELECT AVG(a.v + b.v) FROM a, b WHERE a.k = b.k ERROR 0.5 CONFIDENCE 95%",
        )
        .unwrap();
        // first run: no σ stored, default sigma
        let first = e.execute_on(&q, &inputs).unwrap();
        assert!(e.feedback.has(&q.fingerprint()));
        // second run: stored σ should produce a bound near/below target
        let second = e.execute_on(&q, &inputs).unwrap();
        assert!(
            second.result.error_bound <= first.result.error_bound * 2.0,
            "first {} second {}",
            first.result.error_bound,
            second.result.error_bound
        );
    }

    #[test]
    fn count_is_exact_even_when_sampled() {
        let mut e = engine();
        let inputs = small_inputs();
        let exact = e
            .execute_on(
                &parse("SELECT COUNT(*) FROM a, b WHERE a.k = b.k").unwrap(),
                &inputs,
            )
            .unwrap();
        let sampled = e
            .execute_on(
                &parse("SELECT COUNT(*) FROM a, b WHERE a.k = b.k WITHIN 0.001 SECONDS").unwrap(),
                &inputs,
            )
            .unwrap();
        assert_eq!(exact.result.estimate, sampled.result.estimate);
        assert_eq!(sampled.result.error_bound, 0.0);
    }

    #[test]
    fn missing_dataset_is_error() {
        let mut e = engine();
        let q = parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k").unwrap();
        let err = e.execute(&q, &HashMap::new()).unwrap_err();
        assert!(err.to_string().contains("not registered"));
    }

    #[test]
    fn arity_mismatch_is_error() {
        let mut e = engine();
        let q = parse("SELECT SUM(a.v + b.v) FROM a, b WHERE a.k = b.k").unwrap();
        let inputs = small_inputs();
        assert!(e.execute_on(&q, &inputs[..1]).is_err());
    }
}
