//! Engine configuration.

use crate::bloom::FilterKind;
use crate::cluster::TimeModel;
use crate::stats::EstimatorKind;
use std::path::PathBuf;

/// Configuration of an [`super::ApproxJoinEngine`].
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Logical workers in the simulated cluster (the paper's k).
    pub workers: usize,
    /// OS threads the partition-parallel executor runs the per-worker
    /// task loops on. A throughput knob: given the same sampling decisions
    /// (fixed seed + fixed sampling params), results are bit-identical for
    /// any value (see `runtime::parallel`). Latency-budgeted queries are
    /// the exception — the engine sizes their sampling fraction from
    /// *measured* filter wall time, which varies with thread count and
    /// load. Defaults to `runtime::default_parallelism()`; 1 forces the
    /// sequential path.
    pub parallelism: usize,
    pub time_model: TimeModel,
    /// Bloom filter false-positive target (eq 27 sizing); the filter
    /// geometry snaps to the AOT artifact's (2^20, h=5) when compatible so
    /// the XLA prober can run.
    pub fp_rate: f64,
    /// Pin the artifact geometry regardless of input size (lets the XLA
    /// prober engage; costs filter bytes on small inputs).
    pub pin_artifact_filter_geometry: bool,
    /// Bit layout of the join filters every strategy builds:
    /// `FilterKind::Standard` (default, XLA-artifact compatible) or the
    /// opt-in `FilterKind::Blocked` cache-line hot path (one memory
    /// access per probe, slightly higher fp rate; native probing only).
    /// Survivor *results* are identical either way — false positives are
    /// dropped at the cogroup — only probe speed and shuffled bytes move.
    pub filter_kind: FilterKind,
    pub estimator: EstimatorKind,
    /// Directory with AOT artifacts; None → pure-Rust execution.
    pub artifacts_dir: Option<PathBuf>,
    /// Per-worker memory budget for native-join intermediates.
    pub memory_budget: u64,
    /// Reorder multi-way (3+ relation) joins with the DP/greedy join-order
    /// optimizer (`join::order`) before execution. On by default; planning
    /// is a pure function of (query, stats, feedback snapshot), so results
    /// stay bit-identical at any thread count. Only commutative combine
    /// ops (`Sum`, `Product`) are ever reordered.
    pub reorder_joins: bool,
    /// Overlap fraction above which filtering alone cannot help and the
    /// engine refuses an exact plan under a latency budget (§3.1.1 check).
    pub seed: u64,
    /// Deterministic fault-injection plan threaded into every
    /// [`crate::cluster::SimCluster`] the engine builds; `None` (the
    /// default) runs the pipeline fault-free and bit-identically to a
    /// build without the faults subsystem.
    pub faults: Option<crate::faults::FaultPlan>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 10, // the paper's cluster size
            parallelism: crate::runtime::default_parallelism(),
            time_model: TimeModel::default(),
            fp_rate: 0.01,
            pin_artifact_filter_geometry: false,
            filter_kind: FilterKind::Standard,
            estimator: EstimatorKind::Clt,
            artifacts_dir: default_artifacts_dir(),
            memory_budget: crate::join::native::DEFAULT_MEMORY_BUDGET,
            reorder_joins: true,
            seed: 42,
            faults: None,
        }
    }
}

/// `artifacts/` next to Cargo.toml when present (dev layout), else None.
pub fn default_artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_papers_cluster() {
        let c = EngineConfig::default();
        assert_eq!(c.workers, 10);
        assert_eq!(c.fp_rate, 0.01);
        assert!(c.parallelism >= 1);
    }
}
