//! Comparison baselines:
//!
//! * **extended repartition join** (§5.3): Spark repartition join followed
//!   by stratified sampling over the finished join output — also how the
//!   SnappyData comparison of §5.5 samples (post-join).
//! * **pre-join sampled repartition join** (Fig 1 / §6.1): `sampleByKey`
//!   each input first, join the samples, scale the aggregate back up —
//!   fast but statistically unsound for joins.

use crate::cluster::shuffle::shuffle_dataset;
use crate::cluster::{JoinMetrics, SimCluster};
use crate::data::Dataset;
use crate::join::{group_by_key, CombineOp, JoinStrategy, RepartitionJoin};
use crate::runtime::ParallelExecutor;
use crate::sampling::stratified::{post_join_reservoir_strata, sample_by_key};
use crate::stats::{clt_sum, ApproxResult, StratumAgg};
use crate::util::Rng;
use std::collections::HashMap;
use std::time::Instant;

/// Outcome of a baseline run.
#[derive(Clone, Debug)]
pub struct BaselineRun {
    pub estimate: ApproxResult,
    pub metrics: JoinMetrics,
    /// Per-key aggregates (post-join path) for accuracy analysis.
    pub strata: HashMap<u64, StratumAgg>,
}

/// Extended repartition join: full join, then per-key reservoir sampling of
/// `fraction` of the output (SnappyData-style post-join sampling).
pub fn post_join_sampling(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    fraction: f64,
    confidence: f64,
    seed: u64,
) -> BaselineRun {
    // full repartition shuffle
    let mut s = cluster.stage("shuffle");
    let shuffled: Vec<Vec<Vec<crate::data::Record>>> = inputs
        .iter()
        .map(|d| shuffle_dataset(cluster, &mut s, d))
        .collect();
    s.finish(cluster);

    // full cross product with inline reservoir (the reservoir does not
    // reduce the enumeration cost — that is the point of this baseline);
    // strata run data-parallel with per-(seed, key) RNGs, so the result is
    // identical for any worker visit order or thread count
    let mut s = cluster.stage("join_then_sample");
    let exec = cluster.exec;
    let per_worker = exec.map(cluster.k, |w| {
        let per_input: Vec<Vec<crate::data::Record>> =
            shuffled.iter().map(|inp| inp[w].clone()).collect();
        let t0 = Instant::now();
        let mut groups = group_by_key(&per_input);
        groups.retain(|_, sides| sides.iter().all(|s| !s.is_empty()));
        // the worker-level map above is already parallel; strata within a
        // worker run sequentially to avoid nested thread scopes
        let local = post_join_reservoir_strata(
            &groups,
            fraction,
            op,
            seed,
            &ParallelExecutor::sequential(),
        );
        let pairs: u64 = local.values().map(|a| a.population as u64).sum();
        (local, pairs, t0.elapsed().as_secs_f64())
    });
    let mut strata: HashMap<u64, StratumAgg> = HashMap::new();
    for (w, (local, pairs, secs)) in per_worker.into_iter().enumerate() {
        strata.extend(local);
        s.add_compute(w, secs);
        s.add_items(pairs);
    }
    s.finish(cluster);

    let strata_vec: Vec<StratumAgg> = strata.values().copied().collect();
    BaselineRun {
        estimate: clt_sum(&strata_vec, confidence),
        metrics: cluster.take_metrics(),
        strata,
    }
}

/// Pre-join sampling: sampleByKey each input at `fraction`, join the
/// samples exactly, scale the SUM back by (1/fraction)^n. The scaling is
/// the textbook-naive estimator whose per-key bias the paper's Fig 1/13c
/// quantifies; no sound error bound exists for it, so the bound is
/// reported as NaN.
pub fn pre_join_sampling(
    cluster: &mut SimCluster,
    inputs: &[Dataset],
    op: CombineOp,
    fraction: f64,
    confidence: f64,
    seed: u64,
) -> BaselineRun {
    let mut rng = Rng::new(seed);
    let mut s = cluster.stage("pre_sample");
    let sampled: Vec<Dataset> = inputs
        .iter()
        .map(|d| {
            let mut r = rng.fork(1);
            let t0 = Instant::now();
            let out = sample_by_key(d, fraction, &mut r);
            s.add_compute(0, t0.elapsed().as_secs_f64());
            out
        })
        .collect();
    s.finish(cluster);

    let run = RepartitionJoin
        .execute(cluster, &sampled, op)
        .expect("repartition join is infallible");
    let scale = (1.0 / fraction).powi(inputs.len() as i32);
    let estimate = run.exact_sum() * scale;
    BaselineRun {
        estimate: ApproxResult {
            estimate,
            error_bound: f64::NAN,
            confidence,
            degrees_of_freedom: f64::NAN,
            samples: run.strata.values().map(|s| s.count as u64).sum(),
        },
        metrics: run.metrics,
        strata: run.strata,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TimeModel;
    use crate::data::Record;
    use crate::join::NativeJoin;

    fn cluster() -> SimCluster {
        SimCluster::new(
            4,
            TimeModel {
                bandwidth: 1e9,
                stage_latency: 0.0,
                compute_scale: 1.0,
            },
        )
    }

    fn inputs() -> Vec<Dataset> {
        let mut r = Rng::new(10);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for key in 0..30u64 {
            for _ in 0..20 {
                a.push(Record::new(key, r.range_f64(0.0, 10.0)));
                b.push(Record::new(key, r.range_f64(0.0, 10.0)));
            }
        }
        vec![
            Dataset::from_records_unpartitioned("a", a, 4, 100),
            Dataset::from_records_unpartitioned("b", b, 4, 100),
        ]
    }

    #[test]
    fn post_join_sampling_is_accurate() {
        let ins = inputs();
        let exact = NativeJoin {
            memory_budget: u64::MAX,
        }
        .execute(&mut cluster(), &ins, CombineOp::Sum)
        .unwrap()
        .exact_sum();
        let run = post_join_sampling(&mut cluster(), &ins, CombineOp::Sum, 0.2, 0.95, 1);
        let rel = (run.estimate.estimate - exact).abs() / exact;
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn post_join_sampling_enumerates_everything() {
        let ins = inputs();
        let run = post_join_sampling(&mut cluster(), &ins, CombineOp::Sum, 0.1, 0.95, 1);
        // items processed in the join stage == full cross product size
        let st = run.metrics.stage("join_then_sample").unwrap();
        assert_eq!(st.items, 30 * 20 * 20);
    }

    #[test]
    fn pre_join_sampling_is_fast_but_rough() {
        let ins = inputs();
        let exact = NativeJoin {
            memory_budget: u64::MAX,
        }
        .execute(&mut cluster(), &ins, CombineOp::Sum)
        .unwrap()
        .exact_sum();
        let run = pre_join_sampling(&mut cluster(), &ins, CombineOp::Sum, 0.5, 0.95, 2);
        // it enumerates far fewer pairs...
        let joined: u64 = run
            .metrics
            .stage("crossproduct")
            .map(|s| s.items)
            .unwrap_or(0);
        assert!(joined < 30 * 20 * 20 / 2, "joined {joined}");
        // ...and lands within cooee of the truth only in expectation
        let rel = (run.estimate.estimate - exact).abs() / exact;
        assert!(rel < 0.5, "rel {rel}");
        assert!(run.estimate.error_bound.is_nan());
    }

    #[test]
    fn pre_join_estimator_unbiased_over_reps() {
        let ins = inputs();
        let exact = NativeJoin {
            memory_budget: u64::MAX,
        }
        .execute(&mut cluster(), &ins, CombineOp::Sum)
        .unwrap()
        .exact_sum();
        let mut mean = 0.0;
        let reps = 30;
        for seed in 0..reps {
            let run = pre_join_sampling(&mut cluster(), &ins, CombineOp::Sum, 0.4, 0.95, seed);
            mean += run.estimate.estimate;
        }
        mean /= reps as f64;
        assert!((mean - exact).abs() / exact < 0.1, "mean {mean} vs {exact}");
    }
}
